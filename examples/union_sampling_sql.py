"""The paper's core experience: estimate and sample a union of joins.

Walks UQ1 (five chain joins with controlled overlap), comparing the paper's
three parameter-estimation instantiations and both Algorithm 1 modes, plus
ONLINE-UNION (Algorithm 2) with sample reuse.

    PYTHONPATH=src python examples/union_sampling_sql.py
"""

import time

import numpy as np

from repro.core import (OnlineUnionSampler, SetUnionSampler, estimate_union,
                        exact_union_size, warmup)
from repro.data.workloads import uq1


def main() -> None:
    wl = uq1(scale=0.1, overlap=0.4, seed=0, n_joins=3)
    cat, joins = wl.cat, wl.joins
    U = exact_union_size(cat, joins)
    print(f"UQ1 (3 joins, 5 relations each): exact |U| = {U}")

    print("\n-- warm-up comparison (|J_i| and |U| estimates) --")
    oracles = {}
    for method in ("histogram", "random_walk", "exact"):
        t0 = time.perf_counter()
        wr = warmup(cat, joins, method=method, rw_max_walks=6000)
        est = estimate_union(wr.oracle)
        dt = time.perf_counter() - t0
        oracles[method] = est
        sizes = [f"{wr.oracle.size(j.name):9.0f}" for j in joins]
        print(f"{method:12s} |J|={sizes} |U|={est.union_size_cover:9.0f} "
              f"({dt*1e3:.0f} ms)")

    print("\n-- Algorithm 1: probe vs record membership --")
    for membership in ("probe", "record"):
        s = SetUnionSampler(cat, joins, oracles["random_walk"].cover,
                            membership=membership, seed=1)
        t0 = time.perf_counter()
        ss = s.sample(2000)
        dt = time.perf_counter() - t0
        st = ss.stats
        print(f"{membership:7s}: {len(ss)} samples in {dt:.2f}s "
              f"(draws={st.candidate_draws}, rejects={st.cover_rejects}, "
              f"revisions={st.revisions})")

    print("\n-- Algorithm 2 (ONLINE-UNION): reuse + backtracking --")
    ou = OnlineUnionSampler(cat, joins, seed=2, phi=1024, rw_batch=256)
    t0 = time.perf_counter()
    ss = ou.sample(2000)
    dt = time.perf_counter() - t0
    print(f"online: {len(ss)} samples in {dt:.2f}s "
          f"(reuse_accepts={ss.stats.reuse_accepts}, "
          f"backtrack_removed={ss.stats.backtrack_removed})")


if __name__ == "__main__":
    main()
