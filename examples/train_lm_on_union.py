"""End-to-end driver: train the paper-native ~100M LM on the union stream.

Runs a few hundred steps on CPU with the reduced config by default; pass
--full for the real unionlm-100m (12L, d768) — minutes per step on CPU,
production speed under the TPU mesh (launch/dryrun.py proves the lowering).

    PYTHONPATH=src python examples/train_lm_on_union.py [--full] [--steps N]
"""

import argparse

from repro.launch.train import main as train_main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workload", default="UQ1")
    args = ap.parse_args()

    argv = ["--arch", "unionlm-100m", "--workload", args.workload,
            "--scale", "0.1", "--warmup", "random_walk", "--online",
            "--steps", str(args.steps), "--batch", "8", "--seq", "256",
            "--lr", "6e-4", "--checkpoint-dir", "/tmp/repro_unionlm",
            "--checkpoint-every", "100"]
    if not args.full:
        argv.append("--smoke")
    train_main(argv)


if __name__ == "__main__":
    main()
