"""Quickstart: sample i.i.d. tuples from a union of joins, then train on them.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (JoinSampler, SetUnionSampler, estimate_union,
                        exact_union_size, warmup)
from repro.data.workloads import uq3


def main() -> None:
    # 1. a union of three joins over TPC-H-lite (different schemas per join)
    wl = uq3(scale=0.02, overlap=0.3, seed=0)
    print(f"workload {wl.name}: {[j.name for j in wl.joins]}")
    for j in wl.joins:
        kind = "cyclic" if j.is_cyclic else ("chain" if j.is_chain else "acyclic")
        print(f"  {j.name}: {kind}, relations="
              f"{[n.relation.name for n in j.nodes]}")

    # 2. warm-up: estimate |J_i| and |U| three ways
    for method in ("histogram", "random_walk", "exact"):
        wr = warmup(wl.cat, wl.joins, method=method, rw_max_walks=4000)
        est = estimate_union(wr.oracle)
        print(f"  |U| via {method:11s}: {est.union_size_cover:10.1f} "
              f"(eq1: {est.union_size_eq1:10.1f}, {wr.seconds*1e3:7.1f} ms)")
    print(f"  |U| exact (FULLJOIN): {exact_union_size(wl.cat, wl.joins)}")

    # 3. Algorithm 1: uniform i.i.d. samples from the set union
    wr = warmup(wl.cat, wl.joins, method="random_walk", rw_max_walks=4000)
    est = estimate_union(wr.oracle)
    sampler = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=0)
    ss = sampler.sample(1000)
    print(f"sampled {len(ss)} tuples; per-join credit: "
          f"{np.bincount(ss.home, minlength=len(wl.joins)).tolist()}; "
          f"cover rejects: {ss.stats.cover_rejects}")

    # 4. feed an LM a few training steps from the stream
    from repro.launch.train import main as train_main
    train_main(["--arch", "unionlm-100m", "--smoke", "--workload", "UQ3",
                "--steps", "20", "--batch", "4", "--seq", "128",
                "--lr", "1e-3", "--checkpoint-dir", "/tmp/repro_quickstart"])


if __name__ == "__main__":
    main()
