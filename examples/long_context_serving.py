"""Long-context serving: O(1)-state SSM decode + a streaming sample feed.

Demonstrates why `long_500k` runs for the SSM/hybrid archs: mamba2's decode
state is constant in context length, and gemma2's local layers cap their KV
at the window size.  (Smoke configs; the production shapes are exercised by
launch/dryrun.py.)  The final section feeds the decode loop from the
streaming union-sample service (`repro.serve.SampleService`) — the pattern a
data-augmented serving stack uses: samples are prefetched by the service's
producer thread while the model decodes, so the feed adds no decode latency.

    PYTHONPATH=src python examples/long_context_serving.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.serve import cache_specs, decode_step, init_cache
from repro.models.transformer import init_params


def cache_bytes(cfg, batch, max_len) -> int:
    return sum(int(np.prod(s.shape)) * 2
               for s in cache_specs(cfg, batch, max_len).values())


def main() -> None:
    B = 2
    print("-- decode-state size vs context length --")
    for arch in ("mamba2-780m", "gemma2-9b", "minitron-8b"):
        cfg = get_smoke_config(arch)
        sizes = [cache_bytes(cfg, B, n) for n in (1024, 8192, 65536)]
        kind = {"mamba2": "O(1) state", "gemma2": "ring-buffer local KV",
                "dense": "full KV"}.get(cfg.family, cfg.family)
        print(f"{arch:14s} ({kind:22s}): "
              + "  ".join(f"{n:>6d} ctx -> {b/2**20:7.2f} MiB"
                          for n, b in zip((1024, 8192, 65536), sizes)))

    print("\n-- sustained decode (mamba2 smoke, 256 tokens) --")
    cfg = get_smoke_config("mamba2-780m")
    params = init_params(cfg, seed=0)
    cache = init_cache(cfg, B, 16)      # state is length-independent
    step = jax.jit(lambda c, t, l: decode_step(params, cfg, c, t, l))
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(4, cfg.vocab, (B, 1)), jnp.int32)
    # warm up compile
    cache, logits = step(cache, tok, jnp.zeros((B,), jnp.int32))
    t0 = time.perf_counter()
    n = 256
    for i in range(n):
        cache, logits = step(cache, tok, jnp.full((B,), i + 1, jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(B, 1)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    print(f"{n} decode steps in {dt:.2f}s ({n/dt:.0f} tok/s/seq on CPU; "
          f"state bytes constant at {cache_bytes(cfg, B, 16)/2**20:.2f} MiB)")

    print("\n-- streaming union-sample feed (SampleService) --")
    from repro.core.framework import estimate_union, warmup
    from repro.core.union_sampler import SetUnionSampler
    from repro.data.workloads import uq3
    from repro.serve import SampleService

    wl = uq3(scale=0.02, overlap=0.3, seed=0)
    est = estimate_union(warmup(wl.cat, wl.joins, method="histogram").oracle)
    sampler = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=0,
                              backend="jax", round_batch=2048)
    with SampleService(sampler, batch=2048, prefetch=2) as svc:
        svc.request(256)                     # warm the prefetch pipeline
        t0 = time.perf_counter()
        got = 0
        for i in range(8):                   # interleave: decode + sample feed
            cache, logits = step(cache, tok,
                                 jnp.full((B,), n + i + 1, jnp.int32))
            tok = jnp.argmax(logits, -1).astype(jnp.int32).reshape(B, 1)
            ss = svc.request(512)            # i.i.d. 1/|U| tuples, queue-fed
            got += len(ss)
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        print(f"8 interleaved decode+feed steps in {dt:.2f}s — "
              f"{got} uniform union samples "
              f"({got/max(dt, 1e-9):,.0f} samples/s alongside decode); "
              f"psi={svc.stats().candidate_draws}")


if __name__ == "__main__":
    main()
