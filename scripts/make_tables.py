"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts/."""

import glob
import json
import os
import sys


def main(artifacts="artifacts"):
    for mesh in ("single_pod", "multi_pod"):
        files = sorted(glob.glob(os.path.join(artifacts, mesh, "*.json")))
        print(f"\n### {mesh} ({'16x16=256' if mesh=='single_pod' else '2x16x16=512'} chips)\n")
        print("| arch | shape | compile s | mem/dev GiB | compute s | memory s "
              "| collective s | dominant | roofline frac | useful | coll GiB (AR/AG/A2A/CP) |")
        print("|---|---|---|---|---|---|---|---|---|---|---|")
        for f in files:
            d = json.load(open(f))
            arch, shape = d["arch"], d["shape"]
            if d.get("skipped"):
                print(f"| {arch} | {shape} | — | — | — | — | — | SKIP | — | — | {d['skipped'][:40]}… |")
                continue
            if "error" in d:
                print(f"| {arch} | {shape} | — | — | — | — | — | ERROR | — | — | {d['error'][:40]} |")
                continue
            r = d["roofline"]
            dom_t = max(r["compute_s"], r["memory_s"], r["collective_s"], 1e-12)
            frac = r["compute_s"] / dom_t
            c = d["collectives"]
            cg = "/".join(f"{c.get(k,0)/2**30:.1f}" for k in
                          ("all-reduce", "all-gather", "all-to-all",
                           "collective-permute"))
            print(f"| {arch} | {shape} | {d['compile_s']:.1f} "
                  f"| {d['memory']['per_device_total']/2**30:.2f} "
                  f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
                  f"| {r['collective_s']:.3f} | {r['dominant']} "
                  f"| {frac:.2f} | {r['useful_flops_ratio']:.2f} | {cg} |")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "artifacts")
