#!/usr/bin/env python
"""Perf-regression gate over BENCH_union JSON trajectories.

Compares the latest run's ``samples_per_s`` records against the committed
baseline (``benchmarks/perf_baseline.json``) within a relative tolerance
band (default ±30%):

* a record **slower** than ``baseline * (1 - tol)`` fails the gate (exit 1);
* a record **faster** than ``baseline * (1 + tol)`` prints a notice — the
  machine got quicker or the engine did; refresh the baseline with
  ``--update`` so the band keeps teeth;
* records missing from either side are reported but don't fail (workload
  coverage changes between smoke and full runs).

``--update`` *merges* this run's records into the baseline (overlapping
records refreshed, records the run didn't cover kept), so smoke and full
runs can maintain one baseline file between them.

Usage:
    python scripts/perf_gate.py BENCH_union_smoke.json
    python scripts/perf_gate.py BENCH_union_smoke.json --update   # rebaseline
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "perf_baseline.json")


def latest_rates(bench_path: str) -> dict:
    """``{record_name: samples_per_s}`` from a BENCH file's latest run."""
    with open(bench_path) as f:
        payload = json.load(f)
    records = payload.get("records", [])
    return {r["name"]: float(r["samples_per_s"]) for r in records
            if "samples_per_s" in r}


def update_baseline(bench_path: str, baseline_path: str) -> int:
    """Merge this run's rates into the baseline.

    Records the run covers are overwritten; baseline records the run does
    not cover are kept — so a smoke refresh doesn't wipe full-run rows and
    a new workload sweep extends the baseline instead of replacing it.
    """
    rates = latest_rates(bench_path)
    if not rates:
        print(f"perf_gate: no samples_per_s records in {bench_path}")
        return 1
    try:
        with open(baseline_path) as f:
            prev = json.load(f).get("baselines", {})
    except (FileNotFoundError, json.JSONDecodeError):
        prev = {}
    merged = {**prev, **rates}
    with open(bench_path) as f:
        meta = json.load(f).get("meta", {})
    with open(baseline_path, "w") as f:
        json.dump({"meta": {"source": os.path.basename(bench_path),
                            "git_sha": meta.get("git_sha", "unknown"),
                            "platform": meta.get("platform"),
                            "device_count": meta.get("device_count")},
                   "baselines": merged}, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"perf_gate: wrote baseline {baseline_path} "
          f"({len(rates)} updated, {len(merged)} total)")
    return 0


def gate(bench_path: str, baseline_path: str, tol: float) -> int:
    rates = latest_rates(bench_path)
    try:
        with open(baseline_path) as f:
            base = json.load(f).get("baselines", {})
    except FileNotFoundError:
        print(f"perf_gate: no baseline at {baseline_path}; "
              "run with --update to create one (gate skipped)")
        return 0
    common = sorted(set(rates) & set(base))
    if not common:
        print("perf_gate: no overlapping records between run and baseline "
              "(gate skipped)")
        return 0
    failures, notices = [], []
    for name in common:
        got, want = rates[name], base[name]
        ratio = got / want if want > 0 else float("inf")
        status = "ok"
        if ratio < 1.0 - tol:
            status = "SLOW"
            failures.append(name)
        elif ratio > 1.0 + tol:
            status = "fast"
            notices.append(name)
        print(f"  {name}: {got:,.0f}/s vs baseline {want:,.0f}/s "
              f"({ratio:.2f}x) [{status}]")
    for name in sorted(set(rates) - set(base)):
        print(f"  {name}: {rates[name]:,.0f}/s (no baseline — skipped)")
    for name in sorted(set(base) - set(rates)):
        print(f"  {name}: in baseline but not in this run")
    if notices:
        print(f"perf_gate: NOTICE — {len(notices)} record(s) >"
              f"{tol:.0%} faster than baseline; consider "
              f"`python scripts/perf_gate.py {bench_path} --update`")
    if failures:
        print(f"perf_gate: FAIL — {len(failures)} record(s) more than "
              f"{tol:.0%} slower than baseline: {', '.join(failures)}")
        return 1
    print(f"perf_gate: PASS ({len(common)} records within ±{tol:.0%})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", help="BENCH_*.json produced by the bench CLI")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="relative band around the baseline (default 0.30)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from this run instead of "
                         "gating")
    args = ap.parse_args(argv)
    if args.update:
        return update_baseline(args.bench, args.baseline)
    return gate(args.bench, args.baseline, args.tolerance)


if __name__ == "__main__":
    sys.exit(main())
