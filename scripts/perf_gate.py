#!/usr/bin/env python
"""Perf-regression gate over BENCH_union JSON trajectories.

Compares the latest run's records against the committed baseline
(``benchmarks/perf_baseline.json``) within relative tolerance bands:

* ``samples_per_s`` **slower** than ``baseline * (1 - tol)`` fails the gate
  (exit 1); **faster** than ``baseline * (1 + tol)`` prints a notice — the
  machine got quicker or the engine did; refresh the baseline with
  ``--update`` so the band keeps teeth;
* ``psi`` (candidate draws per emitted sample — waste) **higher** than
  ``baseline * (1 + psi_tol)`` also fails: an engine can hold samples/s on a
  faster machine while silently drawing twice the candidates, and the psi
  band catches exactly that;
* records missing from either side are reported but don't fail (workload
  coverage changes between smoke and full runs).

Baseline schema: ``{"baselines": {name: {"samples_per_s": float,
"psi": float}}}``.  Legacy baselines whose values are bare floats
(samples_per_s only) still gate on rate and pick up psi bands on the next
``--update``.

``--update`` *merges* this run's records into the baseline (overlapping
records refreshed, records the run didn't cover kept), so smoke and full
runs can maintain one baseline file between them.

Usage:
    python scripts/perf_gate.py BENCH_union_smoke.json
    python scripts/perf_gate.py BENCH_union_smoke.json --update   # rebaseline
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "perf_baseline.json")


def latest_rates(bench_path: str) -> dict:
    """``{record_name: {"samples_per_s": ..., "psi": ...}}`` from a BENCH
    file's latest run (psi omitted when the record doesn't carry one)."""
    with open(bench_path) as f:
        payload = json.load(f)
    out = {}
    for r in payload.get("records", []):
        if "samples_per_s" not in r:
            continue
        entry = {"samples_per_s": float(r["samples_per_s"])}
        if "psi" in r:
            entry["psi"] = float(r["psi"])
        out[r["name"]] = entry
    return out


def _as_entry(value) -> dict:
    """Normalise a baseline value: legacy bare floats are rate-only."""
    if isinstance(value, dict):
        return value
    return {"samples_per_s": float(value)}


def update_baseline(bench_path: str, baseline_path: str) -> int:
    """Merge this run's rates into the baseline.

    Records the run covers are overwritten; baseline records the run does
    not cover are kept — so a smoke refresh doesn't wipe full-run rows and
    a new workload sweep extends the baseline instead of replacing it.
    Legacy bare-float values are upgraded to the dict schema as they merge.
    """
    rates = latest_rates(bench_path)
    if not rates:
        print(f"perf_gate: no samples_per_s records in {bench_path}")
        return 1
    try:
        with open(baseline_path) as f:
            prev = json.load(f).get("baselines", {})
    except (FileNotFoundError, json.JSONDecodeError):
        prev = {}
    merged = {name: _as_entry(v) for name, v in prev.items()}
    merged.update(rates)
    with open(bench_path) as f:
        meta = json.load(f).get("meta", {})
    with open(baseline_path, "w") as f:
        json.dump({"meta": {"source": os.path.basename(bench_path),
                            "git_sha": meta.get("git_sha", "unknown"),
                            "platform": meta.get("platform"),
                            "device_count": meta.get("device_count")},
                   "baselines": merged}, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"perf_gate: wrote baseline {baseline_path} "
          f"({len(rates)} updated, {len(merged)} total)")
    return 0


def gate(bench_path: str, baseline_path: str, tol: float,
         psi_tol: float) -> int:
    rates = latest_rates(bench_path)
    try:
        with open(baseline_path) as f:
            base = {name: _as_entry(v)
                    for name, v in json.load(f).get("baselines", {}).items()}
    except FileNotFoundError:
        print(f"perf_gate: no baseline at {baseline_path}; "
              "run with --update to create one (gate skipped)")
        return 0
    common = sorted(set(rates) & set(base))
    if not common:
        print("perf_gate: no overlapping records between run and baseline "
              "(gate skipped)")
        return 0
    failures, notices = [], []
    for name in common:
        got, want = rates[name], base[name]
        ratio = (got["samples_per_s"] / want["samples_per_s"]
                 if want["samples_per_s"] > 0 else float("inf"))
        status = "ok"
        if ratio < 1.0 - tol:
            status = "SLOW"
            failures.append(name)
        elif ratio > 1.0 + tol:
            status = "fast"
            notices.append(name)
        psi_note = ""
        if "psi" in got and "psi" in want and want["psi"] > 0:
            pr = got["psi"] / want["psi"]
            psi_note = f" psi={got['psi']:.2f} vs {want['psi']:.2f}"
            if pr > 1.0 + psi_tol:
                # wasteful regression: more candidate draws per sample even
                # if wall-clock kept up
                status = "WASTEFUL" if status == "ok" else status
                failures.append(f"{name}(psi)")
            elif pr < 1.0 - psi_tol and status == "ok":
                notices.append(f"{name}(psi)")
        print(f"  {name}: {got['samples_per_s']:,.0f}/s vs baseline "
              f"{want['samples_per_s']:,.0f}/s ({ratio:.2f}x){psi_note} "
              f"[{status}]")
    for name in sorted(set(rates) - set(base)):
        print(f"  {name}: {rates[name]['samples_per_s']:,.0f}/s "
              "(no baseline — skipped)")
    for name in sorted(set(base) - set(rates)):
        print(f"  {name}: in baseline but not in this run")
    if notices:
        print(f"perf_gate: NOTICE — {len(notices)} record(s) >"
              f"{tol:.0%} better than baseline; consider "
              f"`python scripts/perf_gate.py {bench_path} --update`")
    if failures:
        print(f"perf_gate: FAIL — {len(failures)} record(s) regressed "
              f"(rate band ±{tol:.0%}, psi band +{psi_tol:.0%}): "
              f"{', '.join(failures)}")
        return 1
    print(f"perf_gate: PASS ({len(common)} records within ±{tol:.0%}, "
          f"psi within +{psi_tol:.0%})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", help="BENCH_*.json produced by the bench CLI")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="relative band around the baseline (default 0.30)")
    ap.add_argument("--psi-tolerance", type=float, default=0.40,
                    help="allowed relative psi (waste) increase before the "
                         "gate fails (default 0.40)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from this run instead of "
                         "gating")
    args = ap.parse_args(argv)
    if args.update:
        return update_baseline(args.bench, args.baseline)
    return gate(args.bench, args.baseline, args.tolerance,
                args.psi_tolerance)


if __name__ == "__main__":
    sys.exit(main())
