#!/usr/bin/env python
"""Observability smoke: scrape /metrics from a short-lived serve CLI.

Spawns ``python -m repro.launch.serve --mode samples`` with an ephemeral
``--metrics-port`` and a linger window, polls the printed URL, and asserts:

* ``/healthz`` answers ``ok``;
* ``/metrics`` is well-formed Prometheus text exposition (every sample line
  belongs to a ``# TYPE``-declared family, histogram ``_bucket`` series are
  cumulative and end at ``+Inf`` = ``_count``);
* the serve-tier request histogram saw traffic (nonzero ``_count``) and the
  derived p50/p99 gauges are positive;
* the queue-depth gauge is present.

Exit 0 on success; nonzero with a diagnostic otherwise.  Used by the CI
perf-smoke job (obs-smoke step); runnable locally:

    PYTHONPATH=src python scripts/obs_smoke.py
"""

from __future__ import annotations

import os
import re
import subprocess
import sys
import time
import urllib.request

SERVE_ARGS = [
    sys.executable, "-m", "repro.launch.serve", "--mode", "samples",
    "--workload", "UQ1", "--scale", "0.05", "--requests", "4",
    "--samples", "1024", "--round-batch", "1024",
    "--metrics-port", "0", "--linger", "30",
]

URL_RE = re.compile(r"metrics: (http://127\.0\.0\.1:\d+)/metrics")


def fetch(url: str, timeout: float = 5.0):
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.status, r.read().decode("utf-8"), r.headers


def wait_for_url(proc, deadline: float) -> str:
    buf = []
    while time.time() < deadline:
        line = proc.stdout.readline()
        if not line:
            if proc.poll() is not None:
                break
            time.sleep(0.1)
            continue
        buf.append(line)
        m = URL_RE.search(line)
        if m:
            return m.group(1)
    raise RuntimeError("serve CLI never printed its metrics URL; output:\n"
                       + "".join(buf))


def wait_for_traffic(url: str, deadline: float) -> str:
    """Poll /metrics until the request histogram has a nonzero count."""
    body = ""
    while time.time() < deadline:
        try:
            _, body, _ = fetch(f"{url}/metrics")
        except Exception:
            time.sleep(0.5)
            continue
        m = re.search(r"^repro_serve_request_seconds_count (\d+)$",
                      body, re.M)
        if m and int(m.group(1)) > 0:
            return body
        time.sleep(0.5)
    raise RuntimeError("request histogram never saw traffic; last scrape:\n"
                       + body[:2000])


def check_exposition(body: str) -> None:
    """Structural validation of the Prometheus text format."""
    types: dict = {}
    for line in body.splitlines():
        if not line or line.startswith("# HELP"):
            continue
        if line.startswith("# TYPE"):
            _, _, name, kind = line.split(None, 3)
            types[name] = kind
            continue
        assert not line.startswith("#"), f"unknown comment line: {line}"
        name = re.split(r"[{ ]", line, 1)[0]
        family = re.sub(r"_(bucket|sum|count)$", "", name)
        assert name in types or family in types, \
            f"sample line without TYPE declaration: {line}"
        value = line.rsplit(" ", 1)[1]
        assert value == "+Inf" or value in ("NaN",) or \
            float(value) == float(value) or True  # parses
    # histogram structure: cumulative buckets ending at +Inf == _count
    for name, kind in types.items():
        if kind != "histogram":
            continue
        buckets = re.findall(
            rf'^{re.escape(name)}_bucket{{le="([^"]+)"}} (\d+)$', body, re.M)
        assert buckets, f"histogram {name} has no buckets"
        counts = [int(c) for _, c in buckets]
        assert counts == sorted(counts), f"{name} buckets not cumulative"
        assert buckets[-1][0] == "+Inf", f"{name} missing +Inf bucket"
        total = re.search(rf"^{re.escape(name)}_count (\d+)$", body, re.M)
        assert total and int(total.group(1)) == counts[-1], \
            f"{name} +Inf bucket != _count"


def main() -> int:
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.Popen(SERVE_ARGS, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True, env=env)
    try:
        url = wait_for_url(proc, time.time() + 240)
        status, health, _ = fetch(f"{url}/healthz")
        assert status == 200 and health.strip() == "ok", \
            f"/healthz: {status} {health!r}"
        body = wait_for_traffic(url, time.time() + 240)
        check_exposition(body)
        for required in ("repro_serve_request_seconds", "repro_serve_queue_depth",
                         "repro_serve_requests_total"):
            assert f"# TYPE {required}" in body, f"missing metric {required}"
        p50 = re.search(r"^repro_serve_request_seconds_p50 (\S+)$", body, re.M)
        p99 = re.search(r"^repro_serve_request_seconds_p99 (\S+)$", body, re.M)
        assert p50 and float(p50.group(1)) > 0, "p50 gauge not positive"
        assert p99 and float(p99.group(1)) > 0, "p99 gauge not positive"
        assert float(p99.group(1)) >= float(p50.group(1)), "p99 < p50"
        print(f"obs_smoke: PASS — {url}/metrics well-formed, "
              f"p50={float(p50.group(1))*1e3:.2f}ms "
              f"p99={float(p99.group(1))*1e3:.2f}ms")
        return 0
    except (AssertionError, RuntimeError) as e:
        print(f"obs_smoke: FAIL — {e}")
        return 1
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()


if __name__ == "__main__":
    sys.exit(main())
