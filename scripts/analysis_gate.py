#!/usr/bin/env python
"""Static invariant gate: AST lint + jaxpr/recompile audits, CI-gating.

Three layers (see ``src/repro/analysis/``):

* ``ast`` — stdlib-only source lint of ``src/repro`` (tracer-safe control
  flow, host escapes, fixed-point discipline, determinism, int32 packing
  guards, stats-vector widths, fallback accounting, lock discipline);
* ``jaxpr`` — traces the real UQ1/UQ4 engines and checks device/host
  primitive parity, collective discipline, and donated-carry aliasing;
* ``recompile`` — drives the engines through mixed request sizes and
  asserts one loop trace per capacity class and per (plan, mode).

Findings already pinned in the baseline file (``analysis_baseline.json``,
each entry carries a fingerprint and a one-line justification) are
suppressed; everything else makes the gate exit non-zero.

Usage::

    python scripts/analysis_gate.py [paths...]           # default src/repro
        [--baseline analysis_baseline.json]
        [--layers ast,jaxpr,recompile]   # default: ast, plus the audit
                                         # layers when jax is importable
        [--require-jax]                  # fail (not skip) if jax missing
        [--json] [--stats artifacts/analysis_stats.json] [--list-rules]

Exit codes: 0 clean (modulo baseline), 1 active findings, 2 usage/internal
error.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.findings import Baseline  # noqa: E402
from repro.analysis.lint import run_lint  # noqa: E402
from repro.analysis.rules import rule_catalog  # noqa: E402

_ALL_LAYERS = ("ast", "jaxpr", "recompile")


def _jax_available() -> bool:
    try:
        import jax  # noqa: F401
        return True
    except Exception:
        return False


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON of justified, suppressed findings")
    ap.add_argument("--layers", default=None,
                    help="comma list from {ast,jaxpr,recompile}")
    ap.add_argument("--require-jax", action="store_true",
                    help="fail instead of skipping audit layers without jax")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON on stdout")
    ap.add_argument("--stats", metavar="PATH", default=None,
                    help="write a findings-count JSON artifact to PATH")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for entry in sorted(rule_catalog(), key=lambda e: e["name"]):
            print(f"{entry['name']:18s} {entry['description']}")
        return 0

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    paths = args.paths or [os.path.join(repo, "src", "repro")]

    if args.layers:
        layers = tuple(s.strip() for s in args.layers.split(",") if s.strip())
        bad = set(layers) - set(_ALL_LAYERS)
        if bad:
            print(f"unknown layers: {sorted(bad)}", file=sys.stderr)
            return 2
    elif args.require_jax or _jax_available():
        layers = _ALL_LAYERS
    else:
        layers = ("ast",)

    skipped = []
    audit_layers = [l for l in layers if l != "ast"]
    if audit_layers and not _jax_available():
        if args.require_jax:
            print("jax is required for the jaxpr/recompile layers but is "
                  "not importable", file=sys.stderr)
            return 2
        skipped = audit_layers
        layers = tuple(l for l in layers if l == "ast")

    findings = []
    reports = []
    if "ast" in layers:
        findings.extend(run_lint(paths))
    if "jaxpr" in layers:
        from repro.analysis.jaxpr_audit import run_jaxpr_audit
        f, r = run_jaxpr_audit()
        findings.extend(f)
        reports.extend(r)
    if "recompile" in layers:
        from repro.analysis.recompile import run_recompile_audit
        f, r = run_recompile_audit()
        findings.extend(f)
        reports.extend(r)

    baseline = Baseline.load(args.baseline) if args.baseline else Baseline()
    active, suppressed = baseline.split(findings)
    stale = baseline.stale(findings)

    by_rule = {}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    stats = {
        "layers": list(layers), "skipped_layers": skipped,
        "total": len(findings), "active": len(active),
        "suppressed": len(suppressed), "stale_baseline": len(stale),
        "by_rule": by_rule, "audits": reports,
    }

    if args.as_json:
        print(json.dumps({
            "stats": stats,
            "findings": [f.to_dict() for f in active],
            "suppressed": [f.to_dict() for f in suppressed],
        }, indent=2))
    else:
        for f in active:
            print(f.render())
        if suppressed:
            print(f"[baseline] {len(suppressed)} finding(s) suppressed")
        for fp in stale:
            print(f"[baseline] stale entry {fp}: no longer fires — "
                  "remove it from the baseline")
        if skipped:
            print(f"[skip] layers {skipped} skipped: jax not importable")
        print(f"analysis gate: {len(active)} active finding(s) across "
              f"{len(layers)} layer(s)")

    if args.stats:
        os.makedirs(os.path.dirname(args.stats) or ".", exist_ok=True)
        with open(args.stats, "w") as fh:
            json.dump(stats, fh, indent=2)

    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
