"""Fault tolerance: supervised training loop with checkpoint/restart,
heartbeat, and straggler-skip.

``TrainSupervisor`` wraps a step function the way a cluster-level launcher
would wrap a worker process:

* **checkpoint/restart** — on any step failure the supervisor restores the
  latest checkpoint (model + optimizer + data-pipeline RNG) and resumes;
  restart storms are bounded by ``max_restarts``.
* **heartbeat** — a monotonically-touched file; an external watchdog (or the
  unit test) detects hangs via mtime staleness.
* **straggler-skip** — if the data pipeline misses its deadline the batch is
  skipped and logged; the union-sample stream is i.i.d., so a skipped batch
  changes nothing statistically (the paper's guarantee doing systems work).
* **elastic resume** — restores accept a different mesh (checkpointer
  re-device_puts to the target shardings), so scale-up/scale-down restarts
  are the same code path.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Optional

from ..checkpoint.checkpointer import Checkpointer


@dataclasses.dataclass
class FTConfig:
    checkpoint_every: int = 50
    max_restarts: int = 5
    heartbeat_path: Optional[str] = None
    batch_deadline_s: Optional[float] = None


@dataclasses.dataclass
class FTStats:
    restarts: int = 0
    skipped_batches: int = 0
    completed_steps: int = 0
    checkpoints: int = 0


class TrainSupervisor:
    def __init__(self, step_fn: Callable[[Any, Any], Any],
                 next_batch: Callable[[], Any],
                 checkpointer: Checkpointer, ft: FTConfig,
                 pipeline_state_fn: Optional[Callable[[], Dict]] = None,
                 restore_pipeline_fn: Optional[Callable[[Dict], None]] = None):
        self.step_fn = step_fn
        self.next_batch = next_batch
        self.ckpt = checkpointer
        self.ft = ft
        self.pipeline_state_fn = pipeline_state_fn
        self.restore_pipeline_fn = restore_pipeline_fn
        self.stats = FTStats()

    def _heartbeat(self) -> None:
        if self.ft.heartbeat_path:
            with open(self.ft.heartbeat_path, "w") as f:
                f.write(str(time.time()))

    def run(self, state: Any, n_steps: int,
            fail_injector: Optional[Callable[[int], None]] = None,
            state_shardings: Any = None) -> Any:
        """Run ``n_steps`` with checkpoint/restart; returns final state."""
        import jax.numpy as jnp
        step0 = int(state["step"])
        target = step0 + n_steps
        restarts = 0
        while int(state["step"]) < target:
            step = int(state["step"])
            try:
                if fail_injector is not None:
                    fail_injector(step)
                t0 = time.perf_counter()
                batch = self.next_batch()
                if (self.ft.batch_deadline_s is not None and
                        time.perf_counter() - t0 > self.ft.batch_deadline_s):
                    self.stats.skipped_batches += 1
                    continue
                if batch is None:          # pipeline-level straggler skip
                    self.stats.skipped_batches += 1
                    continue
                state, metrics = self.step_fn(state, batch)
                self.stats.completed_steps += 1
                self._heartbeat()
                new_step = int(state["step"])
                if new_step % self.ft.checkpoint_every == 0:
                    pp = self.pipeline_state_fn() if self.pipeline_state_fn else None
                    self.ckpt.save(new_step, state, pp)
                    self.stats.checkpoints += 1
            except Exception:
                restarts += 1
                self.stats.restarts += 1
                if restarts > self.ft.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is None:
                    # nothing saved yet: re-raise rather than loop forever
                    if restarts > 1:
                        raise
                    continue
                state, pp = self.ckpt.restore(latest, shardings=state_shardings)
                state["step"] = jnp.asarray(state["step"])
                if pp is not None and self.restore_pipeline_fn is not None:
                    self.restore_pipeline_fn(pp)
        return state
