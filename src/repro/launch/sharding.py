"""Logical-axis sharding rules (MaxText-style) for params, caches, batches.

Logical axes emitted by the model code:
  "embed"   — d_model rows of weights  -> FSDP over ("pod","data")
  "heads"   — attention head dims      -> TP over "model"
  "mlp"     — FFN hidden               -> TP over "model"
  "vocab"   — embedding rows           -> TP over "model"
  "experts" — MoE expert axis          -> EP over "model"
  "layer"   — stacked scan axis        -> never sharded
  "batch"   — activation batch         -> DP over ("pod","data")
  "kvseq"   — KV-cache sequence        -> SP ("model", or ("data","model")
                                          when the batch axis is unsharded —
                                          the long_500k distributed-decode
                                          layout)
  None      — replicated

A rule maps a logical name to mesh axes *if divisibility holds* — otherwise
the dim falls back to replicated (uneven shards are avoided deliberately so
shard_map paths stay legal).
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import data_axes, model_axes


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def rules(mesh: Mesh, *, batch_sharded: bool = True) -> Dict[str, Tuple[str, ...]]:
    da = data_axes(mesh)
    ma = model_axes(mesh)
    return {
        "embed": da,
        "heads": ma,
        "mlp": ma,
        "vocab": ma,
        "experts": ma,
        "layer": (),
        "batch": da if batch_sharded else (),
        "kvseq": ma if batch_sharded else (da + ma),
    }


def spec_for(mesh: Mesh, shape: Tuple[int, ...],
             logical: Sequence[Optional[str]],
             rule: Dict[str, Tuple[str, ...]]) -> P:
    parts = []
    used: set = set()
    for dim, name in zip(shape, logical):
        axes = rule.get(name, ()) if name else ()
        axes = tuple(a for a in axes if a not in used)
        if axes and dim % _axes_size(mesh, axes) == 0:
            parts.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_shardings(mesh: Mesh, shapes: Dict[str, jax.ShapeDtypeStruct],
                   logical: Dict[str, Tuple[Optional[str], ...]],
                   *, batch_sharded: bool = True) -> Dict[str, NamedSharding]:
    r = rules(mesh, batch_sharded=batch_sharded)
    return {k: NamedSharding(mesh, spec_for(mesh, tuple(s.shape), logical[k], r))
            for k, s in shapes.items()}


def batch_sharding(mesh: Mesh, global_batch: int) -> NamedSharding:
    da = data_axes(mesh)
    if global_batch % _axes_size(mesh, da) == 0:
        return NamedSharding(mesh, P(da if len(da) > 1 else da[0]))
    return NamedSharding(mesh, P())


def batch_is_sharded(mesh: Mesh, global_batch: int) -> bool:
    return global_batch % _axes_size(mesh, data_axes(mesh)) == 0


def frontend_sharding(mesh: Mesh, global_batch: int) -> NamedSharding:
    da = data_axes(mesh)
    if global_batch % _axes_size(mesh, da) == 0:
        return NamedSharding(mesh, P(da if len(da) > 1 else da[0], None, "model"))
    return NamedSharding(mesh, P(None, None, "model"))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
