"""Batched serving drivers.

Two modes:

* ``--mode lm`` (default) — continuous-batching-lite greedy decoding.
  Maintains a fixed pool of B decode slots; finished requests are replaced
  from the queue (continuous batching), each slot carrying its own length —
  the per-row ``lengths`` vector is exactly what ``decode_step`` masks on.

      PYTHONPATH=src python -m repro.launch.serve --arch minitron-8b --smoke \
          --requests 8 --max-new 16

* ``--mode samples`` — serve uniform union samples through the streaming
  :class:`repro.serve.SampleService` (prefetched sample queue + request
  batching) over the device-resident engine, optionally mesh-sharded:
  ``--shards k`` builds a k-device mesh and runs the shard_map'd
  Algorithm-1 rounds of ``repro.core.sharding`` (on CPU set
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` first).
  ``--metrics-port P`` additionally starts a background HTTP thread with
  ``/metrics`` (Prometheus text: request-latency histogram + p50/p99,
  queue depth, per-replica engine stats) and ``/healthz`` (``P=0`` binds an
  ephemeral port, printed at startup); ``--linger S`` keeps the service and
  endpoint up for S extra seconds after the request loop so external
  scrapers can collect.

      PYTHONPATH=src python -m repro.launch.serve --mode samples \
          --workload UQ1 --requests 16 --samples 4096 --backend jax \
          --shards 4 --metrics-port 9100
"""

from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def serve_samples(args) -> None:
    """Union-sample serving loop through the streaming SampleService."""
    from ..core.framework import estimate_union, warmup
    from ..core.union_sampler import SetUnionSampler
    from ..data.workloads import WORKLOADS
    from ..serve import SampleService

    wl = WORKLOADS[args.workload](scale=args.scale, seed=args.seed)
    wr = warmup(wl.cat, wl.joins, method="histogram")
    est = estimate_union(wr.oracle)
    mesh = None
    if args.shards:
        from ..core.sharding import make_sampler_mesh
        mesh = make_sampler_mesh(world=args.shards)
    sampler = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=args.seed,
                              backend=args.backend,
                              round_batch=args.round_batch, mesh=mesh,
                              plan=args.plan)
    sampler.sample(256)                     # warm up / compile
    metrics = None
    if args.metrics_port is not None:
        from .. import obs
        metrics = obs.MetricsServer(port=args.metrics_port).start()
        print(f"metrics: {metrics.url}/metrics  (health: "
              f"{metrics.url}/healthz)", flush=True)
    try:
        with SampleService(sampler, batch=args.round_batch,
                           prefetch=args.prefetch) as svc:
            svc.request(args.samples)       # fill the pipeline
            t0 = time.time()
            served = 0
            for rid in range(args.requests):
                ss = svc.request(args.samples)
                served += len(ss)
            dt = time.time() - t0
            st = svc.stats()
            if args.linger > 0:             # let external scrapers collect
                print(f"lingering {args.linger:.0f}s for scrapes...",
                      flush=True)
                time.sleep(args.linger)
        shard_note = f", shards={args.shards}" if args.shards else ""
        print(f"served {args.requests} requests x {args.samples} samples "
              f"({served} total) in {dt:.2f}s — "
              f"{served/max(dt, 1e-9):,.0f} samples/s "
              f"[backend={args.backend}{shard_note}; "
              f"psi={st.psi():.2f}, draws={st.candidate_draws}, "
              f"rejects={st.cover_rejects}]",
              flush=True)
        from .. import obs
        if obs.enabled():
            reg = obs.get_registry()
            hist = reg.get("repro_serve_request_seconds")
            if hist is not None and hist.quantile(0.5) > 0:
                print(f"request latency: p50={hist.quantile(0.5)*1e3:.2f}ms "
                      f"p99={hist.quantile(0.99)*1e3:.2f}ms", flush=True)
    finally:
        if metrics is not None:
            metrics.stop()


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "samples"), default="lm")
    ap.add_argument("--arch", default="minitron-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    # samples mode
    ap.add_argument("--workload", default="UQ1")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--samples", type=int, default=4096)
    ap.add_argument("--backend", default="jax")
    ap.add_argument("--round-batch", type=int, default=8192)
    ap.add_argument("--plan", choices=("static", "adaptive"),
                    default="static",
                    help="round planner: 'adaptive' budgets candidates by "
                         "acceptance EMAs inside the device loop")
    ap.add_argument("--shards", type=int, default=0,
                    help="mesh size for the sharded engine (0 = unsharded)")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="prefetched sample batches in the serve queue")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics + /healthz on this port "
                         "(0 = ephemeral, URL printed at startup)")
    ap.add_argument("--linger", type=float, default=0.0,
                    help="keep the service + /metrics up this many seconds "
                         "after the request loop (for external scrapers)")
    args = ap.parse_args(argv)

    if args.mode == "samples":
        serve_samples(args)
        return

    from ..configs import get_config, get_smoke_config
    from ..models.serve import decode_step, init_cache
    from ..models.transformer import init_params

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(cfg, seed=args.seed)
    rng = np.random.default_rng(args.seed)

    B = args.slots
    cache = init_cache(cfg, B, args.max_len)
    dstep = jax.jit(lambda c, t, l: decode_step(params, cfg, c, t, l))

    # request queue: (request_id, prompt tokens)
    queue: List = [(i, rng.integers(4, cfg.vocab, rng.integers(2, 6)).tolist())
                   for i in range(args.requests)]
    slots = [None] * B          # (req_id, tokens emitted, remaining prompt)
    lengths = np.zeros(B, np.int64)
    current = np.full(B, 1, np.int64)   # BOS
    done: List = []
    t0 = time.time()
    steps = 0

    def refill():
        for b in range(B):
            if slots[b] is None and queue:
                rid, prompt = queue.pop(0)
                slots[b] = [rid, [], list(prompt)]
                lengths[b] = 0
                current[b] = 1

    refill()
    while any(s is not None for s in slots):
        toks = jnp.asarray(current.reshape(B, 1), jnp.int32)
        lens = jnp.asarray(lengths, jnp.int32)
        cache, logits = dstep(cache, toks, lens)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        steps += 1
        for b in range(B):
            if slots[b] is None:
                continue
            rid, out, prompt = slots[b]
            lengths[b] += 1
            if prompt:                       # still consuming the prompt
                current[b] = prompt.pop(0)
            else:
                out.append(int(nxt[b]))
                current[b] = int(nxt[b])
                if len(out) >= args.max_new or lengths[b] >= args.max_len - 1:
                    done.append((rid, out))
                    slots[b] = None
        refill()
    dt = time.time() - t0
    print(f"served {len(done)} requests, {steps} decode steps in {dt:.1f}s "
          f"({steps/max(dt,1e-9):.1f} steps/s, batch={B})", flush=True)
    for rid, out in sorted(done)[:4]:
        print(f"  req {rid}: {out[:10]}", flush=True)


if __name__ == "__main__":
    main()
