import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces (artifacts/<mesh>/<arch>__<shape>.json):
  * compiled.memory_analysis()  — per-device bytes (args/temp/output)
  * compiled.cost_analysis()    — per-device HLO FLOPs + bytes accessed
  * collective bytes parsed from the post-optimization HLO text, split by
    collective kind (all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute, including -start async forms)
  * the three §Roofline terms (compute / memory / collective, seconds) and
    MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (prefill/decode).

The FIRST TWO LINES of this file set XLA_FLAGS before any jax import —
jax locks the device count at first init.  Smoke tests and benchmarks do NOT
import this module, so they see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--out artifacts/]
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ASSIGNED_ARCHS, SHAPES, cell_runnable, get_config
from ..models import serve as mserve
from ..models.transformer import (ModelConfig, logical_axes, param_specs)
from ..train.optimizer import default_opt_for
from ..train.train_step import (TrainConfig, make_train_step,
                                train_state_logical_axes, train_state_specs)
from .mesh import make_production_mesh, set_mesh
from .sharding import (batch_is_sharded, batch_sharding, frontend_sharding,
                       replicated, tree_shardings)

# -- hardware constants (TPU v5e) -------------------------------------------
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link (per-chip effective, documented)

_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8}


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of every collective instruction, by kind."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # operands appear inside the call parens with their shapes
        paren = line[m.end() - 1:]
        total = 0.0
        for dt, dims in _SHAPE_RE.findall(paren):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0.0) + total
        out["count_" + kind] = out.get("count_" + kind, 0.0) + 1
    out["total"] = sum(v for k, v in out.items()
                       if not k.startswith("count_") and k != "total")
    return out


# ---------------------------------------------------------------------------
# Model FLOPs accounting (6·N_active·D)
# ---------------------------------------------------------------------------


def param_counts(cfg: ModelConfig) -> Tuple[float, float]:
    """(total, active) parameter counts (active discounts un-routed experts)."""
    specs = param_specs(cfg)
    total = float(sum(np.prod(s.shape) for s in specs.values()))
    embed = float(np.prod(specs["embed"].shape))
    expert = 0.0
    for k, s in specs.items():
        if ".moe_w_" in k or k.startswith("moe_w_") or "moe_w_" in k:
            expert += float(np.prod(s.shape))
    active = total - embed
    if cfg.n_experts:
        active -= expert * (1.0 - cfg.top_k / cfg.n_experts)
    return total, active


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    cell = SHAPES[shape_name]
    total, active = param_counts(cfg)
    if cell.kind == "train":
        tokens = cell.seq_len * cell.global_batch
        return 6.0 * active * tokens
    if cell.kind == "prefill":
        tokens = cell.seq_len * cell.global_batch
        return 2.0 * active * tokens
    # decode: one token per sequence
    return 2.0 * active * cell.global_batch


# ---------------------------------------------------------------------------
# Analytic per-device HBM traffic model (the roofline memory term)
#
# The census brackets HBM traffic ([hbm floor, post-fusion upper bound]) but
# cannot see TPU kernel fusion (per-tile flash/SSD traffic stays in VMEM).
# The structural model below counts what MUST cross HBM on the TPU target:
#   weights (gathered, per pass) - saved residuals - attention K/V chunk
#   re-reads - loss-head embedding/logits chunks - KV-cache reads -
#   optimizer state.  Formulas per cell kind.
# ---------------------------------------------------------------------------


def analytic_memory_bytes(cfg: ModelConfig, shape_name: str, mesh) -> float:
    cell = SHAPES[shape_name]
    n_chips = int(np.prod(mesh.devices.shape))
    tp = dict(mesh.shape).get("model", 1)
    dp = n_chips // tp
    B_loc = max(cell.global_batch // dp, 1)
    S = cell.seq_len
    total, active = param_counts(cfg)
    specs = param_specs(cfg)
    p_expert = sum(float(np.prod(sp.shape)) for k, sp in specs.items()
                   if "moe_w_" in k)
    p_dense = total - p_expert
    # per-device weight bytes read per pass (bf16): FSDP gathers the dense
    # weights to every device; experts stay EP-local
    w_pass = (p_dense + p_expert / tp) * 2.0

    if cell.kind == "train":
        passes = 3.0      # fwd + bwd (2x weight reads: dgrad + wgrad)
        opt = (total / n_chips) * (4 + 4 + 8)   # master r/w + moment traffic
        resid = cfg.n_layers * B_loc * (S / tp) * cfg.d_model * 2 * 2
        attn_kv = 0.0
        if cfg.n_heads:
            nq = max(S // cfg.q_chunk, 1)
            h_loc = max(cfg.n_heads / tp, 1)
            attn_kv = (cfg.n_layers * B_loc * S * h_loc * cfg.head_dim
                       * 2 * 2 * nq * 3)
        nc = max(S // cfg.loss_chunk, 1)
        loss = nc * (cfg.vocab / tp) * cfg.d_model * 2 * 2   # embed reads f+b
        loss += B_loc * S * (cfg.vocab / tp) * 4 * 2          # logits w+r
        return w_pass * passes + opt + resid + attn_kv + loss
    if cell.kind == "prefill":
        resid = cfg.n_layers * B_loc * (S / tp) * cfg.d_model * 2
        attn_kv = 0.0
        if cfg.n_heads:
            nq = max(S // cfg.q_chunk, 1)
            h_loc = max(cfg.n_heads / tp, 1)
            attn_kv = cfg.n_layers * B_loc * S * h_loc * cfg.head_dim * 2 * 2 * nq
        return w_pass + resid + attn_kv
    # decode: weights shard read once + full cache read/write
    cache = mserve.cache_specs(cfg, cell.global_batch, S)
    cache_bytes = sum(float(np.prod(sp.shape)) * 2 for sp in cache.values())
    return total * 2 / n_chips + cache_bytes / n_chips * 1.01


# ---------------------------------------------------------------------------
# input specs per (arch, shape)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    cell = SHAPES[shape_name]
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    dt = cfg.compute_dtype
    if cell.kind in ("train", "prefill"):
        toks = S
        batch = {}
        if cfg.frontend == "patch":
            toks = S - cfg.n_frontend_tokens
            batch["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), dt)
        elif cfg.frontend == "audio":
            batch["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), dt)
        batch["tokens"] = jax.ShapeDtypeStruct((B, toks), i32)
        if cell.kind == "train":
            batch["targets"] = jax.ShapeDtypeStruct((B, toks), i32)
        return batch
    # decode
    specs = {
        "cache": mserve.cache_specs(cfg, B, S),
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "lengths": jax.ShapeDtypeStruct((B,), i32),
    }
    return specs


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------


def lower_cell(arch: str, shape_name: str, mesh, *,
               compile_: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    cell = SHAPES[shape_name]
    t0 = time.perf_counter()
    bs = batch_is_sharded(mesh, cell.global_batch)

    if cell.kind == "train":
        n_micro = 4 if arch in ("mistral-large-123b", "arctic-480b",
                                "phi3.5-moe-42b-a6.6b", "zamba2-7b") else 1
        tc = TrainConfig(opt=default_opt_for(arch), n_microbatches=n_micro)
        step_fn = make_train_step(cfg, tc)
        state_specs = train_state_specs(cfg, tc)
        state_lax = train_state_logical_axes(cfg, tc)
        state_sh = {
            "step": replicated(mesh),
            "params": tree_shardings(mesh, state_specs["params"],
                                     state_lax["params"]),
            "opt": tree_shardings(mesh, state_specs["opt"], state_lax["opt"]),
        }
        batch = input_specs(cfg, shape_name)
        bsh = {k: (frontend_sharding(mesh, cell.global_batch)
                   if k == "frontend" else batch_sharding(mesh, cell.global_batch))
               for k in batch}
        fn = jax.jit(step_fn, in_shardings=(state_sh, bsh),
                     donate_argnums=(0,))
        with set_mesh(mesh):
            lowered = fn.lower(state_specs, batch)
    elif cell.kind == "prefill":
        def fn_prefill(params, batch):
            return mserve.prefill_step(params, cfg, batch)
        pspecs = param_specs(cfg)
        psh = tree_shardings(mesh, pspecs, logical_axes(cfg))
        batch = input_specs(cfg, shape_name)
        bsh = {k: (frontend_sharding(mesh, cell.global_batch)
                   if k == "frontend" else batch_sharding(mesh, cell.global_batch))
               for k in batch}
        fn = jax.jit(fn_prefill, in_shardings=(psh, bsh))
        with set_mesh(mesh):
            lowered = fn.lower(pspecs, batch)
    else:  # decode
        def fn_decode(params, cache, tokens, lengths):
            return mserve.decode_step(params, cfg, cache, tokens, lengths)
        pspecs = param_specs(cfg)
        psh = tree_shardings(mesh, pspecs, logical_axes(cfg))
        specs = input_specs(cfg, shape_name)
        csh = tree_shardings(mesh, specs["cache"],
                             mserve.cache_logical_axes(cfg, cell.global_batch,
                                                       cell.seq_len),
                             batch_sharded=bs)
        tsh = batch_sharding(mesh, cell.global_batch)
        fn = jax.jit(fn_decode, in_shardings=(psh, csh, tsh, tsh),
                     donate_argnums=(1,))
        with set_mesh(mesh):
            lowered = fn.lower(pspecs, specs["cache"], specs["tokens"],
                               specs["lengths"])

    res: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": list(mesh.devices.shape),
                           "mesh_axes": list(mesh.axis_names),
                           "lower_s": time.perf_counter() - t0}
    if not compile_:
        return res
    t1 = time.perf_counter()
    compiled = lowered.compile()
    res["compile_s"] = time.perf_counter() - t1

    ma = compiled.memory_analysis()
    res["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }
    per_dev = (ma.argument_size_in_bytes + ma.temp_size_in_bytes +
               ma.output_size_in_bytes - ma.alias_size_in_bytes)
    res["memory"]["per_device_total"] = int(per_dev)

    # raw cost_analysis counts loop bodies ONCE (a lax.scan over 88 layers is
    # under-counted 88x) — kept for reference; the census below re-derives
    # FLOPs/bytes/collectives from the HLO text with while-trip scaling.
    from .mesh import cost_analysis_dict
    ca = cost_analysis_dict(compiled)
    res["cost_raw"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes_accessed": float(ca.get("bytes accessed", 0.0))}

    text = compiled.as_text()
    from .hlo_census import census
    cs = census(text)
    flops = cs.flops
    bytes_accessed = cs.hbm_bytes
    res["cost"] = {"flops": flops, "bytes_accessed": bytes_accessed,
                   "bytes_upper_bound": cs.bytes_accessed}
    res["collectives"] = {**{k: v for k, v in cs.collective_bytes.items()},
                          **{"count_" + k: v
                             for k, v in cs.collective_counts.items()},
                          "total": cs.total_collective_bytes}
    res["while_trip_counts"] = cs.while_trip_counts

    n_chips = int(np.prod(mesh.devices.shape))
    mf = model_flops(cfg, shape_name)
    total, active = param_counts(cfg)
    # census numbers are per-device (the partitioned module)
    compute_t = flops / PEAK_FLOPS
    # memory term: analytic structural HBM traffic (what must cross HBM on
    # the TPU target); the census floor (>=8MiB tensors) and post-fusion
    # upper bound bracket it in the artifact (EXPERIMENTS.md §Roofline notes)
    analytic_bytes = analytic_memory_bytes(cfg, shape_name, mesh)
    memory_t = analytic_bytes / HBM_BW
    coll_t = cs.total_collective_bytes / ICI_BW
    dominant = max((("compute", compute_t), ("memory", memory_t),
                    ("collective", coll_t)), key=lambda kv: kv[1])[0]
    res["roofline"] = {
        "n_chips": n_chips,
        "compute_s": compute_t,
        "memory_s": memory_t,
        "memory_census_floor_s": cs.hbm_bytes / HBM_BW,
        "memory_upper_s": cs.bytes_accessed / HBM_BW,
        "analytic_bytes": analytic_bytes,
        "collective_s": coll_t,
        "dominant": dominant,
        "model_flops_total": mf,
        "model_flops_per_chip": mf / n_chips,
        "hlo_flops_per_chip": flops,
        "useful_flops_ratio": (mf / n_chips) / flops if flops else 0.0,
        "params_total": total,
        "params_active": active,
    }
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts")
    ap.add_argument("--lower-only", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod", make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)

    n_ok = n_skip = n_fail = 0
    for mesh_name, mesh in meshes:
        outdir = os.path.join(args.out, mesh_name)
        os.makedirs(outdir, exist_ok=True)
        for arch in archs:
            for shape in shapes:
                ok, why = cell_runnable(arch, shape)
                tag = f"{mesh_name}/{arch}__{shape}"
                path = os.path.join(outdir, f"{arch}__{shape}.json")
                if not ok:
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape,
                                   "skipped": why}, f, indent=1)
                    print(f"SKIP {tag}: {why}", flush=True)
                    n_skip += 1
                    continue
                try:
                    res = lower_cell(arch, shape, mesh,
                                     compile_=not args.lower_only)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                    r = res.get("roofline", {})
                    print(f"OK   {tag}: compile={res.get('compile_s', 0):.1f}s "
                          f"mem/dev={res.get('memory', {}).get('per_device_total', 0)/2**30:.2f}GiB "
                          f"dom={r.get('dominant', '?')}", flush=True)
                    n_ok += 1
                except Exception as e:
                    n_fail += 1
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape,
                                   "error": repr(e),
                                   "traceback": traceback.format_exc()}, f,
                                  indent=1)
                    print(f"FAIL {tag}: {e}", flush=True)
    print(f"dry-run done: ok={n_ok} skip={n_skip} fail={n_fail}", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
