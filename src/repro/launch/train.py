"""End-to-end training driver: union-of-joins sample stream → LM training.

The paper's loop in production form: build the workload (TPC-H-lite union of
joins), warm up the estimators, run Algorithm 1/2 as the data source, encode
tuples to token batches, and train under the fault-tolerant supervisor with
periodic checkpoints.

    PYTHONPATH=src python -m repro.launch.train --arch unionlm-100m \
        --workload UQ3 --steps 200 --batch 8 --seq 256 --warmup histogram

On this CPU container use the smoke configs / small scales; on a TPU mesh the
same driver runs under `jax.set_mesh(make_production_mesh())` with the
shardings from launch/sharding.py (see launch/dryrun.py for the lowering).
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.checkpointer import Checkpointer
from ..configs import get_config, get_smoke_config
from ..core.framework import estimate_union, warmup
from ..core.online import OnlineUnionSampler
from ..core.union_sampler import SetUnionSampler
from ..data.encode import TokenEncoder
from ..data.pipeline import UnionSamplePipeline
from ..data.workloads import WORKLOADS
from ..launch.ft import FTConfig, TrainSupervisor
from ..train.optimizer import OptConfig, default_opt_for
from ..train.train_step import (TrainConfig, init_train_state, make_train_step)


def build_pipeline(workload: str, scale: float, seed: int, batch: int,
                   seq: int, vocab: int, warm: str, online: bool):
    wl = WORKLOADS[workload](scale=scale, seed=seed)
    if online:
        sampler = OnlineUnionSampler(wl.cat, wl.joins, seed=seed)
    else:
        wr = warmup(wl.cat, wl.joins, method=warm,
                    **({"rw_max_walks": 4000} if warm == "random_walk" else {}))
        est = estimate_union(wr.oracle)
        sampler = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=seed)
    enc = TokenEncoder(sorted(wl.joins[0].output_attrs), vocab_size=vocab)
    return UnionSamplePipeline(sampler, enc, batch=batch, seq_len=seq)


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="unionlm-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for the arch")
    ap.add_argument("--workload", default="UQ3", choices=list(WORKLOADS))
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--warmup", default="histogram",
                    choices=["exact", "histogram", "random_walk"])
    ap.add_argument("--online", action="store_true",
                    help="use ONLINE-UNION (Algorithm 2) as the data source")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    pipe = build_pipeline(args.workload, args.scale, args.seed, args.batch,
                          args.seq, cfg.vocab, args.warmup, args.online)

    tc = TrainConfig(opt=OptConfig(kind=default_opt_for(args.arch).kind,
                                   lr=args.lr),
                     warmup_steps=max(args.steps // 20, 2),
                     total_steps=args.steps)
    state = init_train_state(cfg, tc, seed=args.seed)
    step_jit = jax.jit(make_train_step(cfg, tc))

    losses = []

    def step_fn(state, batch):
        toks, tgts = batch
        state, metrics = step_jit(state, {"tokens": jnp.asarray(toks),
                                          "targets": jnp.asarray(tgts)})
        losses.append(float(metrics["loss"]))
        s = int(state["step"])
        if s % args.log_every == 0 or s == 1:
            print(f"step {s:5d}  loss {losses[-1]:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"pipeline: {pipe.stats.tuples} tuples "
                  f"({pipe.stats.sample_seconds:.1f}s sampling)", flush=True)
        return state, metrics

    ckpt = Checkpointer(args.checkpoint_dir)
    sup = TrainSupervisor(step_fn, pipe.next_batch, ckpt,
                          FTConfig(checkpoint_every=args.checkpoint_every),
                          pipeline_state_fn=pipe.state_dict,
                          restore_pipeline_fn=pipe.load_state_dict)
    t0 = time.time()
    state = sup.run(state, args.steps)
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({dt/args.steps:.2f}s/step); loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"checkpoints={sup.stats.checkpoints}", flush=True)


if __name__ == "__main__":
    main()
