"""Production mesh construction.

Single pod: (16, 16) = ("data", "model") — 256 chips (one TPU v5e pod).
Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips.  The "pod"
axis composes with "data" for DP+FSDP so TP/EP ("model") traffic stays on
intra-pod ICI; cross-pod traffic is only gradient reduce-scatter (+ the
optional int8-compressed variant in train/grad_compress.py).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def data_axes(mesh) -> tuple:
    """Mesh axes used for DP/FSDP (includes 'pod' when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axes(mesh) -> tuple:
    return ("model",) if "model" in mesh.axis_names else ()
