"""Production mesh construction + JAX mesh-API compatibility shims.

Single pod: (16, 16) = ("data", "model") — 256 chips (one TPU v5e pod).
Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips.  The "pod"
axis composes with "data" for DP+FSDP so TP/EP ("model") traffic stays on
intra-pod ICI; cross-pod traffic is only gradient reduce-scatter (+ the
optional int8-compressed variant in train/grad_compress.py).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax import).

Compatibility: the pinned JAX (0.4.x) predates ``jax.sharding.AxisType``,
``jax.set_mesh``, ``jax.shard_map``, and ``jax.sharding.get_abstract_mesh``.
The shims below (:func:`make_mesh`, :func:`set_mesh`, :func:`ambient_mesh`,
``shard_map``) present the new-style surface on both API generations; all
mesh construction in src/ and tests/ routes through them so an API drift
fails in exactly one module with a clear error instead of scattering
``AttributeError: module 'jax.sharding' has no attribute ...`` across the
suite (see requirements-dev.txt for the version floor).
"""

from __future__ import annotations

from typing import Sequence

import jax

try:                                    # JAX >= 0.6
    from jax import shard_map           # noqa: F401  (re-exported shim)
except ImportError:                     # 0.4.x: the experimental module
    from jax.experimental.shard_map import shard_map  # noqa: F401

_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _axis_types_kw(n_axes: int) -> dict:
    """``axis_types=`` keyword for ``jax.make_mesh`` where supported.

    ``jax.sharding.AxisType`` only exists on newer JAX; the pinned 0.4.x
    ``make_mesh`` neither has the keyword nor needs it (all axes are Auto).
    """
    if _AXIS_TYPE is None:
        return {}
    return {"axis_types": (_AXIS_TYPE.Auto,) * n_axes}


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_types_kw(len(axes)))


def set_mesh(mesh):
    """Ambient-mesh context manager across JAX generations.

    Newer JAX: ``jax.set_mesh(mesh)``.  0.4.x: ``jax.sharding.Mesh`` is its
    own context manager (the pjit-era thread-resident mesh), so the mesh
    object itself is returned for use in a ``with`` statement.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def ambient_mesh():
    """The mesh set by :func:`set_mesh`, or ``None`` when there isn't one.

    Newer JAX reads the abstract mesh (``jax.sharding.get_abstract_mesh``);
    0.4.x reads the thread-resident physical mesh the ``with mesh:`` context
    installs.
    """
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        am = get_am()
        if am is None or not getattr(am, "axis_names", ()):
            return None
        return am
    from jax._src.mesh import thread_resources
    pm = thread_resources.env.physical_mesh
    return None if pm.empty else pm


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as one dict on both JAX generations
    (0.4.x returns a list with one dict per program)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Mesh axes used for DP/FSDP (includes 'pod' when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axes(mesh) -> tuple:
    return ("model",) if "model" in mesh.axis_names else ()
