"""Post-optimization HLO census: FLOPs / bytes / collectives with loop scaling.

``compiled.cost_analysis()`` counts every computation ONCE — a ``lax.scan``
over 88 layers is under-counted 88×.  This module re-derives the roofline
inputs directly from ``compiled.as_text()``:

* builds the computation call graph (ENTRY → fusions / while bodies / calls),
* extracts while-loop **trip counts** from the loop-condition's comparison
  constant (scan lowers to ``compare(iv, constant(N))``),
* counts **dot FLOPs** (2 × prod(result dims) × prod(contracting dims)) and
  **convolution FLOPs**, scaled by the product of enclosing trip counts,
* counts **bytes accessed** per instruction (operand + result buffer sizes,
  fusion interiors excluded — matching XLA's post-fusion metric),
* sums **collective operand bytes** by kind (all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute, async -start forms
  included), also trip-scaled.

This is per-device (the partitioned module).  Elementwise FLOPs are not
counted (MXU dots dominate every cell here; documented in EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|branch_computations|to_apply)="
                           r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")
_OPND_RE = re.compile(r"%([\w.\-]+)")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota"}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_bytes(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


VMEM_THRESHOLD = 8 * 2**20   # tensors >= 8 MiB cannot stay VMEM-resident


@dataclasses.dataclass
class CensusResult:
    flops: float
    bytes_accessed: float        # upper bound: all post-fusion instruction I/O
    hbm_bytes: float             # floor: only tensors >= VMEM_THRESHOLD
    collective_bytes: Dict[str, float]
    collective_counts: Dict[str, float]
    while_trip_counts: Dict[str, int]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def parse_computations(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        mc = _COMP_RE.match(line)
        if mc and "{" in line and not stripped.startswith("%param"):
            cur = Computation(mc.group(1), [])
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        md = _DEF_RE.match(stripped)
        if not md:
            continue
        name, rhs = md.groups()
        # result type: either "(tuple, ...)" (match parens) or "dtype[...]{...}"
        rhs = rhs.strip()
        if rhs.startswith("("):
            depth = 0
            end = 0
            for i, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i + 1
                        break
            result_type = rhs[:end]
            rest = rhs[end:].lstrip()
        else:
            sp = rhs.find(" ")
            result_type = rhs if sp < 0 else rhs[:sp]
            rest = "" if sp < 0 else rhs[sp + 1:].lstrip()
        # opcode: identifier up to the first "(" in the remainder
        mo = re.match(r"([\w\-]+)\(", rest)
        opcode = mo.group(1) if mo else rest.split(" ")[0].split("(")[0]
        cur.instrs.append(Instr(name, result_type, opcode, stripped))
    return comps, entry


def _trip_count_of(cond: Computation) -> int:
    """Largest s32 constant in the loop condition ≈ trip count."""
    best = 1
    for ins in cond.instrs:
        for m in re.finditer(r"constant\((-?\d+)\)", ins.line):
            v = int(m.group(1))
            if v > best:
                best = v
    return best


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    out_elems = 1
    m = _SHAPE_RE.search(ins.result_type)
    if not m:
        return 0.0
    for d in m.group(2).split(","):
        if d:
            out_elems *= int(d)
    # contracting dims sizes from the lhs operand
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    ops = _OPND_RE.findall(ins.line.split("(", 1)[1])
    if not ops:
        return 0.0
    lhs_type = shapes.get(ops[0], "")
    ml = _SHAPE_RE.search(lhs_type)
    if not ml:
        return 0.0
    lhs_dims = [int(d) for d in ml.group(2).split(",") if d]
    contract = 1
    if mc:
        for i in mc.group(1).split(","):
            if i and int(i) < len(lhs_dims):
                contract *= lhs_dims[int(i)]
    return 2.0 * out_elems * contract


def census(text: str) -> CensusResult:
    comps, entry = parse_computations(text)
    shapes: Dict[str, str] = {}
    for c in comps.values():
        for ins in c.instrs:
            shapes[ins.name] = ins.result_type

    # call graph attributes per instruction
    trip_counts: Dict[str, int] = {}
    memo: Dict[str, Tuple[float, float, Dict[str, float], Dict[str, float]]] = {}

    def eval_comp(name: str):
        if name in memo:
            return memo[name]
        memo[name] = (0.0, 0.0, 0.0, {}, {})  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        flops = 0.0
        byts = 0.0
        hbm = 0.0
        coll: Dict[str, float] = {}
        cnt: Dict[str, float] = {}

        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mcnd = re.search(r"condition=%?([\w.\-]+)", ins.line)
                trips = 1
                if mcnd and mcnd.group(1) in comps:
                    trips = _trip_count_of(comps[mcnd.group(1)])
                    trip_counts[ins.name] = trips
                if mb:
                    f, b, h, cl, cc = eval_comp(mb.group(1))
                    flops += f * trips
                    byts += b * trips
                    hbm += h * trips
                    for k, v in cl.items():
                        coll[k] = coll.get(k, 0.0) + v * trips
                    for k, v in cc.items():
                        cnt[k] = cnt.get(k, 0.0) + v * trips
                continue
            if op in ("fusion", "call", "map", "reduce", "reduce-window",
                      "scatter", "sort", "custom-call", "conditional"):
                # interior computations: fusion interiors are already
                # reflected at the call site (operands+result); dots never
                # appear inside CPU loop fusions, but count callee dots for
                # call/conditional to be safe.
                if op in ("call", "conditional"):
                    mcal = _CALL_ATTR_RE.search(ins.line)
                    if mcal:
                        for callee in re.split(r",\s*%?", mcal.group(1)):
                            f, b, h, cl, cc = eval_comp(callee)
                            flops += f
                            byts += b
                            hbm += h
                            for k, v in cl.items():
                                coll[k] = coll.get(k, 0.0) + v
                            for k, v in cc.items():
                                cnt[k] = cnt.get(k, 0.0) + v
            if op in ("dot", "dot-general"):
                flops += _dot_flops(ins, shapes)
            if op == "convolution":
                # conservative: 2 * out_elems * (contracted window) — parse
                # kernel operand elements / out-channel factor
                out_b = _first_shape_bytes(ins.result_type)
                ops = _OPND_RE.findall(ins.line.split("(", 1)[1])
                ker = shapes.get(ops[1], "") if len(ops) > 1 else ""
                ker_elems = 0
                mk = _SHAPE_RE.search(ker)
                if mk:
                    ker_elems = 1
                    for d in mk.group(2).split(","):
                        if d:
                            ker_elems *= int(d)
                flops += 2.0 * out_b * max(ker_elems, 1) / 4.0  # rough

            base = op.replace("-start", "")
            if base in _COLL_KINDS:
                if op.endswith("-done"):
                    continue
                opnds = _OPND_RE.findall(ins.line.split("(", 1)[1]) if "(" in ins.line else []
                ob = sum(_shape_bytes(shapes.get(o, "")) for o in opnds)
                if ob == 0:
                    ob = _shape_bytes(ins.result_type)
                coll[base] = coll.get(base, 0.0) + ob
                cnt[base] = cnt.get(base, 0.0) + 1

            if op in _SKIP_BYTES_OPS or op == "while":
                continue
            rb = _shape_bytes(ins.result_type)
            opnds = _OPND_RE.findall(ins.line.split("(", 1)[1]) if "(" in ins.line else []
            ob = sum(_shape_bytes(shapes.get(o, "")) for o in opnds
                     if shapes.get(o))
            byts += rb + ob
            # HBM floor: only tensors too big for VMEM residency count —
            # per-tile flash/SSD traffic stays on-chip in the TPU kernels
            if rb >= VMEM_THRESHOLD:
                hbm += rb
            for o in opnds:
                osz = _shape_bytes(shapes.get(o, ""))
                if osz >= VMEM_THRESHOLD:
                    hbm += osz

        memo[name] = (flops, byts, hbm, coll, cnt)
        return memo[name]

    if entry is None:
        # fall back: evaluate the largest computation
        entry = max(comps, key=lambda k: len(comps[k].instrs)) if comps else ""
    f, b, h, cl, cc = eval_comp(entry)
    return CensusResult(f, b, h, cl, cc, trip_counts)


def top_contributors(text: str, k: int = 20):
    """Heaviest instructions by trip-scaled bytes and flops (perf profiling).

    Returns (by_bytes, by_flops): lists of (scaled_value, trips, instr line).
    """
    comps, entry = parse_computations(text)
    shapes: Dict[str, str] = {}
    for c in comps.values():
        for ins in c.instrs:
            shapes[ins.name] = ins.result_type

    # multiplier per computation: product of enclosing while trip counts
    mult: Dict[str, int] = {}

    def mark(name: str, m: int) -> None:
        if name not in comps or mult.get(name, 0) >= m:
            return
        mult[name] = m
        for ins in comps[name].instrs:
            trips = 1
            if ins.opcode == "while":
                mcnd = re.search(r"condition=%?([\w.\-]+)", ins.line)
                if mcnd and mcnd.group(1) in comps:
                    trips = _trip_count_of(comps[mcnd.group(1)])
            for attr in _CALL_ATTR_RE.finditer(ins.line):
                for callee in re.split(r",\s*%?", attr.group(1)):
                    mark(callee, m * trips)

    if entry:
        mark(entry, 1)

    by_bytes, by_flops = [], []
    for cname, comp in comps.items():
        m = mult.get(cname, 1)
        for ins in comp.instrs:
            if ins.opcode in _SKIP_BYTES_OPS or ins.opcode == "while":
                continue
            rb = _shape_bytes(ins.result_type)
            opnds = (_OPND_RE.findall(ins.line.split("(", 1)[1])
                     if "(" in ins.line else [])
            ob = sum(_shape_bytes(shapes.get(o, "")) for o in opnds
                     if shapes.get(o))
            by_bytes.append(((rb + ob) * m, m, ins.line[:180]))
            if ins.opcode in ("dot", "dot-general"):
                by_flops.append((_dot_flops(ins, shapes) * m, m, ins.line[:180]))
    by_bytes.sort(key=lambda t: -t[0])
    by_flops.sort(key=lambda t: -t[0])
    return by_bytes[:k], by_flops[:k]
