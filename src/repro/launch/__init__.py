"""launch subpackage."""
