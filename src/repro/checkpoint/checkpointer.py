"""Lightweight sharded checkpointing with atomic commit + elastic restore.

Layout::

    <dir>/step_000123/
        manifest.json        # tree structure, shapes, dtypes, step, hashes
        <leaf-key>.npy       # one file per pytree leaf (host-local shard in
                             # multi-host deployments; full array here)
        pipeline.json        # sampler/pipeline state (RNG, stats)
    <dir>/LATEST             # atomic pointer (written via rename)

* **atomic**: a checkpoint is staged in ``step_X.tmp`` and ``os.rename``d —
  readers never observe partial state; LATEST is a one-line pointer file
  updated with the same rename trick.
* **elastic restore**: leaves are loaded host-side and ``jax.device_put`` to
  whatever shardings the *target* mesh prescribes — restoring a 256-chip
  checkpoint onto 512 chips (or CPU tests) needs no conversion step.
* **integrity**: per-leaf xxhash-style content hashes in the manifest,
  verified on restore.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, Any]) -> Any:
    tree: Dict[str, Any] = {}
    for k, v in flat.items():
        parts = k.split("/")
        cur = tree
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return tree


def _hash(a: np.ndarray) -> str:
    import hashlib
    return hashlib.blake2b(a.tobytes(), digest_size=8).hexdigest()


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any,
             pipeline_state: Optional[Dict[str, Any]] = None) -> str:
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(state)
        manifest = {"step": step, "leaves": {}}
        for k, v in flat.items():
            fn = k.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), v)
            manifest["leaves"][k] = {"file": fn, "shape": list(v.shape),
                                     "dtype": str(v.dtype), "hash": _hash(v)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if pipeline_state is not None:
            with open(os.path.join(tmp, "pipeline.json"), "w") as f:
                json.dump(_jsonify(pipeline_state), f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                       # atomic commit
        self._update_latest(name)
        self._gc()
        return final

    def _update_latest(self, name: str) -> None:
        tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(tmp, "w") as f:
            f.write(name)
        os.rename(tmp, os.path.join(self.dir, "LATEST"))

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip().split("_")[1])

    def restore(self, step: Optional[int] = None,
                shardings: Optional[Any] = None,
                verify: bool = True) -> Tuple[Any, Optional[Dict[str, Any]]]:
        """Load a checkpoint; device_put with target shardings (elastic)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError("no checkpoint found")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for k, info in manifest["leaves"].items():
            v = np.load(os.path.join(d, info["file"]))
            if verify and _hash(v) != info["hash"]:
                raise IOError(f"checkpoint corruption in leaf {k!r}")
            flat[k] = v
        tree = _unflatten(flat)
        if shardings is not None:
            flat_sh = _flatten_obj(shardings)
            tree = _unflatten({
                k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
                for k, v in flat.items()})
        pp = None
        pj = os.path.join(d, "pipeline.json")
        if os.path.exists(pj):
            with open(pj) as f:
                pp = json.load(f)
        return tree, pp


def _flatten_obj(tree: Any, prefix: str = "") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten_obj(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _jsonify(x: Any) -> Any:
    if isinstance(x, dict):
        return {str(k): _jsonify(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonify(v) for v in x]
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, np.ndarray):
        return x.tolist()
    return x
