"""checkpoint subpackage."""
