"""Serving front-end: streaming union-sample service.

:class:`SampleService` wraps any union sampling engine (host, fused device,
mesh-sharded) with a prefetched sample queue and request batching; the serve
CLI (``python -m repro.launch.serve --mode samples``) and
``examples/long_context_serving.py`` route through it.

The serve tier is instrumented (DESIGN.md §10): request-latency histograms
with scrape-time p50/p99 gauges, queue-depth/prefetch-occupancy gauges, and
per-replica merged ``SamplerStats``, all in the ``repro_serve_*`` namespace.
``python -m repro.launch.serve --mode samples --metrics-port P`` exposes
them at ``http://127.0.0.1:P/metrics`` (Prometheus text exposition) with a
``/healthz`` liveness probe; ``REPRO_OBS=off`` switches it all off.
"""

from .service import SampleService

__all__ = ["SampleService"]
