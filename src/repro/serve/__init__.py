"""Serving front-end: streaming union-sample service.

:class:`SampleService` wraps any union sampling engine (host, fused device,
mesh-sharded) with a prefetched sample queue and request batching; the serve
CLI (``python -m repro.launch.serve --mode samples``) and
``examples/long_context_serving.py`` route through it.
"""

from .service import SampleService

__all__ = ["SampleService"]
