"""serve subpackage."""
