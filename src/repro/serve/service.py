"""Streaming union-sample service — the serving front-end over the engines.

:class:`SampleService` turns any union sampler (host, fused device, or
mesh-sharded — anything with ``sample(n) -> SampleSet``) into a streaming
source for serving traffic:

* **prefetched sample queue** — one producer thread per engine keeps a
  bounded queue of fixed-size sample batches warm, so request latency is a
  queue pop, not an engine round.  Because probe-mode samples are i.i.d.
  ``1/|U|`` draws, any contiguous slice of the prefetched stream is itself a
  valid uniform sample — slicing batches across requests is free.
* **request batching** — concurrent ``request(n)`` calls drain the shared
  stream under a cursor lock; the engine only ever runs its own
  (device-optimal) ``batch``-sized rounds regardless of per-request sizes,
  which is exactly what the fused/sharded engines' surplus banking is built
  for.
* **replicas** — pass several engines (e.g. seed-split replicas, one per
  host or per mesh) and their streams interleave into one queue; per-engine
  cost accounting combines with :meth:`SamplerStats.merge`.
* **telemetry** — every ``request()`` lands in the
  ``repro_serve_request_seconds`` latency histogram (p50/p99 gauges derived
  at scrape time), with request/sample counters, a queue-depth /
  prefetch-occupancy gauge, and per-replica merged ``SamplerStats`` gauges;
  ``python -m repro.launch.serve --mode samples --metrics-port P`` exposes
  all of it on ``http://127.0.0.1:P/metrics`` (Prometheus text) next to a
  ``/healthz`` liveness probe.  ``REPRO_OBS=off`` disables it.

``python -m repro.launch.serve --mode samples`` and
``examples/long_context_serving.py`` route through this class.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from .. import obs
from ..core.union_sampler import SampleSet, SamplerStats


class SampleService:
    """Prefetching, request-batching facade over one or more sample engines."""

    def __init__(self, samplers, batch: int = 4096, prefetch: int = 2,
                 registry=None):
        if not isinstance(samplers, (list, tuple)):
            samplers = [samplers]
        if not samplers:
            raise ValueError("SampleService needs at least one engine")
        self.samplers = list(samplers)
        self.batch = int(batch)
        self.prefetch = int(prefetch)
        self.attrs = list(self.samplers[0].attrs)
        self._queue: "queue.Queue[SampleSet]" = queue.Queue(
            maxsize=max(self.prefetch, 1))
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._threads: List[threading.Thread] = []
        self._cursor: Optional[SampleSet] = None    # partially drained batch
        self._cursor_pos = 0
        self._lock = threading.Lock()               # request serialisation
        self.served = 0
        self._registry = registry                   # None ⇒ global registry
        self._obs_m: Optional[Dict] = None
        self._collector = None

    # ------------------------------------------------------------- lifecycle
    def start(self) -> "SampleService":
        """Spawn the producer threads.  A service is single-use: once
        stopped it cannot restart (a producer may still be inside a long
        engine round when ``stop`` returns, and the engines are not
        thread-safe — build a fresh service instead)."""
        if self._threads:
            return self
        if self._stop.is_set():
            raise RuntimeError("SampleService is single-use: build a new "
                               "service instead of restarting a stopped one")
        if obs.enabled():
            self._obs_handles()
        for i, s in enumerate(self.samplers):
            t = threading.Thread(target=self._produce, args=(s,),
                                 name=f"sample-producer-{i}", daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def stop(self) -> None:
        self._stop.set()
        # unblock producers waiting on a full queue
        while True:
            try:
                self._queue.get_nowait()
            except queue.Empty:
                break
        for t in self._threads:
            t.join(timeout=5)
        self._threads = []
        if self._collector is not None:     # single-use: stop scraping us
            reg, fn = self._collector
            fn()        # final quantile/engine refresh (producers quiesced)
            reg.remove_collector(fn)
            self._collector = None

    def __enter__(self) -> "SampleService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -------------------------------------------------------------- producer
    def _produce(self, sampler) -> None:
        """Keep the queue warm with ``batch``-sized sample sets.

        Engines exposing ``sample_async`` get double-buffered round
        dispatch: batch *k+1* is launched before batch *k* is drained, so
        the host-side assembly (fetch, shuffle, fingerprint) of one batch
        hides behind the device compute of the next — the fused device
        loop's top-up latency never stalls the queue.  Plain engines fall
        back to the synchronous path.
        """
        dispatch = getattr(sampler, "sample_async", None)
        pending = None
        while not self._stop.is_set():
            try:
                if dispatch is None:
                    ss = sampler.sample(self.batch)
                else:
                    if pending is None:
                        pending = dispatch(self.batch)
                    nxt = dispatch(self.batch)     # in flight while we drain
                    ss = pending.result()
                    pending = nxt
            except BaseException as e:        # surfaced on the next request
                self._error = e
                self._stop.set()
                return
            while not self._stop.is_set():
                try:
                    self._queue.put(ss, timeout=0.1)
                    break
                except queue.Full:
                    continue

    # -------------------------------------------------------------- consumer
    def _next_batch(self, timeout: float) -> SampleSet:
        while True:
            if self._error is not None:
                raise RuntimeError("sample producer failed") from self._error
            try:
                return self._queue.get(timeout=min(timeout, 0.2))
            except queue.Empty:
                timeout -= 0.2
                if timeout <= 0:
                    raise TimeoutError(
                        "SampleService.request timed out (engine too slow "
                        "for the requested size, or service not started)")

    # ------------------------------------------------------------- telemetry
    def _obs_handles(self) -> Dict:
        """Serve-tier metric handles (get-or-create in the registry); the
        queue-depth gauge and p50/p99 + per-replica stat gauges refresh at
        scrape time via a registry collector (removed again on stop)."""
        if self._obs_m is None:
            reg = (self._registry if self._registry is not None
                   else obs.get_registry())
            m = {
                "latency": reg.histogram(
                    "repro_serve_request_seconds",
                    "end-to-end SampleService.request latency"),
                "requests": reg.counter(
                    "repro_serve_requests_total",
                    "sample requests served"),
                "samples": reg.counter(
                    "repro_serve_samples_total",
                    "union samples handed out by the serve tier"),
                "queue": reg.gauge(
                    "repro_serve_queue_depth",
                    "prefetch queue occupancy (batches ready to serve)"),
                "capacity": reg.gauge(
                    "repro_serve_prefetch_capacity",
                    "prefetch queue capacity (batches)"),
                "p50": reg.gauge(
                    "repro_serve_request_seconds_p50",
                    "median request latency (bucket-interpolated)"),
                "p99": reg.gauge(
                    "repro_serve_request_seconds_p99",
                    "p99 request latency (bucket-interpolated)"),
                "engine": reg.gauge(
                    "repro_serve_engine_stat",
                    "per-replica engine SamplerStats fields",
                    labelnames=("replica", "field")),
            }
            m["queue"].set_function(self._queue.qsize)
            m["capacity"].set(self._queue.maxsize)

            def collect():
                m["p50"].set(m["latency"].quantile(0.5))
                m["p99"].set(m["latency"].quantile(0.99))
                for i, s in enumerate(self.samplers):
                    for field, v in s.stats.as_dict().items():
                        m["engine"].labels(str(i), field).set(v)
                    # derived waste ratio: candidate draws per emitted sample
                    m["engine"].labels(str(i), "psi").set(s.stats.psi())

            reg.add_collector(collect)
            self._collector = (reg, collect)
            self._obs_m = m
        return self._obs_m

    def request(self, n: int, timeout: float = 120.0) -> SampleSet:
        """Blocking request for ``n`` uniform union samples."""
        if not self._threads:
            raise RuntimeError("SampleService not started (use start() or a "
                               "with-block)")
        t0 = time.perf_counter() if obs.enabled() else None
        if n <= 0:
            from ..core.union_sampler import empty_sample_set
            return empty_sample_set(self.attrs, self.stats())
        parts: List[SampleSet] = []
        got = 0
        with self._lock:
            while got < n:
                if self._cursor is None:
                    self._cursor = self._next_batch(timeout)
                    self._cursor_pos = 0
                cur, lo = self._cursor, self._cursor_pos
                hi = min(lo + n - got, len(cur))
                parts.append(SampleSet(
                    cur.attrs, {a: c[lo:hi] for a, c in cur.rows.items()},
                    cur.home[lo:hi], cur.fingerprint[lo:hi], cur.stats))
                got += hi - lo
                if hi >= len(cur):
                    self._cursor = None
                else:
                    self._cursor_pos = hi
            self.served += got
        rows = {a: np.concatenate([p.rows[a] for p in parts])
                for a in self.attrs}
        home = np.concatenate([p.home for p in parts])
        fp = np.concatenate([p.fingerprint for p in parts])
        if t0 is not None:
            m = self._obs_handles()
            m["latency"].observe(time.perf_counter() - t0)
            m["requests"].inc()
            m["samples"].inc(got)
        return SampleSet(self.attrs, rows, home, fp, self.stats())

    def stats(self) -> SamplerStats:
        """Merged cost accounting across all engines (associative merge)."""
        out = SamplerStats()
        for s in self.samplers:
            out.merge(s.stats)
        return out
