"""mamba2-780m [ssm] — SSD state-space duality [arXiv:2405.21060; unverified]."""

from ..models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="mamba2", n_layers=48, d_model=1536,
        n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0, vocab=50280,
        ssm_state=128, ssm_headdim=64)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m-smoke", family="mamba2", n_layers=2, d_model=64,
        n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0, vocab=512,
        ssm_state=16, ssm_headdim=16, ssd_chunk=16)
