"""unionlm-100m — the paper-native config: ~100M-param LM trained end-to-end
on the union-of-joins sample stream (examples/train_lm_on_union.py)."""

from ..models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="unionlm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, vocab=8192,
        q_chunk=128, kv_chunk=256)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="unionlm-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        q_chunk=32, kv_chunk=32)
