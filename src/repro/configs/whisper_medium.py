"""whisper-medium [audio] — enc-dec; conv/audio frontend STUBBED [arXiv:2212.04356; unverified].

Per the assignment, the modality frontend is a stub: ``input_specs()``
supplies precomputed 1500-frame embeddings (30 s of audio after the conv
stem); the transformer backbone (24L enc + 24L dec, d=1024) is real.
Decoder uses RoPE (framework-level long-context extension; the released
checkpoint's learned 448-position embedding does not constrain the backbone).
"""

from ..models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="encdec", n_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096, vocab=51865,
        encdec=True, n_enc_layers=24, frontend="audio", n_frontend_tokens=1500)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-smoke", family="encdec", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=512,
        encdec=True, n_enc_layers=2, frontend="audio", n_frontend_tokens=16,
        q_chunk=16, kv_chunk=16)
