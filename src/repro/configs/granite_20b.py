"""granite-20b [dense] — llama-arch MQA code model [arXiv:2405.04324; hf]."""

from ..models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b", family="dense", n_layers=52, d_model=6144,
        n_heads=48, n_kv_heads=1, head_dim=128, d_ff=24576, vocab=49152)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab=512,
        q_chunk=32, kv_chunk=32)
