"""arctic-480b [moe] — 128 experts top-2 + dense residual [hf:Snowflake/snowflake-arctic-base; hf]."""

from ..models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe", n_layers=35, d_model=7168,
        n_heads=56, n_kv_heads=8, head_dim=128, d_ff=4864, vocab=32000,
        n_experts=128, top_k=2, moe_dff=4864, dense_residual=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        n_experts=8, top_k=2, moe_dff=64, dense_residual=True,
        moe_capacity_factor=8.0, q_chunk=32, kv_chunk=32)
