"""minitron-8b [dense] — pruned nemotron [arXiv:2407.14679; hf]."""

from ..models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=16384, vocab=256000)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        q_chunk=32, kv_chunk=32)
