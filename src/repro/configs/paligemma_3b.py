"""paligemma-3b [vlm] — SigLIP patch frontend STUBBED + gemma decoder [arXiv:2407.07726; hf].

Per the assignment, the vision frontend is a stub: ``input_specs()`` supplies
256 precomputed patch embeddings which prepend the text tokens; attention is
bidirectional over the patch prefix (prefix-LM) and causal elsewhere.
"""

from ..models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", family="vlm", n_layers=18, d_model=2048,
        n_heads=8, n_kv_heads=1, head_dim=256, d_ff=16384, vocab=257216,
        frontend="patch", n_frontend_tokens=256, prefix_len=256,
        embed_scale=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab=512,
        frontend="patch", n_frontend_tokens=16, prefix_len=16,
        embed_scale=True, q_chunk=16, kv_chunk=16)
