"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks [arXiv:2411.15242; unverified].

81 layers = 13 groups of (5 mamba + 1 shared-weight attention application)
+ 3 trailing mamba layers.  The attention+MLP block weights are SHARED across
all 13 applications (zamba's hallmark); a learned per-group gate mixes the
shared block's output back into the backbone.
"""

from ..models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="zamba2", n_layers=81, d_model=3584,
        n_heads=32, n_kv_heads=32, head_dim=112, d_ff=14336, vocab=32000,
        ssm_state=64, ssm_headdim=64, mamba_per_attn=5)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-smoke", family="zamba2", n_layers=7, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=512,
        ssm_state=16, ssm_headdim=16, mamba_per_attn=2, ssd_chunk=16,
        q_chunk=32, kv_chunk=32)
