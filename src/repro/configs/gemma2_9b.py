"""gemma2-9b [dense] — local+global alternating, logit softcap [arXiv:2408.00118; hf]."""

from ..models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b", family="gemma2", n_layers=42, d_model=3584,
        n_heads=16, n_kv_heads=8, head_dim=256, d_ff=14336, vocab=256000,
        attn_softcap=50.0, final_softcap=30.0, window=4096,
        embed_scale=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b-smoke", family="gemma2", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        attn_softcap=50.0, final_softcap=30.0, window=32, embed_scale=True,
        q_chunk=32, kv_chunk=32)
