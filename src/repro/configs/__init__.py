"""Architecture registry: --arch <id> -> ModelConfig (+ reduced smoke config).

The 10 assigned architectures + the paper-native unionlm config.  Shape cells
(train_4k / prefill_32k / decode_32k / long_500k) are defined here too so the
dry-run, benchmarks, and tests agree on one source of truth.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from ..models.transformer import ModelConfig
from . import (arctic_480b, gemma2_9b, granite_20b, mamba2_780m,
               minitron_8b, mistral_large_123b, paligemma_3b, phi35_moe,
               unionlm_100m, whisper_medium, zamba2_7b)

_MODULES = {
    "minitron-8b": minitron_8b,
    "granite-20b": granite_20b,
    "gemma2-9b": gemma2_9b,
    "mistral-large-123b": mistral_large_123b,
    "mamba2-780m": mamba2_780m,
    "zamba2-7b": zamba2_7b,
    "whisper-medium": whisper_medium,
    "phi3.5-moe-42b-a6.6b": phi35_moe,
    "arctic-480b": arctic_480b,
    "paligemma-3b": paligemma_3b,
    "unionlm-100m": unionlm_100m,
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "unionlm-100m"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke_config()


def cell_runnable(arch: str, shape: str) -> Tuple[bool, str]:
    """Skip policy (DESIGN.md §4): long_500k only for sub-quadratic archs."""
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: every layer would need the "
                       "full 500K dense-attention KV (documented skip)")
    return True, ""


def all_cells() -> List[Tuple[str, str, bool, str]]:
    out = []
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            ok, why = cell_runnable(arch, shape)
            out.append((arch, shape, ok, why))
    return out
