"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct; hf]."""

from ..models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=6400, vocab=32064,
        n_experts=16, top_k=2, moe_dff=6400)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        n_experts=4, top_k=2, moe_dff=128, moe_capacity_factor=8.0,
        q_chunk=32, kv_chunk=32)
