"""mistral-large-123b [dense] — [hf:mistralai/Mistral-Large-Instruct-2407; unverified]."""

from ..models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b", family="dense", n_layers=88, d_model=12288,
        n_heads=96, n_kv_heads=8, head_dim=128, d_ff=28672, vocab=32768)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b-smoke", family="dense", n_layers=3,
        d_model=96, n_heads=6, n_kv_heads=2, head_dim=16, d_ff=192, vocab=512,
        q_chunk=32, kv_chunk=32)
