"""§5.2 splitting + §8.1 standard templates.

The HISTOGRAM-BASED overlap estimator needs every join in ``Δ`` rewritten as an
*equi-length chain of 2-attribute sub-relations over the same template* so the
per-position degree statistics are comparable across joins (§5.1).  A template
is an ordering ``A_1 … A_k`` of the shared output attributes; join ``J`` is
split into pairs ``S_i = π_{A_i,A_{i+1}}(R)`` where ``R`` is a base relation of
``J`` containing both attributes.  Edges between consecutive pairs drawn from
the *same* base relation are **fake joins** (row identity ⇒ multiplier 1);
edges between pairs from different relations are real (multiplier = max/avg
degree of the shared attribute, Theorem 4).

Template heuristic (§8.1 / extended version): keep attributes that co-occur in
base relations adjacent — build the attribute co-occurrence graph and grow a
path greedily by strongest co-occurrence with the current endpoint (this
minimises the total pairwise distance objective the paper formulates).  When a
pair is not co-located in any base relation of some join, the sound fallback
multiplies the max degrees along the shortest connecting path in the join
(documented in DESIGN.md §7) — every multiplier stays an upper bound.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .index import Catalog
from .joins import JoinSpec


@dataclasses.dataclass
class SplitPair:
    attrs: Tuple[str, str]
    source_alias: Optional[str]        # None => not co-located (path fallback)
    fake_edge_to_prev: bool            # same source as previous pair?
    path_aliases: Tuple[str, ...] = () # fallback path (for multiplier product)


@dataclasses.dataclass
class SplitPlan:
    join: JoinSpec
    template: Tuple[str, ...]
    pairs: List[SplitPair]


def _cooccurrence(joins: Sequence[JoinSpec]) -> Dict[Tuple[str, str], int]:
    co: Dict[Tuple[str, str], int] = {}
    for j in joins:
        for n in j.nodes:
            attrs = n.relation.attrs
            for i, a in enumerate(attrs):
                for b in attrs[i + 1:]:
                    k = (a, b) if a < b else (b, a)
                    co[k] = co.get(k, 0) + 1
    return co


def build_template(joins: Sequence[JoinSpec]) -> Tuple[str, ...]:
    """Greedy max-co-occurrence path over the shared output schema."""
    attrs = list(joins[0].output_attrs)
    co = _cooccurrence(joins)

    def w(a: str, b: str) -> int:
        return co.get((a, b) if a < b else (b, a), 0)

    # start from the endpoint of the strongest co-occurring pair
    best_pair = max(
        ((a, b) for i, a in enumerate(attrs) for b in attrs[i + 1:]),
        key=lambda p: w(*p),
        default=None,
    )
    if best_pair is None:
        return tuple(attrs)
    order = [best_pair[0], best_pair[1]]
    remaining = [a for a in attrs if a not in order]
    while remaining:
        tail = order[-1]
        head = order[0]
        best_tail = max(remaining, key=lambda a: w(tail, a))
        best_head = max(remaining, key=lambda a: w(head, a))
        if w(tail, best_tail) >= w(head, best_head):
            order.append(best_tail)
            remaining.remove(best_tail)
        else:
            order.insert(0, best_head)
            remaining.remove(best_head)
    return tuple(order)


def _path_between(spec: JoinSpec, a: str, b: str) -> Tuple[str, ...]:
    """Aliases on the tree path between a relation holding ``a`` and one holding ``b``."""
    holders_a = [n.alias for n in spec.nodes if a in n.relation.attrs]
    holders_b = [n.alias for n in spec.nodes if b in n.relation.attrs]
    # BFS over the tree (+ residual edges treated as links to all earlier nodes)
    parent_of: Dict[str, Optional[str]] = {}
    adj: Dict[str, List[str]] = {n.alias: [] for n in spec.nodes}
    for n in spec.tree_nodes:
        if n.parent is not None:
            adj[n.alias].append(n.parent)
            adj[n.parent].append(n.alias)
    for n in spec.residual_nodes:
        for m in spec.nodes:
            if m.alias != n.alias and set(n.edge_attrs) & set(m.relation.attrs):
                adj[n.alias].append(m.alias)
                adj[m.alias].append(n.alias)
    start = holders_a[0]
    frontier = [start]
    parent_of[start] = None
    while frontier:
        x = frontier.pop(0)
        if x in holders_b:
            path = [x]
            while parent_of[path[-1]] is not None:
                path.append(parent_of[path[-1]])
            return tuple(reversed(path))
        for y in adj[x]:
            if y not in parent_of:
                parent_of[y] = x
                frontier.append(y)
    return (start,)


def split_join(spec: JoinSpec, template: Sequence[str]) -> SplitPlan:
    template = tuple(template)
    pairs: List[SplitPair] = []
    prev_source: Optional[str] = None
    for i in range(len(template) - 1):
        a, b = template[i], template[i + 1]
        holders = [n.alias for n in spec.nodes
                   if a in n.relation.attrs and b in n.relation.attrs]
        if holders:
            # prefer the previous source (=> fake edge, multiplier 1)
            src = prev_source if prev_source in holders else holders[0]
            pairs.append(SplitPair((a, b), src, fake_edge_to_prev=(src == prev_source)))
            prev_source = src
        else:
            path = _path_between(spec, a, b)
            pairs.append(SplitPair((a, b), None, False, path_aliases=path))
            prev_source = None
    return SplitPlan(spec, template, pairs)


def split_plans(joins: Sequence[JoinSpec],
                template: Optional[Sequence[str]] = None) -> List[SplitPlan]:
    tpl = tuple(template) if template is not None else build_template(joins)
    return [split_join(j, tpl) for j in joins]
