"""§7: ONLINE-UNION sampling (Algorithm 2) — reuse + backtracking.

Initialises cheaply with the HISTOGRAM-BASED parameters, then refines join /
overlap / union estimates on the fly with RANDOM-WALK batches while sampling.

* **Sample reuse** (Alg 2 lines 8-10): walk tuples collected during warm-up
  carry exact probabilities ``p(t)``.  When join ``J_j`` is selected and its
  pool is non-empty, draw a pooled tuple uniformly and accept with
  ``R = l / (p(t)·|J_j|)`` (``l`` = current pool size, sampling *without*
  replacement) — acceptance makes the reused tuple a ``1/|J_j|`` uniform draw.
  ``R > 1`` is handled as ``⌊R⌋`` copies plus a Bernoulli(frac) extra copy
  (the paper's multi-instance system ``Σ r_i·i = R``).
* **Backtracking with parameter update** (Alg 2 lines 18-20): every ``φ``
  recorded candidate probabilities, parameters are re-estimated from the
  accumulated walks and previously accepted samples are thinned with
  probability proportional to the new-to-old selection-ratio
  ``(|J'_h|'/|U|') / (|J'_h|/|U|)`` (normalised by its maximum so retention is
  maximal) — the retained output is uniform under the refined parameters.
  Backtracking stops once the estimate confidence reaches ``γ``.

Warm-up, φ-batch refinement, and the reuse pool are served by an
:class:`~repro.core.estimators.base.EstimatorBackend`: ``backend="numpy"``
keeps the behaviour-identical host engine; ``backend="jax"`` runs histogram
initialisation, whole wander-join walk batches, membership probes, and the
Horvitz–Thompson accumulators on device (sharing the sampling backend's
membership indexes).  ``backend="jax", mesh=...`` additionally spreads each
refinement observation across the mesh — ``world`` independent walk batches
whose HT moments merge on-mesh in one ``psum``
(:func:`repro.core.sharding.stats.psum_merge_moments`), so φ refines from
all shards' walks at once.  Unknown backend selectors raise.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from .backends import Backend, get_backend
from .cover import Cover, build_cover
from .estimators import EstimatorBackend, get_estimator
from .framework import estimate_union
from .index import Catalog
from .joins import JoinSpec
from .koverlap import OverlapOracle
from .membership import rows_subset
from .planner import PiecePlanner
from .predicates import (pred_mask_np, scaled_overlap_estimate,
                         selectivity_factor)
from .relation import fingerprint128
from .size_estimation import olken_bound
from .union_sampler import SampleSet, SamplerStats, pop_residual_rejects

Rows = Dict[str, np.ndarray]


@dataclasses.dataclass
class _Accepted:
    values: Dict[str, int]
    home: int
    sel_ratio: float    # |J'_h|/|U| under the parameters at acceptance time


class OnlineUnionSampler:
    """Algorithm 2: histogram init + random-walk refinement + reuse + backtrack."""

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec], seed: int = 0,
                 phi: int = 2048, gamma: float = 0.90,
                 target_rel_halfwidth: float = 0.15,
                 join_method: str = "ew", rw_batch: int = 256,
                 order: Optional[Sequence[str]] = None,
                 warm_rounds: int = 2,
                 backend: str | Backend = "numpy",
                 estimator: Optional[str | EstimatorBackend] = None,
                 pool_cap: int = 512, mesh=None,
                 trace_capacity: int = 256, predicate=None,
                 plan: str = "static"):
        if plan not in ("static", "adaptive"):
            raise ValueError(f"plan must be 'static' or 'adaptive', got {plan!r}")
        self.plan = plan
        self.cat = cat
        self.joins = list(joins)
        self.names = [j.name for j in self.joins]
        self._by_name = {j.name: j for j in self.joins}
        # §8.3 predicates: per-join reject_preds AND the union-wide
        # RejectingPredicate gate fresh draws and reuse-pool candidates
        # (counted in stats.pred_rejects); the membership prober applies
        # each piece's own reject_preds internally, so cover acceptance is
        # already predicate-aware on both backends.
        self.predicate = predicate
        gp = tuple(predicate.preds) if predicate is not None else ()
        self._own_preds = {j.name: tuple(j.reject_preds) + gp
                           for j in self.joins}
        # get_backend raises on unknown backend strings (no silent fallback)
        self.backend = get_backend(backend, cat, self.joins, join_method=join_method,
                                   seed=seed)
        self.prober = self.backend.oracle()
        self.attrs = list(self.joins[0].output_attrs)
        self.rng = np.random.default_rng(seed)
        self.phi = phi
        self.gamma = gamma
        self.target_rel_halfwidth = target_rel_halfwidth
        self.stats = SamplerStats()

        # (2 — built first so (1) can consume its histogram oracle)
        # estimation subsystem: warm-up, φ-batch refinement, and the reuse
        # pool all come from the estimator backend, which follows the
        # sampling backend unless overridden (backend="jax" ⇒ device walks,
        # device membership probes, device HT accumulators).
        if estimator is not None:
            est_spec = estimator            # explicit; unknown strings raise
        elif isinstance(backend, str):
            est_spec = backend              # follow the sampling backend
        else:
            est_spec = getattr(backend, "name", "numpy")
            if est_spec not in ("numpy", "jax"):
                import warnings
                warnings.warn(
                    f"OnlineUnionSampler: no estimator backend for custom "
                    f"sampling backend {est_spec!r}; refinement walks fall "
                    "back to the host engine (pass estimator= to override)",
                    stacklevel=2)
                obs.record_fallback(
                    "estimator_backend",
                    detail=f"custom sampling backend {est_spec!r} has no "
                           "estimator twin; refinement walks use numpy")
                est_spec = "numpy"
        est_kwargs = {}
        if mesh is not None and est_spec != "jax":
            raise ValueError("mesh= needs the device estimator; use "
                             "backend='jax' (or estimator='jax')")
        if est_spec == "jax":
            members = getattr(self.backend, "members", None)
            if members is not None:   # share the device membership indexes
                est_kwargs["members"] = members
            if mesh is not None:      # refine φ from all shards (on-mesh merge)
                est_kwargs["mesh"] = mesh
        self.estimator = get_estimator(est_spec, cat, self.joins,
                                       seed=seed + 1, batch=rw_batch,
                                       pool_cap=pool_cap, **est_kwargs)

        # (1) cheap init: HISTOGRAM-BASED parameters (device ops under jax).
        # §8.3: under rejection predicates the raw histogram algebra bounds
        # the *unfiltered* joins — scale overlaps by predicate selectivity so
        # φ initialisation doesn't overshoot filtered pieces by 1/selectivity
        # (olken_bound scales per-join internally).
        hist = self.estimator.histogram()
        est_fn = hist.estimate
        if any(j.reject_preds for j in self.joins):
            est_fn = scaled_overlap_estimate(hist.estimate)
        oracle = OverlapOracle(est_fn,
                               lambda j: olken_bound(cat, j), self.joins)
        est = estimate_union(oracle, order)
        self.cover: Cover = est.cover
        self.order = list(self.cover.order)
        # plan="adaptive": the fresh-draw retry path batches its draws by
        # the same fixed-point acceptance EMAs the fused engines carry on
        # device (ceil(1/ema) candidates per retry ~ one accept expected);
        # φ-refreshes reseed the EMAs from the rebuilt cover.
        self.planner = (PiecePlanner(self.cover, self._by_name)
                        if plan == "adaptive" else None)

        # φ-trajectory tracer: refinement history used to be dropped on the
        # floor; the ring keeps the recent trajectory queryable (bounded).
        self.trace = obs.TraceRing(capacity=trace_capacity)
        self.refresh_count = 0          # φ-batch refreshes performed so far
        self.last_refresh_at = -1       # stats.iterations at the last refresh
        self._hist_sizes = {n: float(self.cover.join_sizes[n])
                            for n in self.names}
        self._obs_m = None
        self.trace.append(
            "init",
            union_size=float(self.cover.union_size),
            piece_sizes={n: float(self.cover.piece_sizes[n])
                         for n in self.order},
            join_sizes=dict(self._hist_sizes),
            order=list(self.order))

        for j in self.joins:            # tiny warm start so sizes exist
            for _ in range(warm_rounds):
                self.estimator.observe([j], rounds=1)
        self._refresh_pools()
        self._refresh_size_cache()

        self.sources = {j.name: self.backend.source(j.name)
                        for j in self.joins}
        self._accepted: List[_Accepted] = []
        self._since_refresh = 0
        self._confident = False

    @property
    def rw(self) -> EstimatorBackend:
        """Historical name of the refinement engine (now an estimator backend)."""
        return self.estimator

    # ------------------------------------------------------------------ pools
    def _refresh_pools(self) -> None:
        """Flatten drained walk-pool batches into per-join candidate lists."""
        self.pools: Dict[str, List[Tuple[Dict[str, int], float]]] = {}
        for name, batches in self.estimator.drain_pool().items():
            entries: List[Tuple[Dict[str, int], float]] = []
            for rows, prob in batches:
                ok = prob > 0
                idx = np.nonzero(ok)[0]
                for i in idx:
                    entries.append(({a: int(rows[a][i]) for a in self.attrs},
                                    float(prob[i])))
            self.pools[name] = entries

    # ------------------------------------------------------------- parameters
    def _sel_ratio(self, oidx: int) -> float:
        u = max(self.cover.union_size, 1e-12)
        return self.cover.piece_sizes[self.order[oidx]] / u

    def _selection_probs(self) -> np.ndarray:
        p = np.array([max(self.cover.piece_sizes[n], 0.0) for n in self.order])
        s = p.sum()
        return p / s if s > 0 else np.full(len(p), 1.0 / len(p))

    def _refresh_size_cache(self) -> None:
        """Pull the walk-refined join sizes to host, once per refresh.

        Under the jax estimator ``size_stats`` are device-backed running
        accumulators: every ``.count`` / ``.mean`` read is a device→host
        scalar sync.  The accumulators only change when the estimator
        observes, so the sampling hot path (reuse acceptance in
        ``_try_reuse`` runs per candidate) reads this host-side memo
        instead of re-syncing unchanged device state."""
        cache: Dict[str, float] = {}
        for name in self.names:
            st = self.estimator.size_stats.get(name)
            if st is not None and st.count > 0 and st.mean > 0:
                # wander-join walks estimate the unfiltered join; scale by
                # the §8.3 predicate selectivity so reuse acceptance and the
                # refined cover see the *filtered* size
                cache[name] = (st.mean
                               * selectivity_factor(self._by_name[name]))
            else:
                cache[name] = max(self.cover.join_sizes[name], 1.0)
        self._size_est_cache = cache

    def _join_size_est(self, name: str) -> float:
        return self._size_est_cache[name]

    def _refresh_parameters(self) -> None:
        """Re-estimate sizes/overlaps from walks; rebuild cover; backtrack."""
        removed_before = self.stats.backtrack_removed
        old_ratio = {i: self._sel_ratio(i) for i in range(len(self.order))}
        # add fresh walk rounds for every pair (budgeted)
        import itertools
        for a, b in itertools.combinations(self.joins, 2):
            self.estimator.observe([a, b], rounds=1)
        if len(self.joins) > 2:
            self.estimator.observe(self.joins, rounds=1)
        self._refresh_pools()
        self._refresh_size_cache()
        ostats = self.estimator.overlap_stats
        est_fn = (lambda d: ostats[frozenset(j.name for j in d)].mean
                  if frozenset(j.name for j in d) in ostats else 0.0)
        if any(j.reject_preds for j in self.joins):
            # walks sample the unfiltered joins (membership probes are
            # already pred-aware) — scale like framework.warmup does
            est_fn = scaled_overlap_estimate(est_fn)
        oracle = OverlapOracle(est_fn,
                               lambda j: self._join_size_est(j.name),
                               self.joins)
        self.cover = build_cover(oracle, self.order)
        if self.planner is not None:
            # refined parameters invalidate the learned acceptance rates
            self.planner.reseed(self.cover, self._by_name)
        # ---- backtracking ----
        new_ratio = {i: self._sel_ratio(i) for i in range(len(self.order))}
        r = {i: (new_ratio[i] / old_ratio[i]) if old_ratio[i] > 0 else 1.0
             for i in range(len(self.order))}
        rmax = max(r.values()) if r else 1.0
        if rmax > 0:
            kept: List[_Accepted] = []
            for s in self._accepted:
                cur = self.cover.piece_sizes[self.order[s.home]] / max(self.cover.union_size, 1e-12)
                ratio = (cur / s.sel_ratio) if s.sel_ratio > 0 else 1.0
                q = min(ratio / rmax, 1.0)
                if self.rng.random() < q:
                    s.sel_ratio = cur
                    kept.append(s)
                else:
                    self.stats.backtrack_removed += 1
            self._accepted = kept
            # confidence check (γ): all pairwise overlap CIs tight enough?
            hw_ok = True
            for key, st in self.estimator.overlap_stats.items():
                if len(key) < 2 or st.count < 8:
                    continue
                if st.mean > 0 and st.half_width(self.gamma) > self.target_rel_halfwidth * st.mean:
                    hw_ok = False
            self._confident = hw_ok
        # ---- trace + metrics (refinement history used to be discarded) ----
        removed = self.stats.backtrack_removed - removed_before
        self.refresh_count += 1
        self.last_refresh_at = self.stats.iterations
        self.trace.append(
            "refresh",
            at_iteration=int(self.stats.iterations),
            union_size=float(self.cover.union_size),
            piece_sizes={n: float(self.cover.piece_sizes[n])
                         for n in self.order},
            sel_ratio={self.order[i]: float(new_ratio[i])
                       for i in range(len(self.order))},
            hist_gap=self.histogram_gaps(),
            kept=len(self._accepted), removed=int(removed),
            confident=bool(self._confident))
        if obs.enabled():
            m = self._obs_handles()
            m["refreshes"].inc()
            if removed:
                m["backtracked"].inc(removed)
            m["union"].set(float(self.cover.union_size))

    def histogram_gaps(self) -> Dict[str, float]:
        """Relative gap between the histogram init bound and the current
        walk-refined size estimate, per member join: ``(hist - walk)/hist``.
        Large positive gaps mean the cheap histogram bound overshot."""
        out = {}
        for name in self.names:
            hist = self._hist_sizes.get(name, 0.0)
            out[name] = (hist - self._join_size_est(name)) / max(hist, 1.0)
        return out

    @property
    def backtrack_count(self) -> int:
        """Total accepted samples removed by backtracking (all refreshes)."""
        return self.stats.backtrack_removed

    def _obs_handles(self):
        if self._obs_m is None:
            reg = obs.get_registry()
            self._obs_m = {
                "refreshes": reg.counter(
                    "repro_online_refreshes_total",
                    "phi-batch parameter refreshes performed"),
                "backtracked": reg.counter(
                    "repro_online_backtrack_removed_total",
                    "accepted samples removed by backtracking"),
                "union": reg.gauge(
                    "repro_online_union_size",
                    "current union-size estimate after refinement"),
            }
        return self._obs_m

    # ---------------------------------------------------------------- accept
    def _cover_accept(self, oidx: int, rows: Rows) -> np.ndarray:
        n = next(iter(rows.values())).shape[0]
        keep = np.ones(n, dtype=bool)
        for i in range(oidx):
            if not keep.any():
                break
            keep &= ~self.prober.contains(self.order[i], rows)
        return keep

    def _try_reuse(self, name: str, oidx: int) -> List[_Accepted]:
        """One reuse attempt (Alg 2 line 8). Returns accepted copies (may be >1)."""
        pool = self.pools.get(name, [])
        if not pool:
            return []
        l = len(pool)
        k = int(self.rng.integers(0, l))
        values, p = pool.pop(k)
        preds = self._own_preds[name]
        if preds:
            rows1 = {a: np.asarray([values[a]]) for a in self.attrs}
            if not bool(pred_mask_np(preds, rows1)[0]):
                self.stats.pred_rejects += 1
                return []
        # |J_j| is predicate-scaled (see _join_size_est), so surviving pool
        # tuples are emitted uniformly over the *filtered* join
        jsize = self._join_size_est(name)
        # Acceptance R = 1/(p(t)·|J_j|): each pool entry is an independent walk
        # outcome, so P(emit t) = p(t)·R = 1/|J_j|.  (The paper's printed
        # formula carries an extra factor l that double-counts the uniform
        # pick among l entries — see DESIGN.md §7.)  R>1 is handled as the
        # paper prescribes: ⌊R⌋ copies + Bernoulli(frac).
        R = 1.0 / max(p * jsize, 1e-300)
        copies = int(np.floor(R)) + (1 if self.rng.random() < (R - np.floor(R)) else 0)
        if copies == 0:
            self.stats.reuse_rejects += 1
            return []
        rows = {a: np.asarray([values[a]], dtype=np.int64) for a in self.attrs}
        if not bool(self._cover_accept(oidx, rows)[0]):
            self.stats.cover_rejects += 1
            return []
        self.stats.reuse_accepts += copies
        ratio = self._sel_ratio(oidx)
        return [_Accepted(dict(values), oidx, ratio) for _ in range(copies)]

    # ----------------------------------------------------------- fresh draws
    def _fresh_static(self, name: str, oidx: int,
                      retry_rounds: int) -> Optional[Rows]:
        """Pre-planner fresh-draw loop: one candidate per retry (bit-stable)."""
        from .join_sampler import EmptyJoinError
        for _ in range(retry_rounds):
            try:
                rows, draws = self.sources[name].draw(self.rng, 1, batch=32)
            except EmptyJoinError:
                break
            self.stats.candidate_draws += draws
            self.stats.residual_rejects += pop_residual_rejects(
                self.sources[name])
            self._since_refresh += 1
            preds = self._own_preds[name]
            if preds and not bool(pred_mask_np(preds, rows)[0]):
                self.stats.pred_rejects += 1
                continue
            if bool(self._cover_accept(oidx, rows)[0]):
                return rows
            self.stats.cover_rejects += 1
        return None

    def _fresh_adaptive(self, name: str, oidx: int,
                        retry_rounds: int) -> Optional[Rows]:
        """EMA-batched fresh draws: ``suggest_batch`` candidates per retry,
        first eligible wins; scanned-prefix reject counts feed the planner."""
        from .join_sampler import EmptyJoinError
        k = self.planner.suggest_batch(oidx)
        preds = self._own_preds[name]
        scanned = accepted_n = pred_total = 0
        out: Optional[Rows] = None
        for _ in range(retry_rounds):
            try:
                rows, draws = self.sources[name].draw(self.rng, k, batch=32)
            except EmptyJoinError:
                break
            self.stats.candidate_draws += draws
            self.stats.residual_rejects += pop_residual_rejects(
                self.sources[name])
            self._since_refresh += 1
            nb = next(iter(rows.values())).shape[0]
            pm = (pred_mask_np(preds, rows) if preds
                  else np.ones(nb, dtype=bool))
            cm = self._cover_accept(oidx, rows)
            elig = np.nonzero(pm & cm)[0]
            stop = int(elig[0]) + 1 if elig.size else nb
            # candidates past the first eligible one are never examined —
            # dropping them whole keeps the emitted tuple a plain uniform
            # draw conditioned on eligibility
            pred_r = int((~pm[:stop]).sum())
            self.stats.pred_rejects += pred_r
            self.stats.cover_rejects += int((pm[:stop] & ~cm[:stop]).sum())
            scanned += stop
            pred_total += pred_r
            if elig.size:
                i = int(elig[0])
                out = {a: rows[a][i:i + 1] for a in self.attrs}
                accepted_n = 1
                break
        if scanned > 0:
            self.planner.observe(oidx, scanned, accepted_n,
                                 pred_rejects=pred_total)
        return out

    # ---------------------------------------------------------------- sample
    def sample(self, n: int, retry_rounds: int = 64) -> SampleSet:
        guard = 0
        max_guard = max(500 * n, 20_000)
        while len(self._accepted) < n:
            guard += 1
            if guard > max_guard:
                raise RuntimeError("OnlineUnionSampler budget exhausted")
            probs = self._selection_probs()
            oidx = int(self.rng.choice(len(self.order), p=probs))
            name = self.order[oidx]
            got = self._try_reuse(name, oidx)
            if got:
                self._accepted.extend(got)
                self._since_refresh += 1
            else:
                # fresh uniform sampling with retry-within-join; under
                # plan="adaptive" each retry draws an EMA-sized batch and
                # accepts the first eligible candidate (the batch is i.i.d.
                # and eligibility is per-candidate, so the first eligible is
                # the same uniform draw the one-at-a-time loop makes)
                if self.planner is not None:
                    accepted = self._fresh_adaptive(name, oidx, retry_rounds)
                else:
                    accepted = self._fresh_static(name, oidx, retry_rounds)
                if accepted is not None:
                    self._accepted.append(_Accepted(
                        {a: int(accepted[a][0]) for a in self.attrs},
                        oidx, self._sel_ratio(oidx)))
                else:
                    self.stats.dropped_slots += 1
            self.stats.iterations += 1
            if (not self._confident) and self._since_refresh >= self.phi:
                self._since_refresh = 0
                self._refresh_parameters()
        acc = self._accepted[:n]
        self.stats.samples_emitted += n
        rows = {a: np.asarray([s.values[a] for s in acc], dtype=np.int64)
                for a in self.attrs}
        home = np.asarray([s.home for s in acc], dtype=np.int64)
        fp = fingerprint128([rows[a] for a in sorted(self.attrs)])
        return SampleSet(self.attrs, rows, home, fp, self.stats)
