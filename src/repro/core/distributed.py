"""Distributed union sampling for multi-host training (beyond-paper; DESIGN §2/§5).

Two uniformity-preserving, coordination-free schemes, now layered on top of
the backend + sharding stack (:mod:`repro.core.backends`,
:mod:`repro.core.sharding`):

* **seed-split** (default, zero overhead) — probe-mode Algorithm 1 is
  *stateless across samples*: each accepted tuple is an independent
  ``1/|U|`` draw.  Host ``h`` simply runs its own sampler with fold-in seed
  ``h``; the interleaved global stream is i.i.d. uniform.  This is the direct
  payoff of the paper's independence guarantee.  On a device mesh this is the
  *replicated* axis: every host runs its own (optionally sharded) engine on
  its own seed — ``DistributedUnionSampler(..., backend="jax", mesh=...)``
  puts each host's fused Algorithm-1 rounds on its local mesh via
  :class:`~repro.core.sharding.sampler.ShardedUnionSampler`.
* **hash-partition** — required only for record-mode (which keeps the
  ``orig_join`` revision record): the tuple-fingerprint space is split into
  ``world`` partitions; host ``h`` additionally rejects candidates outside
  partition ``h``, so its record is private and never needs communication.
  Each host's stream is uniform over its partition ``U_h``; hosts are sampled
  proportionally to ``|U_h| ≈ |U|/world`` when streams are merged.  The
  *intra*-host analogue of this partition is exactly the sharded engine's
  membership ownership exchange
  (:func:`repro.core.sharding.catalog.partition_of_fp32`).

Estimator statistics (:class:`RunningMean`) are associative, so periodic
cross-host refinement is one all-gather + merge (`merge_statistics`); the
on-mesh form of the same merge is
:func:`repro.core.sharding.stats.psum_merge_moments`.  Sample-stream cost
accounting merges with :meth:`SamplerStats.merge`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .cover import Cover
from .index import Catalog
from .joins import JoinSpec
from .size_estimation import RunningMean
from .union_sampler import SampleSet, SamplerStats, SetUnionSampler


def partition_of(fingerprint: np.ndarray, world: int) -> np.ndarray:
    """Partition id per sample from the primary 64-bit fingerprint."""
    return (fingerprint[:, 0] % np.uint64(world)).astype(np.int64)


class DistributedUnionSampler:
    """Per-host wrapper around :class:`SetUnionSampler`.

    ``backend`` and ``mesh`` forward to the inner sampler, so the seed-split
    scheme can run the fused device engine (or the mesh-sharded engine) today;
    the numpy default stays the behaviour-identical host reference.
    """

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec], cover: Cover,
                 rank: int, world: int, scheme: str = "seed-split",
                 membership: str = "probe", join_method: str = "ew",
                 seed: int = 0, backend="numpy", mesh=None,
                 round_batch: int = 4096):
        if scheme not in ("seed-split", "hash-partition"):
            raise ValueError(f"unknown scheme {scheme!r}")
        if scheme == "seed-split" and membership != "probe":
            raise ValueError("seed-split requires the stateless probe mode")
        self.rank, self.world, self.scheme = rank, world, scheme
        self.inner = SetUnionSampler(
            cat, joins, cover, membership=membership, join_method=join_method,
            seed=seed * 1_000_003 + rank, backend=backend, mesh=mesh,
            round_batch=round_batch)

    def sample(self, n: int, oversample: float = 1.5,
               max_rounds: int = 64) -> SampleSet:
        if self.scheme == "seed-split":
            return self.inner.sample(n)
        # hash-partition: keep only this rank's partition (extra rejection)
        got_rows: List[Dict[str, np.ndarray]] = []
        got_home: List[np.ndarray] = []
        got_fp: List[np.ndarray] = []
        count = 0
        grow = 1.0          # geometric growth across under-filled rounds
        for _ in range(max_rounds):
            want = max(int((n - count) * self.world * oversample * grow), 32)
            ss = self.inner.sample(want)
            mine = partition_of(ss.fingerprint, self.world) == self.rank
            idx = np.nonzero(mine)[0]
            if idx.shape[0]:
                got_rows.append({a: c[idx] for a, c in ss.rows.items()})
                got_home.append(ss.home[idx])
                got_fp.append(ss.fingerprint[idx])
                count += idx.shape[0]
            if count >= n:
                break
            # under-filled round: this partition holds less than the assumed
            # |U|/world share, so a fixed oversample can stall just short of
            # the target — widen the next request geometrically
            grow = min(grow * 2.0, 64.0)
        if count < n:
            raise RuntimeError(
                f"hash-partition sampler under-filled: got {count} of {n} "
                f"requested samples for partition {self.rank}/{self.world} "
                f"after {max_rounds} rounds (raise max_rounds/oversample)")
        rows = {a: np.concatenate([r[a] for r in got_rows])[:n]
                for a in got_rows[0]}
        return SampleSet(self.inner.attrs, rows,
                         np.concatenate(got_home)[:n],
                         np.concatenate(got_fp)[:n],
                         self.inner.stats)


def merge_statistics(stats: Sequence[RunningMean]) -> RunningMean:
    """All-gather + associative merge of per-host estimator statistics."""
    out = RunningMean()
    for s in stats:
        out.merge(s)
    return out


def merge_streams(parts: Sequence[SampleSet], seed: int = 0) -> SampleSet:
    """Interleave per-host sample streams into one global stream."""
    rng = np.random.default_rng(seed)
    attrs = parts[0].attrs
    rows = {a: np.concatenate([p.rows[a] for p in parts]) for a in attrs}
    home = np.concatenate([p.home for p in parts])
    fp = np.concatenate([p.fingerprint for p in parts])
    perm = rng.permutation(home.shape[0])
    stats = SamplerStats()
    for p in parts:
        stats.merge(p.stats)
    return SampleSet(attrs, {a: c[perm] for a, c in rows.items()},
                     home[perm], fp[perm], stats)
