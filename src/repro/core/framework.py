"""Facade: warm-up → parameter oracle → cover → sampler (paper Fig. overview).

``warmup(cat, joins, method)`` builds the :class:`OverlapOracle` backing both
Theorem 3 (union size, Eq. 1 diagnostics) and the cover sizes of Algorithm 1:

* ``exact``        — FULLJOIN ground truth (tests / small data only),
* ``histogram``    — §5 degree-statistics bounds (decentralised setting),
* ``random_walk``  — §6 wander-join estimates (centralised setting).

All three handle cyclic (§8.2 skeleton+residual) members: ``exact`` counts
distinct tuples of the materialised join, the histogram algebra treats
residual edges as links to their earlier relations, and wander-join walks
hop residual edges like any other — so every warm-up method feeds covers
over unions that mix acyclic and cyclic joins, on either estimation
backend.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cover import Cover, build_cover
from .estimators import get_estimator
from .index import Catalog
from .joins import JoinSpec, join_size
from .join_sampler import JoinSampler
from .koverlap import KOverlaps, OverlapOracle, k_overlaps
from .overlap import (HistogramOverlap, exact_join_size_distinct,
                      exact_overlap)
from .size_estimation import olken_bound
from .union_sampler import SampleSet, SetUnionSampler


@dataclasses.dataclass
class WarmupResult:
    oracle: OverlapOracle
    method: str
    seconds: float
    aux: object = None  # HistogramOverlap / EstimatorBackend instance


def _exact_size_fn(cat: Catalog):
    def f(j: JoinSpec) -> float:
        if j.is_cyclic or j.reject_preds:
            # cyclic: residual edges; reject_preds: the filtered join must be
            # counted — both need the materialised distinct count
            return float(exact_join_size_distinct(cat, j))
        # duplicate-free base relations => join output duplicate-free, so the
        # EW total weight IS the distinct size (cheap, no materialisation).
        return JoinSampler(cat, j, method="ew").exact_acyclic_size()
    return f


def warmup(cat: Catalog, joins: Sequence[JoinSpec], method: str = "exact",
           seed: int = 0, rw_batch: int = 512,
           rw_rel_halfwidth: float = 0.25,
           rw_max_walks: int = 20_000,
           hist_mode: str = "max",
           backend: str = "numpy", mesh=None) -> WarmupResult:
    """Build the parameter oracle.  ``backend`` selects the estimation engine
    for the ``histogram`` / ``random_walk`` methods: ``"numpy"`` is the host
    reference, ``"jax"`` runs walks, probes, HT accumulation, and the
    histogram algebra on device (see repro.core.estimators).  ``mesh``
    (random_walk + jax only) spreads each walk batch across the mesh with an
    on-mesh moment merge (see repro.core.sharding.stats)."""
    joins = list(joins)
    if mesh is not None and (method != "random_walk" or backend != "jax"):
        raise ValueError("mesh= applies to method='random_walk' with "
                         "backend='jax' only")
    t0 = time.perf_counter()
    if method == "exact":
        oracle = OverlapOracle(lambda d: exact_overlap(cat, d),
                               _exact_size_fn(cat), joins)
        aux = None
    elif method == "histogram":
        if backend == "numpy":
            hist = HistogramOverlap(cat, joins, mode=hist_mode)
        elif backend == "jax":
            # no walkers needed for the histogram method — build the device
            # histogram directly rather than a full estimator
            from .estimators.jax_estimator import DeviceHistogramOverlap
            hist = DeviceHistogramOverlap(cat, joins, mode=hist_mode)
        else:
            raise ValueError(
                f"unknown estimation backend {backend!r} "
                "(expected 'numpy' or 'jax')")
        est_fn = hist.estimate
        if any(j.reject_preds for j in joins):
            # §8.3 rejection predicates: overlaps of filtered joins shrink by
            # (at least) the most selective member's predicate; olken_bound
            # scales per-join internally
            from .predicates import scaled_overlap_estimate
            est_fn = scaled_overlap_estimate(hist.estimate)
        oracle = OverlapOracle(est_fn, lambda j: olken_bound(cat, j), joins)
        aux = hist
    elif method == "random_walk":
        est_kwargs = {"mesh": mesh} if mesh is not None else {}
        rw = get_estimator(backend, cat, joins, seed=seed, batch=rw_batch,
                           **est_kwargs)
        est_fn = (lambda d: rw.estimate(d, rel_halfwidth=rw_rel_halfwidth,
                                        max_walks=rw_max_walks).value)
        size_fn = rw.join_size
        if any(j.reject_preds for j in joins):
            # walks sample the unfiltered joins; scale both estimates by the
            # predicate selectivity (membership probes are already pred-aware)
            from .predicates import scaled_overlap_estimate, scaled_size_fn
            est_fn = scaled_overlap_estimate(est_fn)
            size_fn = scaled_size_fn(size_fn)
        oracle = OverlapOracle(est_fn, size_fn, joins)
        aux = rw
    else:
        raise ValueError(f"unknown warmup method {method!r}")
    return WarmupResult(oracle, method, time.perf_counter() - t0, aux)


@dataclasses.dataclass
class UnionEstimates:
    cover: Cover
    koverlaps: KOverlaps
    union_size_cover: float     # Σ |J'_i| (drives Algorithm 1's selection)
    union_size_eq1: float       # Eq. 1 via Theorem 3 (diagnostic consistency)


def estimate_union(oracle: OverlapOracle,
                   order: Optional[Sequence[str]] = None) -> UnionEstimates:
    cover = build_cover(oracle, order)
    ko = k_overlaps(oracle)
    return UnionEstimates(cover, ko, cover.union_size, ko.union_size())


def make_set_union_sampler(cat: Catalog, joins: Sequence[JoinSpec],
                           method: str = "exact", membership: str = "probe",
                           join_method: str = "ew", seed: int = 0,
                           order: Optional[Sequence[str]] = None,
                           sampler_backend: str = "numpy", mesh=None,
                           **warmup_kw) -> Tuple[SetUnionSampler, UnionEstimates, WarmupResult]:
    """``sampler_backend``/``mesh`` select the sampling engine; ``backend=``
    still flows through ``**warmup_kw`` to :func:`warmup` and keeps selecting
    the estimation engine, as before."""
    wr = warmup(cat, joins, method=method, seed=seed, **warmup_kw)
    est = estimate_union(wr.oracle, order)
    sampler = SetUnionSampler(cat, joins, est.cover, membership=membership,
                              join_method=join_method, seed=seed,
                              backend=sampler_backend, mesh=mesh)
    return sampler, est, wr
