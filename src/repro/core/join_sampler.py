"""Batched random sampling over a single join (the paper's §3.2 subroutine).

Implements the three weight instantiations of Zhao et al. [38] that the paper
adopts, re-derived as batched tensor algebra (no tuple-at-a-time walks):

* ``ew``  — Exact Weight.  ``w(t)`` = number of join tuples ``t`` yields,
  computed bottom-up over the join tree with *prefix-sum semi-join
  aggregation*: per edge, ``S(parent row) = cs[hi] - cs[lo]`` where ``cs`` is
  the cumulative sum of child weights in sorted-key order and ``[lo, hi)`` is
  the sorted range matching the parent's key.  Sampling draws the root
  proportional to ``w`` and each child proportional to ``w`` *within its
  matching range* — a uniform draw into the prefix sums followed by a binary
  search.  Zero rejection on acyclic joins.
* ``eo``  — Extended Olken.  Uniform root, uniform child among matches,
  accept with probability ``prod(d_edge / M_edge)``.  Includes the paper's
  zero-weight fix: a backward semi-join pass marks tuples that cannot reach a
  full join tuple so they are never drawn (``reduce="backward"``), plus a
  beyond-paper full Yannakakis reduction (``reduce="full"``).
* ``wj``  — Wander Join.  Like ``eo`` but never rejects; returns each tuple
  with its exact walk probability ``p(t)`` for Horvitz–Thompson estimation
  (§6.1) and for the reuse phase of ONLINE-UNION (§7).

Cyclic joins (skeleton + residual, §8.2): after the tree walk, each residual
relation contributes an acceptance factor ``d/M`` and a uniform pick among its
``d`` matches; overall uniformity is preserved (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .index import Catalog, SortedIndex
from .joins import JoinNode, JoinSpec
from .relation import Relation, combine_columns

Rows = Dict[str, np.ndarray]


class EmptyJoinError(RuntimeError):
    """Raised when asked for uniform samples from a structurally empty join."""


@dataclasses.dataclass
class EdgePlan:
    node: JoinNode
    index: SortedIndex
    max_degree: int
    # EW only: prefix sums of child weights in sorted order, shape (n+1,)
    weight_prefix: Optional[np.ndarray] = None


@dataclasses.dataclass
class SampleBatch:
    """One round of B candidate samples."""

    rows: Rows                    # gathered output attrs, each (B,)
    ok: np.ndarray                # walk completed (no dead end)
    accept: np.ndarray            # ok AND passed accept/reject (uniform samples)
    prob: np.ndarray              # exact walk probability p(t) (wj; ew/eo: sampling prob)
    draws: int                    # candidate count (cost accounting, §3.3)

    def accepted_rows(self) -> Rows:
        idx = np.nonzero(self.accept)[0]
        return {a: c[idx] for a, c in self.rows.items()}


class JoinSampler:
    """Uniform (ew/eo) or HT-weighted (wj) batched sampler over one join."""

    def __init__(self, cat: Catalog, spec: JoinSpec, method: str = "ew",
                 reduce: str | None = None):
        if method not in ("ew", "eo", "wj"):
            raise ValueError(f"unknown method {method!r}")
        self.cat = cat
        self.spec = spec
        self.method = method
        self.reduce = reduce if reduce is not None else ("backward" if method == "eo" else "none")
        # cumulative §8.2 residual rejections (ew on cyclic joins only —
        # under eo the d/M test blends tree and residual factors)
        self.residual_rejects = 0
        self._prepare()

    # ------------------------------------------------------------------ prep
    def _prepare(self) -> None:
        spec = self.spec
        self.order: List[JoinNode] = spec.expansion_order()
        self.root = self.order[0]
        self._reduced: Dict[str, Relation] = {n.alias: n.relation for n in self.order}
        if self.reduce in ("backward", "full"):
            self._semijoin_reduce(full=self.reduce == "full")

        # Edge plans for all non-root nodes (tree children + residuals).
        self.edges: Dict[str, EdgePlan] = {}
        for n in self.order[1:]:
            rel = self._reduced[n.alias]
            idx = self.cat.index(rel, list(n.edge_attrs))
            self.edges[n.alias] = EdgePlan(n, idx, idx.max_degree())

        root_rel = self._reduced[self.root.alias]
        self.root_rel = root_rel
        self.n_root = root_rel.nrows

        if self.method == "ew":
            self._compute_exact_weights()
        else:
            self.root_weight_total = float(self.n_root)

    def _semijoin_reduce(self, full: bool) -> None:
        """Yannakakis semi-join reduction over the *tree* part.

        backward: leaf→root 'has a match' filtering (the paper's zero-weight
        fix generalised); full: adds the root→leaf pass.
        Residual relations are left untouched (they only gate acceptance).
        """
        spec = self.spec
        kids = spec.children_map()
        # backward (children filter parents)
        for n in reversed([m for m in self.order if m.kind == "tree"]):
            rel = self._reduced[n.alias]
            mask = np.ones(rel.nrows, dtype=bool)
            for c in kids.get(n.alias, []):
                crel = self._reduced[c.alias]
                cidx = self.cat.index(crel, list(c.edge_attrs))
                key = combine_columns([rel.columns[a] for a in c.edge_attrs])
                mask &= cidx.contains(key)
            if not mask.all():
                self._reduced[n.alias] = rel.filter(mask, name=f"{rel.name}#red{n.alias}")
        if full:
            # forward (parents filter children)
            for n in [m for m in self.order[1:] if m.kind == "tree"]:
                prel = self._reduced[n.parent]
                crel = self._reduced[n.alias]
                pidx = self.cat.index(prel, list(n.edge_attrs))
                key = combine_columns([crel.columns[a] for a in n.edge_attrs])
                mask = pidx.contains(key)
                if not mask.all():
                    self._reduced[n.alias] = crel.filter(mask, name=f"{crel.name}#redf{n.alias}")
            # rebuild edge indexes against reduced children happens in _prepare caller

    def _compute_exact_weights(self) -> None:
        spec = self.spec
        kids = spec.children_map()
        weights: Dict[str, np.ndarray] = {}
        for n in reversed([m for m in self.order if m.kind == "tree"]):
            rel = self._reduced[n.alias]
            w = np.ones(rel.nrows, dtype=np.float64)
            for c in kids.get(n.alias, []):
                plan = self.edges[c.alias]
                cw = weights[c.alias]
                cs = np.zeros(plan.index.nrows + 1, dtype=np.float64)
                np.cumsum(cw[plan.index.perm], out=cs[1:])
                plan.weight_prefix = cs
                key = combine_columns([rel.columns[a] for a in c.edge_attrs])
                lo, hi = plan.index.ranges(key)
                w = w * (cs[hi] - cs[lo])
            weights[n.alias] = w
        self.node_weights = weights
        w_root = weights[self.root.alias]
        self.root_weight_prefix = np.zeros(self.n_root + 1, dtype=np.float64)
        np.cumsum(w_root, out=self.root_weight_prefix[1:])
        self.root_weight_total = float(self.root_weight_prefix[-1])

    # ----------------------------------------------------------------- bounds
    def size_upper_bound(self) -> float:
        """Extended-Olken style bound |J| <= |R_root| * prod M (§3.2)."""
        b = float(self.n_root)
        for plan in self.edges.values():
            b *= max(plan.max_degree, 0)
        return b

    def exact_acyclic_size(self) -> float:
        """For acyclic joins with method=ew this is the exact |J| (Σ w_root)."""
        if self.method != "ew":
            raise ValueError("exact size requires method='ew'")
        if self.spec.is_cyclic:
            raise ValueError("exact_acyclic_size on a cyclic join")
        return self.root_weight_total

    # ---------------------------------------------------------------- sampling
    def sample_batch(self, rng: np.random.Generator, batch: int) -> SampleBatch:
        """Draw ``batch`` candidates (one vectorised walk per candidate)."""
        B = int(batch)
        if self.n_root == 0 or any(p.index.nrows == 0 for p in self.edges.values()):
            return self._empty_batch(B)
        ok = np.ones(B, dtype=bool)
        prob = np.ones(B, dtype=np.float64)
        accept_ratio = np.ones(B, dtype=np.float64)

        # root draw
        if self.method == "ew":
            if self.root_weight_total <= 0:
                return self._empty_batch(B)
            u = rng.random(B)
            tgt = u * self.root_weight_total
            root_ids = np.searchsorted(self.root_weight_prefix, tgt, side="right") - 1
            root_ids = np.clip(root_ids, 0, self.n_root - 1)
            w_root = self.node_weights[self.root.alias]
            prob *= w_root[root_ids] / self.root_weight_total
        else:
            if self.n_root == 0:
                return self._empty_batch(B)
            root_ids = rng.integers(0, self.n_root, size=B)
            prob *= 1.0 / self.n_root

        rows: Rows = {a: c[root_ids] for a, c in self.root_rel.columns.items()}

        for n in self.order[1:]:
            plan = self.edges[n.alias]
            key = combine_columns([rows[a] for a in n.edge_attrs])
            lo, hi = plan.index.ranges(key)
            d = hi - lo
            if n.kind == "tree" and self.method == "ew":
                cs = plan.weight_prefix
                tot = cs[hi] - cs[lo]
                alive = ok & (tot > 0)
                u = rng.random(B)
                tgt = cs[lo] + u * np.maximum(tot, 1e-300)
                pos = np.searchsorted(cs, tgt, side="right") - 1
                pos = np.clip(pos, lo, np.maximum(hi - 1, lo))
                pos = np.clip(pos, 0, plan.index.nrows - 1)  # dead walks: safe gather
                cw = self.node_weights[n.alias]
                child_rows = plan.index.perm[pos]
                sel_w = cw[child_rows]
                prob = np.where(alive, prob * np.where(tot > 0, sel_w / np.maximum(tot, 1e-300), 0.0), 0.0)
                ok = alive
            else:
                alive = ok & (d > 0)
                u = rng.random(B)
                off = np.floor(u * np.maximum(d, 1)).astype(np.int64)
                pos = lo + np.minimum(off, np.maximum(d - 1, 0))
                pos = np.clip(pos, 0, plan.index.nrows - 1)  # dead walks: safe gather
                child_rows = plan.index.perm[pos]
                prob = np.where(alive, prob / np.maximum(d, 1), 0.0)
                ok = alive
                if self.method in ("eo", "ew") and (n.kind == "residual" or self.method == "eo"):
                    m = max(plan.max_degree, 1)
                    accept_ratio = np.where(alive, accept_ratio * d / m, 0.0)
            rel = self._reduced[n.alias]
            safe_rows = np.where(ok, child_rows, 0)
            for a in rel.attrs:
                if a not in rows:
                    rows[a] = rel.columns[a][safe_rows]

        if self.method == "wj":
            accept = ok.copy()
        else:
            u = rng.random(B)
            accept = ok & (u < accept_ratio)
            if self.method == "ew" and self.spec.is_cyclic:
                self.residual_rejects += int((ok & ~accept).sum())
        return SampleBatch(rows=rows, ok=ok, accept=accept, prob=np.where(ok, prob, 0.0), draws=B)

    def _empty_batch(self, B: int) -> SampleBatch:
        rows = {a: np.zeros(B, dtype=np.int64) for a in self.spec.output_attrs}
        z = np.zeros(B, dtype=bool)
        return SampleBatch(rows=rows, ok=z, accept=z.copy(), prob=np.zeros(B), draws=B)

    def sample_uniform(self, rng: np.random.Generator, n: int,
                       batch: int = 1024, max_rounds: int = 10_000
                       ) -> Tuple[Rows, int]:
        """Collect ``n`` uniform samples (ew/eo); returns (rows, total draws)."""
        if self.method == "wj":
            raise ValueError("wj samples are not uniform; use sample_batch + HT")
        if self.is_empty():
            raise EmptyJoinError(f"join {self.spec.name!r} is empty")
        got: List[Rows] = []
        total = 0
        count = 0
        for _ in range(max_rounds):
            sb = self.sample_batch(rng, batch)
            total += sb.draws
            acc = sb.accepted_rows()
            k = next(iter(acc.values())).shape[0] if acc else 0
            if k:
                got.append(acc)
                count += k
            if count >= n:
                break
        else:
            raise RuntimeError(f"sample_uniform: exceeded {max_rounds} rounds")
        rows = {a: np.concatenate([g[a] for g in got])[:n] for a in got[0]}
        return rows, total

    def is_empty(self) -> bool:
        if self.n_root == 0 or any(p.index.nrows == 0 for p in self.edges.values()):
            return True
        if self.method == "ew" and self.root_weight_total <= 0:
            return True
        return False

    # ------------------------------------------------------------- acceptance
    def acceptance_rate(self, rng: np.random.Generator, probe: int = 4096) -> float:
        sb = self.sample_batch(rng, probe)
        return float(sb.accept.mean())
