"""Fully-jitted batched chain-join sampling (the device path of the sampler).

Historically this module carried its own chain-only device pipeline; the
engine now lives in :mod:`repro.core.backends.jax_backend` as
:class:`DeviceTreeJoin`, which generalises the same root-draw → per-hop
``searchsorted`` → ranged-weighted-pick program from single-attribute chains
to arbitrary acyclic joins (composite mixed-radix edge keys, per-node child
picks).  :class:`JaxChainSampler` is kept as the chain-shaped façade: same
API, same chain-only validation, one jitted program per batch with no host
round trips per hop — so the sampler can run inside the training program
(fused with the input pipeline, or on dedicated sampler chips at pod scale).

Equivalence with the host sampler is property-tested
(tests/test_jax_sampler.py: identical distribution, exact EW totals; the
tree generalisation is covered by tests/test_backends.py).
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import numpy as np

from .index import Catalog
from .joins import JoinSpec

# Re-exported for backward compatibility; the implementation moved to the
# backend layer.
from .backends.jax_backend import DeviceTreeJoin, _inverse_cdf_pick  # noqa: F401


class JaxChainSampler:
    """Jitted EW sampler over a chain join (uniform, zero rejection)."""

    def __init__(self, cat: Catalog, spec: JoinSpec, seed: int = 0):
        if spec.is_cyclic or not spec.is_chain:
            shape = "cyclic" if spec.is_cyclic else "non-chain acyclic"
            raise ValueError(
                f"JaxChainSampler: join {spec.name!r} is {shape}; this facade "
                "is chain-only — DeviceTreeJoin in "
                "repro.core.backends.jax_backend runs acyclic and cyclic "
                "(§8.2 skeleton+residual) joins on device")
        self.spec = spec
        self.tree = DeviceTreeJoin(cat, spec)
        self.attrs = tuple(spec.output_attrs)
        self.n_hops = len(self.tree.node_cfgs)
        self.key = jax.random.PRNGKey(seed)
        self.total_weight = self.tree.total_weight
        self._draw_jits: Dict[int, object] = {}

    def _draw_fn(self, batch: int):
        if batch not in self._draw_jits:
            self._draw_jits[batch] = jax.jit(
                functools.partial(self.tree.draw, batch=batch))
        return self._draw_jits[batch]

    def sample_batch(self, batch: int) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        self.key, sub = jax.random.split(self.key)
        rows, ok, _ = self._draw_fn(batch)(sub)   # chains: accept == walk_ok
        return ({a: np.asarray(rows[a]).astype(np.int64) for a in self.attrs},
                np.asarray(ok))

    def sample_uniform(self, n: int, batch: int = 4096,
                       max_rounds: int = 1000) -> Dict[str, np.ndarray]:
        got: List[Dict[str, np.ndarray]] = []
        count = 0
        for _ in range(max_rounds):
            rows, ok = self.sample_batch(batch)
            idx = np.nonzero(ok)[0]
            if idx.shape[0]:
                got.append({a: c[idx] for a, c in rows.items()})
                count += idx.shape[0]
            if count >= n:
                break
        else:
            raise RuntimeError("JaxChainSampler: round budget exhausted")
        return {a: np.concatenate([g[a] for g in got])[:n] for a in got[0]}
