"""Fully-jitted batched chain-join sampling (the device path of the sampler).

The numpy samplers in :mod:`join_sampler` are the host reference; this module
is the TPU-resident pipeline for chain joins (UQ1/UQ2's shape — the paper's
§5.1 base case): relations live on device as sorted columns + prefix-summed
exact weights, and one ``sample_batch`` is a single jitted program:

    root draw (prefix-sum inverse-CDF)                         [kernel: choice]
    per hop:  searchsorted(lo,hi) → ranged weighted pick       [kernel: walk]
    gathers of payload columns

Everything is ``jax.lax`` control flow over fixed shapes — no host round
trips per hop — so the sampler can run *inside* the training program (e.g.
fused with the input pipeline on the host-offload core of each chip, or on
dedicated sampler chips at pod scale; DESIGN §2/§5).

Equivalence with the host sampler is property-tested
(tests/test_jax_sampler.py: identical distribution, exact EW totals).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .index import Catalog
from .joins import JoinSpec
from .relation import combine_columns


@dataclasses.dataclass
class DeviceChain:
    """Device-resident chain-join state for EW sampling."""

    # per hop i (0..m-2): child relation's sorted-by-key data
    sorted_keys: List[jnp.ndarray]       # (n_i,) int64-as-2xint32? use int32 domain
    perm: List[jnp.ndarray]              # (n_i,) int32 row ids in key order
    wprefix: List[jnp.ndarray]           # (n_i+1,) float32 prefix sums of child weights
    child_cols: List[Dict[str, jnp.ndarray]]   # payload columns per child
    root_cols: Dict[str, jnp.ndarray]
    root_wprefix: jnp.ndarray            # (n_0+1,)
    edge_attrs: List[str]
    total_weight: float


def build_device_chain(cat: Catalog, spec: JoinSpec) -> DeviceChain:
    """Prepare a chain join for the jitted sampler (EW weights, prefix sums)."""
    if spec.is_cyclic or not spec.is_chain:
        raise ValueError("device sampler: chain joins only (use the host "
                         "sampler for trees/cyclic)")
    from .join_sampler import JoinSampler
    js = JoinSampler(cat, spec, method="ew")   # reuse host weight computation
    order = js.order
    sorted_keys, perm, wprefix, child_cols, edge_attrs = [], [], [], [], []
    for n in order[1:]:
        plan = js.edges[n.alias]
        sorted_keys.append(jnp.asarray(plan.index.sorted_vals))
        perm.append(jnp.asarray(plan.index.perm, jnp.int32))
        wprefix.append(jnp.asarray(plan.weight_prefix, jnp.float32))
        rel = js._reduced[n.alias]
        child_cols.append({a: jnp.asarray(c) for a, c in rel.columns.items()})
        edge_attrs.append(n.edge_attrs[0] if len(n.edge_attrs) == 1 else None)
        if edge_attrs[-1] is None:
            raise ValueError("device sampler: single-attribute edges only")
    root_rel = js.root_rel
    return DeviceChain(
        sorted_keys, perm, wprefix,
        child_cols,
        {a: jnp.asarray(c) for a, c in root_rel.columns.items()},
        jnp.asarray(js.root_weight_prefix, jnp.float32),
        edge_attrs,
        float(js.root_weight_total),
    )


def _inverse_cdf_pick(prefix: jnp.ndarray, lo, hi, u):
    """Weighted pick within [lo, hi) via prefix sums (vectorised)."""
    tot = prefix[hi] - prefix[lo]
    tgt = prefix[lo] + u * jnp.maximum(tot, 1e-30)
    pos = jnp.searchsorted(prefix, tgt, side="right") - 1
    pos = jnp.clip(pos, lo, jnp.maximum(hi - 1, lo))
    return pos, tot > 0


@functools.partial(jax.jit, static_argnames=("batch", "n_hops", "attrs",
                                              "edge_attrs"))
def _sample_chain(chain_flat, batch: int, n_hops: int, attrs: Tuple[str, ...],
                  edge_attrs: Tuple[str, ...], key: jax.Array):
    """One jitted batch of EW chain samples. Returns (rows, ok)."""
    (sorted_keys, perm, wprefix, child_cols, root_cols,
     root_wprefix) = chain_flat
    keys = jax.random.split(key, n_hops + 1)

    # root: inverse-CDF on the root weight prefix
    u0 = jax.random.uniform(keys[0], (batch,))
    n0 = root_wprefix.shape[0] - 1
    r_pos, ok = _inverse_cdf_pick(root_wprefix, jnp.zeros((batch,), jnp.int32),
                                  jnp.full((batch,), n0, jnp.int32), u0)
    rows = {a: c[r_pos] for a, c in root_cols.items()}

    for i in range(n_hops):
        ea = edge_attrs[i]
        q = rows[ea]
        lo = jnp.searchsorted(sorted_keys[i], q, side="left")
        hi = jnp.searchsorted(sorted_keys[i], q, side="right")
        u = jax.random.uniform(keys[i + 1], (batch,))
        pos, alive = _inverse_cdf_pick(wprefix[i], lo, hi, u)
        ok = ok & alive & (hi > lo)
        child_rows = perm[i][jnp.clip(pos, 0, perm[i].shape[0] - 1)]
        for a, c in child_cols[i].items():
            if a not in rows:
                rows[a] = c[child_rows]
    out = tuple(rows[a] for a in attrs)
    return out, ok


class JaxChainSampler:
    """Jitted EW sampler over a chain join (uniform, zero rejection)."""

    def __init__(self, cat: Catalog, spec: JoinSpec, seed: int = 0):
        self.spec = spec
        self.chain = build_device_chain(cat, spec)
        self.attrs = tuple(spec.output_attrs)
        self.n_hops = len(self.chain.sorted_keys)
        self.key = jax.random.PRNGKey(seed)
        self.total_weight = self.chain.total_weight

    def _flat(self):
        c = self.chain
        return (c.sorted_keys, c.perm, c.wprefix, c.child_cols, c.root_cols,
                c.root_wprefix)

    def sample_batch(self, batch: int) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        self.key, sub = jax.random.split(self.key)
        out, ok = _sample_chain(self._flat(), batch, self.n_hops, self.attrs,
                                tuple(self.chain.edge_attrs), sub)
        rows = {a: np.asarray(v) for a, v in zip(self.attrs, out)}
        return rows, np.asarray(ok)

    def sample_uniform(self, n: int, batch: int = 4096,
                       max_rounds: int = 1000) -> Dict[str, np.ndarray]:
        got: List[Dict[str, np.ndarray]] = []
        count = 0
        for _ in range(max_rounds):
            rows, ok = self.sample_batch(batch)
            idx = np.nonzero(ok)[0]
            if idx.shape[0]:
                got.append({a: c[idx] for a, c in rows.items()})
                count += idx.shape[0]
            if count >= n:
                break
        else:
            raise RuntimeError("JaxChainSampler: round budget exhausted")
        return {a: np.concatenate([g[a] for g in got])[:n] for a in got[0]}
