"""Mesh-partitioned catalog: row-range shards + hash-partitioned membership.

The third execution layer under the samplers (host numpy → device JAX →
sharded JAX).  A :class:`ShardedCatalog` partitions the columnar stores of a
union's relations across a 1-axis :class:`jax.sharding.Mesh`:

* **row-range shards** — each relation's rows are cut into ``world``
  contiguous ranges; the per-shard slices are placed on their devices as
  stacked ``P(axis)`` arrays (``columns_for``).  Dict-encodings (the
  per-attribute mixed-radix widths of the device engine) are *replicated*:
  every shard packs composite keys identically, so probes and fingerprints
  agree across shards.
* **replicated candidate roots** (:class:`ShardedTreeJoin`) — the per-join
  draw state (root weight prefix + payload columns, plus the non-root node
  indexes of the underlying
  :class:`~repro.core.backends.jax_backend.DeviceTreeJoin`) is broadcast to
  every shard, so each shard draws i.i.d. candidates from the *whole* join
  under its own fold-in key with zero communication — the exactness
  rationale is in the class docstring (root-*range* pieces would make the
  shard streams non-exchangeable and bias any fixed-shape consumption).
* **hash-partitioned membership** (:class:`ShardedMembership`) — the
  row-fingerprint space of every base relation is split by
  :func:`partition_of_fp32` (the 32-bit twin of
  :func:`repro.core.distributed.partition_of`): shard ``s`` owns and indexes
  only fingerprints with ``fp1 % world == s``.  A membership probe is
  resolved by the owner, which is why the sampler's round needs exactly one
  all-gather + one reduce-scatter exchange (see
  :class:`~repro.core.sharding.sampler.ShardedUnionSampler`).  Residual
  (§8.2 cycle-closing) relations of cyclic joins are base relations like
  any other here, so their fingerprints ride the same exchange; the
  residual *draw* state (sorted composite-key indexes) is replicated
  non-root node state of the underlying :class:`DeviceTreeJoin`, like every
  child index.

With ``world == 1`` every per-shard structure degenerates to the PR-1 device
engine's arrays bit for bit — the acceptance bar the equivalence tests pin.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..index import Catalog
from ..joins import JoinSpec
from ..relation import Relation
from ..backends.jax_backend import (DeviceTreeJoin, JaxBackend, _as_i32,
                                    fp32_np)

SHARD_AXIS = "shards"

_FP_PAD = np.uint32(0xFFFFFFFF)   # sort-stable pad; real hits are n-guarded


def make_sampler_mesh(world: Optional[int] = None,
                      axis: str = SHARD_AXIS) -> Mesh:
    """1-axis mesh over the first ``world`` local devices (default: all)."""
    devs = jax.devices()
    if world is None:
        world = len(devs)
    if world > len(devs):
        raise ValueError(
            f"requested {world} shards but only {len(devs)} devices are "
            "visible (set XLA_FLAGS=--xla_force_host_platform_device_count "
            "on CPU)")
    return Mesh(np.asarray(devs[:world]), (axis,))


def partition_of_fp32(fp1: np.ndarray, world: int) -> np.ndarray:
    """Shard ownership of 32-bit row fingerprints (device-engine twin of
    :func:`repro.core.distributed.partition_of`)."""
    return (np.asarray(fp1, np.uint32) % np.uint32(world)).astype(np.int64)


def _shard_put(mesh: Mesh, axis: str, arr: np.ndarray) -> jax.Array:
    """Place a stacked ``(world, ...)`` host array one row per device."""
    return jax.device_put(jnp.asarray(arr), NamedSharding(mesh, P(axis)))


def row_range_bounds(nrows: int, world: int) -> np.ndarray:
    """Balanced contiguous row-range bounds ``(world + 1,)``."""
    return np.linspace(0, nrows, world + 1).astype(np.int64)


# ---------------------------------------------------------------------------
# Per-join root partition (candidate generation side)
# ---------------------------------------------------------------------------


class ShardedTreeJoin:
    """One join's candidate-generation state laid out for the mesh.

    The root draw arrays (weight prefix + payload columns) are *replicated*:
    every shard draws i.i.d. from the **whole** join under its own fold-in
    key, so each shard's accepted stream is uniform over the full cover
    piece and any fixed-shape consumption order (prefix take, surplus
    banking) stays exactly uniform — the paper's independence guarantee
    makes the shard streams exchangeable.

    Why not partition the root rows?  A root-range shard draws candidates
    uniform over its *local* piece ``J_s`` only; with fixed per-shard batch
    shapes, every downstream consumption rule (take the first ``need``
    accepted, bank the rest) then over-represents whichever shards are
    consumed first, and correcting that exactly needs per-``(cover piece,
    shard)`` sizes no estimator provides.  Replicating the root is the
    classic broadcast side of a distributed join; the state that dominates
    memory at scale — the membership fingerprint indexes — *is* partitioned
    (:class:`ShardedMembership`), and relation stores row-range shard via
    :meth:`ShardedCatalog.columns_for`.  ``store_bounds`` records the root
    store's row-range ownership.
    """

    def __init__(self, tree: DeviceTreeJoin, mesh: Mesh, axis: str = SHARD_AXIS):
        self.tree = tree
        self.name = tree.name
        self.attrs = tree.attrs
        world = int(mesh.shape[axis])
        self.world = world
        self.mode = "replicated"
        n_root = tree.n_root
        self.store_bounds = row_range_bounds(n_root, world)
        wp32 = tree.host_root_wprefix.astype(np.float32)   # (n_root + 1,)
        prefix_stk = np.broadcast_to(wp32, (world, n_root + 1)).copy()
        cols_stk = {
            a: (np.broadcast_to(c, (world, n_root)).copy() if n_root
                else np.zeros((world, 1), dtype=np.int32))
            for a, c in tree.host_root_cols.items()}
        self.root_prefix = _shard_put(mesh, axis, prefix_stk)
        self.root_cols = {a: _shard_put(mesh, axis, c)
                          for a, c in cols_stk.items()}
        self.n_root = _shard_put(
            mesh, axis, np.full(world, n_root, dtype=np.int32))

    def is_empty(self) -> bool:
        return self.tree.is_empty()

    def state(self) -> Dict[str, object]:
        """Per-shard leaves for the sampler's ``shard_map`` inputs."""
        return {"prefix": self.root_prefix, "cols": self.root_cols,
                "n_root": self.n_root}


# ---------------------------------------------------------------------------
# Per-join hash-partitioned membership (cover-acceptance side)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _ShardedRelIndex:
    attrs: Tuple[str, ...]
    fp1: jax.Array          # (world, max_owned) uint32, sorted per shard
    fp2: jax.Array          # (world, max_owned) uint32, fp1 order
    n_owned: jax.Array      # (world,) int32
    kmax: int               # global duplicate window (>= any shard's)
    nrows: int


class ShardedMembership:
    """'Is tuple t in join J' with fingerprint hash-partition ownership.

    Mirrors :class:`~repro.core.backends.jax_backend.DeviceJoinMembership`
    (same fp32 arithmetic, same sorted-index + ``kmax`` duplicate-window
    probe) but each shard indexes only the row fingerprints it owns under
    :func:`partition_of_fp32`, so the total index memory is ``1/world`` per
    shard and a probe must be routed to the owner.  With ``world == 1`` the
    owned index equals the unsharded one exactly.
    """

    def __init__(self, spec: JoinSpec, mesh: Mesh, axis: str = SHARD_AXIS):
        self.join_name = spec.name
        world = int(mesh.shape[axis])
        self.world = world
        self.rels: List[_ShardedRelIndex] = []
        seen = set()
        for node in spec.nodes:
            rel = node.relation
            attrs = tuple(sorted(rel.attrs))
            if (rel.name, attrs) in seen:
                continue
            seen.add((rel.name, attrs))
            for a in attrs:
                _as_i32(rel.columns[a], f"{rel.name}.{a}")   # domain check
            fp1 = fp32_np([rel.columns[a] for a in attrs], salt=1)
            fp2 = fp32_np([rel.columns[a] for a in attrs], salt=2)
            owner = partition_of_fp32(fp1, world)
            owned1: List[np.ndarray] = []
            owned2: List[np.ndarray] = []
            kmax = 0
            for s in range(world):
                idx = np.nonzero(owner == s)[0]
                order = idx[np.argsort(fp1[idx], kind="stable")]
                s1 = fp1[order]
                if s1.shape[0]:
                    _, counts = np.unique(s1, return_counts=True)
                    kmax = max(kmax, int(counts.max()))
                owned1.append(s1)
                owned2.append(fp2[order])
            max_owned = max(max(c.shape[0] for c in owned1), 1)
            stk1 = np.full((world, max_owned), _FP_PAD, dtype=np.uint32)
            stk2 = np.zeros((world, max_owned), dtype=np.uint32)
            n_owned = np.zeros(world, dtype=np.int32)
            for s in range(world):
                n = owned1[s].shape[0]
                stk1[s, :n] = owned1[s]
                stk2[s, :n] = owned2[s]
                n_owned[s] = n
            self.rels.append(_ShardedRelIndex(
                attrs, _shard_put(mesh, axis, stk1),
                _shard_put(mesh, axis, stk2),
                _shard_put(mesh, axis, n_owned), kmax, int(rel.nrows)))

    def state(self) -> List[Dict[str, object]]:
        """Per-shard leaves for the sampler's ``shard_map`` inputs."""
        return [{"fp1": r.fp1, "fp2": r.fp2, "n_owned": r.n_owned}
                for r in self.rels]


# ---------------------------------------------------------------------------
# The catalog
# ---------------------------------------------------------------------------


class ShardedCatalog:
    """Mesh-partitioned stores + per-shard indexes for one union of joins.

    Wraps (or builds) a :class:`~repro.core.backends.jax_backend.JaxBackend`
    — its :class:`DeviceTreeJoin` child indexes and dict-encodings are the
    replicated part — and adds the per-shard partitions: weight-balanced root
    ranges per join and hash-partitioned membership per join.  Relation
    columnar stores are row-range sharded lazily via :meth:`columns_for`.
    """

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec],
                 mesh: Optional[Mesh] = None, axis: str = SHARD_AXIS,
                 backend: Optional[JaxBackend] = None,
                 join_method: str = "ew", seed: int = 0,
                 use_pallas: Optional[bool] = None):
        self.cat = cat
        self.joins = list(joins)
        self.mesh = mesh if mesh is not None else make_sampler_mesh(axis=axis)
        if axis not in self.mesh.axis_names:
            raise ValueError(f"mesh has no axis {axis!r}: {self.mesh}")
        self.axis = axis
        self.world = int(self.mesh.shape[axis])
        self.backend = backend if backend is not None else JaxBackend(
            cat, self.joins, join_method=join_method, seed=seed,
            use_pallas=use_pallas)
        self.attrs = list(self.backend.attrs)
        self.trees: Dict[str, ShardedTreeJoin] = {
            j.name: ShardedTreeJoin(self.backend.trees[j.name], self.mesh,
                                    axis)
            for j in self.joins}
        self.members: Dict[str, ShardedMembership] = {
            j.name: ShardedMembership(j, self.mesh, axis) for j in self.joins}
        self._col_cache: Dict[str, Dict[str, jax.Array]] = {}

    def shard_bounds(self, rel: Relation) -> np.ndarray:
        """Row-range ownership of one relation's store: ``(world + 1,)``."""
        return row_range_bounds(rel.nrows, self.world)

    def columns_for(self, rel: Relation) -> Dict[str, jax.Array]:
        """The relation's columnar store as ``(world, max_rows)`` device
        shards (row-range partition, zero-padded), one row-range per device."""
        if rel.name not in self._col_cache:
            b = self.shard_bounds(rel)
            max_rows = max(int((b[1:] - b[:-1]).max()), 1)
            shards = {}
            for a, c in rel.columns.items():
                stk = np.zeros((self.world, max_rows), dtype=np.int64)
                for s in range(self.world):
                    lo, hi = int(b[s]), int(b[s + 1])
                    stk[s, :hi - lo] = c[lo:hi]
                shards[a] = _shard_put(self.mesh, self.axis, stk)
            self._col_cache[rel.name] = shards
        return self._col_cache[rel.name]
