"""Sharded multi-device execution layer (third layer under the samplers).

``ShardedCatalog`` partitions a union's columnar stores across a 1-axis
:class:`jax.sharding.Mesh` (row-range shards, replicated dict-encodings,
hash-partitioned membership fingerprints); ``ShardedUnionSampler`` runs the
fused Algorithm-1 round inside ``shard_map`` with one fingerprint exchange
per round.  ``SetUnionSampler(backend="jax", mesh=...)`` is the facade entry
point.  See DESIGN.md ("Sharded execution layer").
"""

from __future__ import annotations

from .catalog import (SHARD_AXIS, ShardedCatalog, ShardedMembership,
                      ShardedTreeJoin, make_sampler_mesh, partition_of_fp32,
                      row_range_bounds)
from .sampler import ShardedUnionSampler
from .stats import merge_moment_stack, psum_counters, psum_merge_moments

__all__ = [
    "SHARD_AXIS", "ShardedCatalog", "ShardedMembership", "ShardedTreeJoin",
    "ShardedUnionSampler", "make_sampler_mesh", "merge_moment_stack",
    "partition_of_fp32", "psum_counters", "psum_merge_moments",
    "row_range_bounds",
]
