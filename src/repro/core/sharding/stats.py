"""On-mesh statistic merges — the associative algebra behind cross-shard
estimation.

Every statistic the estimation layer keeps (``RunningMean`` on host,
``DeviceRunning`` on device) is a moment triple ``(count, mean, M2)`` whose
merge is associative (Chan et al.); the same structure lets wander-join
statistics from many shards combine into one global estimate with a single
``psum``.  :func:`psum_merge_moments` is the collective form used inside
``shard_map`` (see :class:`repro.core.estimators.jax_estimator.JaxEstimator`
with ``mesh=``), :func:`merge_moment_stack` the host-side reference the tests
compare against (and :func:`repro.core.distributed.merge_statistics`'s device
twin).

Counters have the same algebra with a plain sum: :func:`psum_counters` merges
per-shard ``SamplerStats``-style counter vectors across the mesh — the
on-device analogue of :meth:`repro.core.union_sampler.SamplerStats.merge`.
The sharded union loop itself derives its global counters from the one
``all_gather`` its water-filling banking already performs (DESIGN.md §4a), so
it needs no second collective; ``psum_counters`` is the standalone form for
programs where only counters cross the mesh.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Moments = Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]   # (count, mean, M2)


def psum_merge_moments(n: jnp.ndarray, mean: jnp.ndarray, m2: jnp.ndarray,
                       axis_name: str) -> Moments:
    """Merge per-shard Welford moments across a mesh axis in one ``psum``.

    Uses the pooled-moments identity
    ``M2 = Σ_s M2_s + Σ_s n_s (mean_s - mean)²`` — algebraically identical to
    folding the shards sequentially with Chan's merge, but order-free and a
    single collective.  Call inside ``shard_map``; every shard returns the
    same merged triple.
    """
    nf = n.astype(jnp.float32)
    total = jax.lax.psum(n, axis_name)
    totalf = jnp.maximum(total.astype(jnp.float32), 1.0)
    gmean = jax.lax.psum(nf * mean, axis_name) / totalf
    gm2 = jax.lax.psum(m2 + nf * (mean - gmean) ** 2, axis_name)
    return total, gmean, gm2


def psum_counters(vec: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Merge per-shard int counter vectors across a mesh axis (one ``psum``).

    Counter merges are plain sums (associative and order-free), so the
    collective form is trivial — this exists so callers state the intent
    (``SamplerStats``-vector merge) rather than a bare ``psum``, mirroring
    :func:`psum_merge_moments` for the moment triples.
    """
    return jax.lax.psum(vec, axis_name)


def merge_moment_stack(n: jnp.ndarray, mean: jnp.ndarray, m2: jnp.ndarray
                       ) -> Moments:
    """Host/jit reference: merge stacked per-shard moments ``(world,)`` → one.

    Same pooled-moments identity as :func:`psum_merge_moments` with the
    ``psum`` replaced by an axis-0 sum, so tests can check the collective
    against an explicit all-gather + merge.
    """
    nf = n.astype(jnp.float32)
    total = jnp.sum(n)
    totalf = jnp.maximum(total.astype(jnp.float32), 1.0)
    gmean = jnp.sum(nf * mean) / totalf
    gm2 = jnp.sum(m2 + nf * (mean - gmean) ** 2)
    return total, gmean, gm2
