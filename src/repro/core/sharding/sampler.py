"""Mesh-sharded Algorithm-1 rounds: the fused union loop under ``shard_map``.

:class:`ShardedUnionSampler` scales the fused device engine
(:class:`~repro.core.backends.jax_backend.JaxUnionSampler`) across a 1-axis
device mesh.  One round, per shard:

1. **replicated cover selection** — every shard derives the same per-slot
   categorical picks from the shared round key and histograms them into the
   global per-piece targets (no communication; the histogram covers all
   global slots of the round),
2. **local candidate draws** — each shard draws its per-join batch of
   i.i.d. EW tree candidates from the *whole* join under its own fold-in
   key (replicated roots — see
   :class:`~repro.core.sharding.catalog.ShardedTreeJoin` for why root-range
   pieces would bias fixed-shape consumption); cyclic joins run the §8.2
   skeleton draw + residual-edge verification entirely inside this local
   step (residual sorted-key indexes are replicated non-root node state,
   like every other child index),
3. **one fingerprint exchange** — earlier-piece membership probes are
   resolved by hash-partition ownership: all shards ``all_gather`` the
   candidates' per-relation fingerprints, the owner shard answers each
   probe against its local sorted index, and one ``psum_scatter``
   (reduce-scatter) ORs the owner verdicts and hands each shard exactly its
   own candidates' segment.  Residual relations are ordinary base relations
   of their join, so their row fingerprints are hash-partitioned and ride
   this same exchange — cyclic cover pieces add **zero** extra collectives,
4. **local compaction** — accepted candidates are rank-scattered to the
   front of each shard's ``(B_j, A+1)`` row matrix (attributes + home
   piece id), exactly like the unsharded engine.

With ``fused_rounds="device"`` (default) the *entire multi-round loop* runs
inside one ``shard_map``'d ``lax.while_loop`` program: per-shard ring-buffer
surplus banks, the global shortfall vector and dead-piece flags as
replicated carry, and one extra (tiny) ``all_gather`` of the per-shard
``(count, accepted, ok, residual)`` matrices per round from which **every**
shard computes the same global water-filling allocation — which shard
serves how much of each piece's target from bank and fresh rows — plus its
own rows' global output offsets, with no further collectives.  Each shard
scatters its rows directly to their final global positions in a private
output buffer; the host ORs the disjoint buffers once per ``sample(n)``
call.  ``fused_rounds="host"`` drives the same shard_map'd round program
from the inherited host loop (one sync per round) for parity testing.

Exactness: each emitted sample is an i.i.d. ``1/|U|`` draw — the same
argument as the unsharded engine, because every shard's candidates are
i.i.d. uniform over the whole join, so their cover-accepted subsequences
are i.i.d. uniform over the cover piece, exchangeable across shards, and
any deterministic consumption order (shard-major water filling, per-shard
FIFO banking) is unbiased.  With a 1-device mesh both modes degenerate to
the unsharded programs op-for-op, which the equivalence tests pin bit for
bit against ``JaxUnionSampler``.  With ``world > 1`` the device loop's
banking is per-shard FIFO (capacity ``surplus_cap // world`` each) while
the host-mode twin banks globally — both unbiased by exchangeability, but
only ``world == 1`` is bit-identical across the two modes once banks are
exercised.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..backends.jax_backend import (PIECE_STAT_FIELDS, _STAT_FIELDS,
                                    JaxUnionSampler, _cover_cum,
                                    _emit_and_bank, _piece_batches, fp32_jnp)
from .. import planner
from .catalog import ShardedCatalog


def _window_probe(s1, s2, n_own, qq1, qq2, kmax: int):
    """Sorted-fingerprint probe with a static duplicate window (per shard)."""
    lo = jnp.searchsorted(s1, qq1, side="left")
    m = jnp.zeros(qq1.shape, bool)
    cap = s1.shape[0]
    for k in range(kmax):       # duplicate window (tiny, static)
        pos = jnp.minimum(lo + k, cap - 1)
        m = m | ((lo + k < n_own) & (s1[pos] == qq1) & (s2[pos] == qq2))
    return m


class ShardedUnionSampler(JaxUnionSampler):
    """Algorithm-1 top-up rounds over a device mesh.

    ``round_batch`` is the *per-shard* selection-slot budget; the global
    round capacity is ``world * round_batch`` and per-join draw batches are
    cover-balanced per shard (``world ×`` the unsharded schedule).  The
    host-loop twin (selection carry, global surplus banking, dead-piece
    detection, final shuffle) is inherited unchanged from
    :class:`JaxUnionSampler`; the device mode replaces the whole loop with
    the ``shard_map``'d persistent program built here.
    """

    def __init__(self, scat: ShardedCatalog, cover, seed: int = 0,
                 round_batch: int = 4096, dead_rounds: int = 8,
                 max_rounds: int = 4096, surplus_cap: Optional[int] = None,
                 stats=None, fused_rounds: str = "device",
                 balance: str = "cover", balance_slack: float = 1.5,
                 predicate=None, plan: str = "static"):
        self.scat = scat
        self.mesh = scat.mesh
        self.saxis = scat.axis
        self.world = scat.world
        self.shard_batch = int(round_batch)
        super().__init__(scat.backend, cover, seed=seed,
                         round_batch=self.shard_batch * self.world,
                         dead_rounds=dead_rounds, max_rounds=max_rounds,
                         surplus_cap=surplus_cap, stats=stats,
                         fused_rounds=fused_rounds, balance=balance,
                         balance_slack=balance_slack, predicate=predicate,
                         plan=plan)
        # per-shard cover-balanced draw widths; the global schedule (used by
        # the stats accounting) is world× that, and collapses to the
        # unsharded schedule on a 1-device mesh (bitwise-parity pin)
        base = np.maximum(np.asarray(cover.selection_probs(), np.float64), 0)
        self.shard_piece_batches = _piece_batches(
            base, self.shard_batch, balance, balance_slack)
        if self.plan == "adaptive":
            # demand-matched widths per shard (same rule as the unsharded
            # engine at shard granularity), so the world× global schedule
            # stays an exact multiple of the per-shard draw widths and
            # collapses to the unsharded one on a 1-device mesh
            self.shard_piece_batches = planner.alloc_batches(
                self.shard_piece_batches, base,
                planner.seed_rates(cover, self._tree_specs())[:, 0],
                planner.adaptive_slot(self.shard_batch))
        self.piece_batches = tuple(self.world * b
                                   for b in self.shard_piece_batches)
        # the planner constants derive from piece_batches, which this
        # subclass just rescaled — rebuild them on the global schedule
        self._setup_planner()
        self.strees = [scat.trees[n] for n in self.order]
        self.smems = [scat.members[n] for n in self.order]
        self._dtrees = [t.tree for t in self.strees]
        self._state = {"roots": [t.state() for t in self.strees],
                       "mem": [m.state() for m in self.smems]}
        # flat probe plan: (join j, earlier piece q, relation ridx, ...)
        self._probe_plan: List[Tuple[int, int, int, Tuple[str, ...], int]] = []
        for j in range(len(self.order)):
            for q in range(j):
                for ridx, r in enumerate(self.smems[q].rels):
                    self._probe_plan.append((j, q, ridx, r.attrs, r.kmax))
        self._round_prog = self._build_round_prog()
        self._round_jit = self._sharded_round      # host-loop entry point

    # -- device-input hook ----------------------------------------------------
    def _ensure_device_inputs(self) -> None:
        """No-op: the sharded engine's tree/membership state is prebuilt in
        ``self._state`` (hash-partitioned device arrays), so nothing lazy
        may leak into a trace."""

    # -- the shard-local round core (traceable) -------------------------------
    def _shard_round_core(self, key: jax.Array, probs_cum, carry_need,
                          extra_target, st, sid, ema=None, gcount=None):
        """One round on one shard: replicated picks, local draws, the
        fingerprint exchange, local acceptance + matrix compaction.

        Returns ``(mats, okc, resc, accc, predc, need)`` where ``mats[j]``
        is this shard's accepted-compacted ``(B_j, A+1)`` row matrix and the
        count vectors are per-shard; ``need`` is the replicated global
        target.  Under ``plan="adaptive"`` the replicated EMAs and global
        bank occupancy come in, the replicated **global** budget goes out as
        a seventh element, and each shard draws its near-equal split of it.
        """
        nj = len(self.order)
        world = self.world
        adaptive = self.plan == "adaptive"
        bs = self.shard_piece_batches
        kpick, *jks = jax.random.split(key, nj + 1)
        # (1) replicated multinomial cover selection over all global slots
        u = jax.random.uniform(kpick, (self._slot_width,))
        pick = jnp.clip(jnp.searchsorted(probs_cum, u, side="right"
                                         ).astype(jnp.int32), 0, nj - 1)
        valid = (jnp.arange(self._slot_width)
                 < extra_target).astype(jnp.int32)
        need = carry_need + jnp.zeros((nj,), jnp.int32).at[pick].add(valid)
        gbudget = bshard = None
        if adaptive:
            # replicated global budget from replicated counts (no
            # collectives), split across shards so the per-shard shares sum
            # exactly to the global budget; world=1 degenerates to the
            # unsharded budget bit for bit
            gbudget = planner.budget_for(
                need, gcount, ema[:, 0],
                jnp.asarray(self._pbatch_i32), self._drain_w, jnp)
            bshard = (gbudget // world
                      + (sid < (gbudget % world)).astype(jnp.int32))

        # (2) local i.i.d. whole-join draws (replicated roots, per-shard
        # fold-in keys; §8.2 residual edges verify locally — their sorted
        # indexes are replicated non-root node state)
        rows_j, ok_j, wok_j = [], [], []
        for j in range(nj):
            rst = st["roots"][j]
            prefix = rst["prefix"][0]
            cols = {a: c[0] for a, c in rst["cols"].items()}
            kd = (jks[j] if world == 1          # bit-for-bit unsharded
                  else jax.random.fold_in(jks[j], sid))
            rows, ok, wok = self._dtrees[j].draw_with_root(
                kd, bs[j], prefix, cols, rst["n_root"][0])
            if bshard is not None:
                elig = jnp.arange(bs[j]) < bshard[j]
                ok = ok & elig
                wok = wok & elig
            rows_j.append(rows)
            ok_j.append(ok)
            wok_j.append(wok)

        # (3) one fingerprint exchange answers every earlier-piece probe
        found = self._exchange_probes(rows_j, st, sid)

        # (4) local acceptance (fused §8.3 predicate mask first) +
        # rank-scatter compaction (home id rides as the last matrix column,
        # exactly like the unsharded round)
        mats, okc, resc, accc, predc = [], [], [], [], []
        p = 0
        for j in range(nj):
            acc = ok_j[j]
            resc.append(jnp.sum(wok_j[j]) - jnp.sum(acc))
            pf = self._pred_fns[j]
            if pf is None:
                predc.append(jnp.int32(0))
            else:
                pok = pf(rows_j[j])
                predc.append(jnp.sum(acc & ~pok).astype(jnp.int32))
                acc = acc & pok
            for q in range(j):
                contained = jnp.ones((bs[j],), bool)
                for _ in range(len(self.smems[q].rels)):
                    contained = contained & found[p][: bs[j]]
                    p += 1
                # a rejection-predicate piece q contains the candidate only
                # if its own reject_preds also hold (the union-wide
                # predicate is excluded: candidates already passed it)
                cpf = self._cont_pred_fns[q]
                if cpf is not None:
                    contained = contained & cpf(rows_j[j])
                acc = acc & ~contained
            dst = jnp.where(acc, jnp.cumsum(acc) - 1, bs[j])
            mat = jnp.stack([rows_j[j][a].astype(jnp.int32)
                             for a in self.attrs]
                            + [jnp.full(bs[j], j, jnp.int32)], axis=1)
            mats.append(jnp.zeros((bs[j], mat.shape[1]), jnp.int32)
                        .at[dst].set(mat, mode="drop"))
            okc.append(jnp.sum(wok_j[j]))
            accc.append(jnp.sum(acc))
        out = (mats, jnp.stack(okc).astype(jnp.int32),
               jnp.stack(resc).astype(jnp.int32),
               jnp.stack(accc).astype(jnp.int32),
               jnp.stack(predc).astype(jnp.int32), need)
        if adaptive:
            out = out + (gbudget.astype(jnp.int32),)
        return out

    def _exchange_probes(self, rows_j, st, sid):
        """All earlier-piece membership probes in one collective exchange.

        ``world == 1`` degenerates to fully local probes (no collectives,
        bit-equal to :meth:`DeviceJoinMembership.contains`).  Otherwise the
        per-join probe vectors are padded to the widest draw batch so one
        ``all_gather`` + one ``psum_scatter`` covers every (join, earlier
        piece, relation) triple; pad verdicts are sliced off before use.
        """
        # named scope: the exchange shows up as one block in profiler traces
        # (jax.named_scope is trace-time metadata — zero runtime cost)
        with jax.named_scope("fingerprint_exchange"):
            return self._exchange_probes_impl(rows_j, st, sid)

    def _exchange_probes_impl(self, rows_j, st, sid):
        plan = self._probe_plan
        if not plan:
            return []
        world, axis = self.world, self.saxis
        if world == 1:
            out = []
            for (j, q, ridx, attrs, kmax) in plan:
                mst = st["mem"][q][ridx]
                out.append(_window_probe(
                    mst["fp1"][0], mst["fp2"][0], mst["n_owned"][0],
                    fp32_jnp([rows_j[j][a] for a in attrs], salt=1),
                    fp32_jnp([rows_j[j][a] for a in attrs], salt=2),
                    kmax))
            return out
        bs = self.shard_piece_batches
        bmax = max(bs[j] for (j, _q, _r, _a, _k) in plan)

        def padded(vec):
            if vec.shape[0] == bmax:
                return vec
            return jnp.concatenate(
                [vec, jnp.zeros((bmax - vec.shape[0],), vec.dtype)])

        q1 = jnp.stack([padded(fp32_jnp([rows_j[j][a] for a in attrs],
                                        salt=1))
                        for (j, q, ridx, attrs, kmax) in plan])
        q2 = jnp.stack([padded(fp32_jnp([rows_j[j][a] for a in attrs],
                                        salt=2))
                        for (j, q, ridx, attrs, kmax) in plan])
        n_probe = len(plan)
        gn = world * bmax
        g1 = jnp.transpose(jax.lax.all_gather(q1, axis),
                           (1, 0, 2)).reshape(n_probe, gn)
        g2 = jnp.transpose(jax.lax.all_gather(q2, axis),
                           (1, 0, 2)).reshape(n_probe, gn)
        hits = []
        for pi, (j, q, ridx, attrs, kmax) in enumerate(plan):
            mst = st["mem"][q][ridx]
            m = _window_probe(mst["fp1"][0], mst["fp2"][0],
                              mst["n_owned"][0], g1[pi], g2[pi], kmax)
            # only the fp owner may answer (hash-partition ownership)
            m = m & ((g1[pi] % jnp.uint32(world)).astype(jnp.int32) == sid)
            hits.append(m.astype(jnp.int32))
        scat = jax.lax.psum_scatter(jnp.stack(hits), axis,
                                    scatter_dimension=1, tiled=True)
        return [scat[pi] > 0 for pi in range(n_probe)]

    # -- host-mode round program (fused_rounds="host") ------------------------
    def _build_round_prog(self):
        mesh, axis = self.mesh, self.saxis
        adaptive = self.plan == "adaptive"

        if adaptive:
            def round_fn(probs_base, dead, carry_need, extra_target, key,
                         st, ema, gcount):
                sid = jax.lax.axis_index(axis)
                probs_cum, bad = _cover_cum(probs_base, dead)
                mats, okc, resc, accc, predc, need, gb = \
                    self._shard_round_core(key, probs_cum, carry_need,
                                           extra_target, st, sid, ema,
                                           gcount)
                return ([m[None] for m in mats], okc[None], resc[None],
                        accc[None], predc[None], need[None], gb[None],
                        bad[None])

            in_specs = (P(), P(), P(), P(), P(), P(axis), P(), P())
        else:
            def round_fn(probs_base, dead, carry_need, extra_target, key,
                         st):
                sid = jax.lax.axis_index(axis)
                probs_cum, bad = _cover_cum(probs_base, dead)
                mats, okc, resc, accc, predc, need = self._shard_round_core(
                    key, probs_cum, carry_need, extra_target, st, sid)
                return ([m[None] for m in mats], okc[None], resc[None],
                        accc[None], predc[None], need[None], bad[None])

            in_specs = (P(), P(), P(), P(), P(), P(axis))

        return jax.jit(shard_map(
            round_fn, mesh=mesh,
            in_specs=in_specs,
            out_specs=P(axis), check_rep=False))

    def _sharded_round(self, probs_base, dead, carry_need, extra_target,
                       key, ema=None, bank_count=None):
        """Run one mesh round; adapt it to the host-loop contract.

        ``cols[j]``'s first ``accc[j]`` rows are the accepted rows in
        shard-major order — the same consumption order the device loop's
        water-filling allocation uses for fresh rows.  ``bank_count`` under
        ``plan="adaptive"`` is the host loop's *global* bank occupancy — the
        same quantity the device loop carries replicated as ``gcount``.
        """
        budget = None
        if self.plan == "adaptive":
            (mats, okc, resc, accc, predc, need, budget,
             bad) = self._round_prog(
                probs_base, dead, carry_need, extra_target, key,
                self._state, ema, bank_count)
            budget = np.asarray(budget)[0]
        else:
            mats, okc, resc, accc, predc, need, bad = self._round_prog(
                probs_base, dead, carry_need, extra_target, key, self._state)
        okc = np.asarray(okc)
        resc = np.asarray(resc)
        accc = np.asarray(accc)                     # (world, nj)
        predc = np.asarray(predc)
        cols: List[np.ndarray] = []
        a1 = len(self.attrs) + 1
        for j in range(len(self.order)):
            m = np.asarray(mats[j])                 # (world, B_j, A+1)
            if self.world == 1:
                cols.append(m[0])
                continue
            g = np.zeros((self.world * m.shape[1], a1), np.int32)
            pos = 0
            for s in range(self.world):
                a = int(accc[s, j])
                g[pos:pos + a] = m[s, :a]
                pos += a
            cols.append(g)
        out = (cols, okc.sum(axis=0), resc.sum(axis=0), accc.sum(axis=0),
               predc.sum(axis=0), np.asarray(need)[0])
        if budget is not None:
            out = out + (budget,)
        return out + (bool(np.asarray(bad)[0]),)

    # -- the persistent device loop (fused_rounds="device") -------------------
    def _init_state(self):
        nj = len(self.order)
        cap = max(1, self.surplus_cap // self.world)
        st = {
            "key": self.key,
            "owed": jnp.zeros(nj, jnp.int32),
            "dead": jnp.zeros(nj, dtype=bool),
            "streak": jnp.zeros(nj, jnp.int32),
            "bank": jnp.zeros((self.world, nj, cap, len(self.attrs) + 1),
                              jnp.int32),
            "bank_head": jnp.zeros((self.world, nj), jnp.int32),
            "bank_count": jnp.zeros((self.world, nj), jnp.int32),
        }
        if self.plan == "adaptive":
            st["ema"] = jnp.asarray(self._ema_seed)
            # replicated global bank occupancy at round start (the per-shard
            # counts are sharded carry, so the budget reads this instead)
            st["gcount"] = jnp.zeros(nj, jnp.int32)
        return st

    def _out_buffer(self, C: int):
        """Per-shard output buffers: each shard scatters its rows at their
        final global positions; the disjoint buffers merge by summation."""
        return jnp.zeros((self.world, C, len(self.attrs) + 1), jnp.int32)

    def _merge_out(self, out) -> np.ndarray:
        arr = np.asarray(out)
        return arr[0] if self.world == 1 else arr.sum(axis=0)

    def _build_loop(self, C: int):
        mesh, axis, world = self.mesh, self.saxis, self.world
        cap = max(1, self.surplus_cap // world)
        W = min(self._drain_w, cap)
        bt = int(sum(self.piece_batches))
        adaptive = self.plan == "adaptive"
        max_rounds = jnp.int32(self.max_rounds)
        dead_rounds = jnp.int32(self.dead_rounds)
        st_global = self._state

        pbatch = jnp.asarray(self.piece_batches, jnp.int32)
        shifts = jnp.asarray(self._ema_shifts)

        def loop_fn(shr, rep, out, n, probs_base, st):
            self._trace_events.append(("loop", C, self.plan))
            sid = jax.lax.axis_index(axis)

            def cond(c):
                total, rounds, fail = c[8], c[9], c[10]
                return (total < n) & (rounds < max_rounds) & ~fail

            def body(c):
                (key, owed, dead, streak, bank, head, count, out,
                 total, rounds, fail, stats, pstats) = c[:13]
                probs_cum, bad = _cover_cum(probs_base, dead)
                key2, kround = jax.random.split(key)
                extra = jnp.clip(n - total - jnp.sum(owed),
                                 0, self._slot_width)
                if adaptive:
                    ema, gcount = c[13], c[14]
                    (mats, okc_s, resc_s, accc_s, predc_s, need,
                     gb) = self._shard_round_core(
                        kround, probs_cum, owed, extra, st, sid, ema,
                        gcount)
                else:
                    gb = None
                    (mats, okc_s, resc_s, accc_s, predc_s,
                     need) = self._shard_round_core(
                        kround, probs_cum, owed, extra, st, sid)
                # one tiny exchange: per-shard (bank count, accepted, ok,
                # residual, predicate-reject) matrices — every shard then
                # computes the same global water-filling allocation AND its
                # own rows' global output offsets with no further collectives
                gat = jax.lax.all_gather(
                    jnp.stack([count, accc_s, okc_s, resc_s, predc_s]), axis)
                counts_w, acc_w = gat[:, 0], gat[:, 1]     # (world, nj)
                okg = jnp.sum(gat[:, 2])
                resg = jnp.sum(gat[:, 3])
                predg = jnp.sum(gat[:, 4])
                accg_v = jnp.sum(acc_w, axis=0)            # (nj,) global
                tot_count = jnp.sum(counts_w, axis=0)
                # bank take (FIFO, capped) → fresh take → carried shortfall
                dtg = jnp.minimum(jnp.minimum(need, tot_count),
                                  self._drain_w)
                ftg = jnp.minimum(need - dtg, accg_v)
                # shard-major water filling: shard s serves the slice of the
                # global take that lands in its segment of the prefix sums
                cpref = jnp.cumsum(counts_w, axis=0) - counts_w
                dt_w = jnp.clip(dtg[None] - cpref, 0, counts_w)
                apref = jnp.cumsum(acc_w, axis=0) - acc_w
                ft_w = jnp.clip(ftg[None] - apref, 0, acc_w)
                takeg = dtg + ftg
                seg = total + jnp.cumsum(takeg) - takeg
                bank_base = seg + (jnp.cumsum(dt_w, axis=0) - dt_w)[sid]
                fresh_base = (seg + dtg
                              + (jnp.cumsum(ft_w, axis=0) - ft_w)[sid])
                out2, _, bank2, head2, count2 = _emit_and_bank(
                    out, total, bank, head, count, mats,
                    dt_w[sid], ft_w[sid], accc_s, cap, C, W,
                    bank_base=bank_base, fresh_base=fresh_base)
                total2 = total + jnp.sum(takeg)
                # global post-round bank occupancy for the dead-piece rules
                # (derivable on every shard from the gathered matrices)
                push_w = jnp.minimum(acc_w - ft_w,
                                     cap - (counts_w - dt_w))
                countg2 = jnp.sum(counts_w - dt_w + push_w, axis=0)
                shortfall = need - dtg - ftg
                dropped = jnp.sum(jnp.where(dead, shortfall, 0))
                shortfall = jnp.where(dead, 0, shortfall)
                trig = (shortfall > 0) & (accg_v == 0) & (countg2 == 0)
                streak2 = jnp.where(dead, streak,
                                    jnp.where(trig, streak + 1, 0))
                newly = ~dead & (streak2 >= dead_rounds)
                dropped = dropped + jnp.sum(jnp.where(newly, shortfall, 0))
                shortfall = jnp.where(newly, 0, shortfall)
                drawn = jnp.sum(gb) if adaptive else jnp.int32(bt)
                stats2 = stats + jnp.stack(
                    [drawn.astype(jnp.int32), drawn.astype(jnp.int32),
                     (okg - resg - predg - jnp.sum(accg_v))
                     .astype(jnp.int32),
                     resg.astype(jnp.int32),
                     predg.astype(jnp.int32),
                     dropped.astype(jnp.int32)])
                pstats2 = jnp.stack(
                    [pstats[:, 0] + (gb if adaptive else pbatch),
                     pstats[:, 1] + accg_v.astype(jnp.int32),
                     pstats[:, 2] + jnp.sum(gat[:, 3], axis=0)
                                       .astype(jnp.int32),
                     pstats[:, 3] + dtg.astype(jnp.int32),
                     jnp.maximum(pstats[:, 4], countg2.astype(jnp.int32))],
                    axis=1)
                nxt = (key2, shortfall.astype(jnp.int32), dead | newly,
                       streak2.astype(jnp.int32), bank2,
                       head2.astype(jnp.int32), count2.astype(jnp.int32),
                       out2, total2, rounds + 1, fail | bad, stats2,
                       pstats2)
                if adaptive:
                    # EMA step from the already-gathered global counts —
                    # zero extra collectives; the post-round global bank
                    # occupancy doubles as next round's budget input
                    okg_v = jnp.sum(gat[:, 2], axis=0)
                    resg_v = jnp.sum(gat[:, 3], axis=0)
                    predg_v = jnp.sum(gat[:, 4], axis=0)
                    counts4 = jnp.stack(
                        [accg_v, okg_v, resg_v, predg_v],
                        axis=1).astype(jnp.int32)
                    ema2 = planner.ema_update(ema, gb, counts4, shifts, jnp)
                    nxt = nxt + (ema2, countg2.astype(jnp.int32))
                return nxt

            init = (rep["key"], rep["owed"], rep["dead"], rep["streak"],
                    shr["bank"][0], shr["bank_head"][0],
                    shr["bank_count"][0], out[0],
                    jnp.int32(0), jnp.int32(0), jnp.bool_(False),
                    jnp.zeros(len(_STAT_FIELDS), jnp.int32),
                    jnp.zeros((len(self.order), len(PIECE_STAT_FIELDS)),
                              jnp.int32))
            if adaptive:
                init = init + (rep["ema"], rep["gcount"])
            fin = jax.lax.while_loop(cond, body, init)
            (key, owed, dead, streak, bank, head, count, out2,
             total, rounds, fail, stats, pstats) = fin[:13]
            rep2 = {"key": key[None], "owed": owed[None],
                    "dead": dead[None], "streak": streak[None]}
            if adaptive:
                rep2["ema"] = fin[13][None]
                rep2["gcount"] = fin[14][None]
            return ({"bank": bank[None], "bank_head": head[None],
                     "bank_count": count[None]},
                    rep2,
                    out2[None], total[None], rounds[None], fail[None],
                    stats[None], pstats[None])

        shr_spec = {"bank": P(axis), "bank_head": P(axis),
                    "bank_count": P(axis)}
        rep_keys = ("key", "owed", "dead", "streak")
        if adaptive:
            rep_keys = rep_keys + ("ema", "gcount")
        rep_spec = {k: P() for k in rep_keys}
        prog = jax.jit(shard_map(
            loop_fn, mesh=mesh,
            in_specs=(shr_spec, rep_spec, P(axis), P(), P(), P(axis)),
            out_specs=P(axis), check_rep=False),
            donate_argnums=(0, 2))

        def run(state, out, n, probs_base):
            shr = {k: state[k] for k in ("bank", "bank_head", "bank_count")}
            rep = {k: state[k] for k in rep_keys}
            shr2, rep2, out2, total, rounds, fail, stats, pstats = prog(
                shr, rep, out, n, probs_base, st_global)
            state2 = dict(shr2)
            state2.update({k: v[0] for k, v in rep2.items()})
            return (state2, out2, total[0], rounds[0], fail[0], stats[0],
                    pstats[0])

        # expose the jitted program and its arg plumbing so the static
        # analyzer (repro.analysis.jaxpr_audit) can lower it without running
        run._prog = prog
        run._rep_keys = rep_keys
        run._st_global = st_global
        return run
