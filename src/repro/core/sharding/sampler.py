"""Mesh-sharded Algorithm-1 rounds: the fused union round under ``shard_map``.

:class:`ShardedUnionSampler` scales the PR-1 fused device round
(:class:`~repro.core.backends.jax_backend.JaxUnionSampler`) across a 1-axis
device mesh.  One round, per shard:

1. **replicated cover selection** — every shard derives the same per-slot
   categorical picks from the shared round key and histograms them into the
   global per-piece targets (no communication; the histogram covers all
   ``world × round_batch`` slots of the round),
2. **local candidate draws** — each shard draws ``round_batch`` i.i.d. EW
   tree candidates per join from the *whole* join under its own fold-in key
   (replicated roots — see
   :class:`~repro.core.sharding.catalog.ShardedTreeJoin` for why root-range
   pieces would bias fixed-shape consumption); cyclic joins run the §8.2
   skeleton draw + residual-edge verification entirely inside this local
   step (residual sorted-key indexes are replicated non-root node state,
   like every other child index),
3. **one fingerprint exchange** — earlier-piece membership probes are
   resolved by hash-partition ownership: all shards ``all_gather`` the
   candidates' per-relation fingerprints, the owner shard answers each
   probe against its local sorted index, and one ``psum_scatter``
   (reduce-scatter) ORs the owner verdicts and hands each shard exactly its
   own candidates' segment (the only collectives in the round).  Residual
   relations are ordinary base relations of their join, so their row
   fingerprints are hash-partitioned and ride this same exchange — cyclic
   cover pieces add **zero** extra collectives,
4. **local compaction** — accepted candidates are sorted to the front per
   shard; per-shard accepted counts return to the host, which merges
   shortfall/surplus banking exactly as the unsharded engine does (the
   per-piece shortfall is global, so the banked-surplus invariants carry
   over unchanged).

Exactness: each emitted sample is an i.i.d. ``1/|U|`` draw — the same
argument as the unsharded engine, because every shard's candidates are
i.i.d. uniform over the whole join, so their cover-accepted subsequences
are i.i.d. uniform over the cover piece, exchangeable across shards, and
any deterministic consumption order (shard-major prefix take, banking) is
unbiased.  With a 1-device mesh the program degenerates to the unsharded
round op-for-op, which the equivalence tests pin bit-for-bit against
``JaxUnionSampler``.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..backends.jax_backend import JaxUnionSampler, fp32_jnp
from .catalog import ShardedCatalog


class ShardedUnionSampler(JaxUnionSampler):
    """Algorithm-1 top-up rounds over a device mesh.

    ``round_batch`` is the *per-shard* candidate budget; the global round
    capacity is ``world * round_batch``.  The host loop (selection carry,
    surplus banking, dead-piece detection, final shuffle) is inherited
    unchanged from :class:`JaxUnionSampler` — only the round program is
    replaced by the ``shard_map``'d version.
    """

    def __init__(self, scat: ShardedCatalog, cover, seed: int = 0,
                 round_batch: int = 4096, dead_rounds: int = 8,
                 max_rounds: int = 4096, surplus_cap: Optional[int] = None,
                 stats=None):
        self.scat = scat
        self.mesh = scat.mesh
        self.saxis = scat.axis
        self.world = scat.world
        self.shard_batch = int(round_batch)
        super().__init__(scat.backend, cover, seed=seed,
                         round_batch=self.shard_batch * self.world,
                         dead_rounds=dead_rounds, max_rounds=max_rounds,
                         surplus_cap=surplus_cap, stats=stats)
        self.strees = [scat.trees[n] for n in self.order]
        self.smems = [scat.members[n] for n in self.order]
        self._state = {"roots": [t.state() for t in self.strees],
                       "mem": [m.state() for m in self.smems]}
        self._round_prog = self._build_round_prog()
        self._round_jit = self._sharded_round      # host-loop entry point

    # -- the shard_map'd round ------------------------------------------------
    def _build_round_prog(self):
        mesh, axis, world = self.mesh, self.saxis, self.world
        nj = len(self.order)
        B = self.shard_batch
        GB = self.round_batch                       # world * B (global slots)
        dtrees = [t.tree for t in self.strees]      # replicated child indexes
        out_attrs = self.attrs
        # flat probe plan: (join j, earlier piece q, relation ridx)
        plan: List[Tuple[int, int, int, Tuple[str, ...], int]] = []
        for j in range(nj):
            for q in range(j):
                for ridx, r in enumerate(self.smems[q].rels):
                    plan.append((j, q, ridx, r.attrs, r.kmax))
        n_probe = len(plan)

        def round_fn(probs_cum, carry_need, extra_target, key, st):
            sid = jax.lax.axis_index(axis)
            # (1) replicated multinomial cover selection over all GB slots
            kpick, *jks = jax.random.split(key, nj + 1)
            u = jax.random.uniform(kpick, (GB,))
            pick = jnp.clip(jnp.searchsorted(probs_cum, u, side="right"
                                             ).astype(jnp.int32), 0, nj - 1)
            valid = (jnp.arange(GB) < extra_target).astype(jnp.int32)
            need = carry_need + jnp.zeros((nj,), jnp.int32).at[pick].add(valid)

            # (2) local i.i.d. whole-join draws (replicated roots, per-shard
            # fold-in keys — see ShardedTreeJoin for why ranges would bias).
            # Residual (§8.2) edges resolve here too: their sorted-key
            # indexes are replicated non-root node state, so cyclic pieces
            # verify locally with zero extra communication.
            rows_j, ok_j, wok_j = [], [], []
            for j in range(nj):
                rst = st["roots"][j]
                prefix = rst["prefix"][0]
                cols = {a: c[0] for a, c in rst["cols"].items()}
                kd = (jks[j] if world == 1          # bit-for-bit unsharded
                      else jax.random.fold_in(jks[j], sid))
                rows, ok, wok = dtrees[j].draw_with_root(kd, B, prefix, cols,
                                                         rst["n_root"][0])
                rows_j.append(rows)
                ok_j.append(ok)
                wok_j.append(wok)

            # (3) one fingerprint exchange answers every earlier-piece probe
            def window_probe(s1, s2, n_own, qq1, qq2, kmax):
                lo = jnp.searchsorted(s1, qq1, side="left")
                m = jnp.zeros(qq1.shape, bool)
                cap = s1.shape[0]
                for k in range(kmax):   # duplicate window (tiny, static)
                    pos = jnp.minimum(lo + k, cap - 1)
                    m = m | ((lo + k < n_own) & (s1[pos] == qq1)
                             & (s2[pos] == qq2))
                return m

            found = None
            if n_probe and world == 1:
                # fully local: one shard owns everything, no collectives
                found = []
                for (j, q, ridx, attrs, kmax) in plan:
                    mst = st["mem"][q][ridx]
                    found.append(window_probe(
                        mst["fp1"][0], mst["fp2"][0], mst["n_owned"][0],
                        fp32_jnp([rows_j[j][a] for a in attrs], salt=1),
                        fp32_jnp([rows_j[j][a] for a in attrs], salt=2),
                        kmax))
            elif n_probe:
                # all-gather the candidates' fingerprints; each shard
                # answers the probes it owns against its local index; a
                # reduce-scatter ORs the owner verdicts and hands every
                # shard exactly its own candidates' segment
                GN = world * B
                q1 = jnp.stack([fp32_jnp([rows_j[j][a] for a in attrs],
                                         salt=1)
                                for (j, q, ridx, attrs, kmax) in plan])
                q2 = jnp.stack([fp32_jnp([rows_j[j][a] for a in attrs],
                                         salt=2)
                                for (j, q, ridx, attrs, kmax) in plan])
                g1 = jnp.transpose(jax.lax.all_gather(q1, axis),
                                   (1, 0, 2)).reshape(n_probe, GN)
                g2 = jnp.transpose(jax.lax.all_gather(q2, axis),
                                   (1, 0, 2)).reshape(n_probe, GN)
                hits = []
                for p, (j, q, ridx, attrs, kmax) in enumerate(plan):
                    mst = st["mem"][q][ridx]
                    qq1, qq2 = g1[p], g2[p]
                    m = window_probe(mst["fp1"][0], mst["fp2"][0],
                                     mst["n_owned"][0], qq1, qq2, kmax)
                    # only the fp owner may answer (hash-partition ownership)
                    m = m & ((qq1 % jnp.uint32(world)).astype(jnp.int32)
                             == sid)
                    hits.append(m.astype(jnp.int32))
                found = [f > 0 for f in jax.lax.psum_scatter(
                    jnp.stack(hits), axis, scatter_dimension=1, tiled=True)]

            # (4) local acceptance + compaction
            out_cols, okc, resc, accc = [], [], [], []
            p = 0
            for j in range(nj):
                acc = ok_j[j]
                resc.append(jnp.sum(wok_j[j]) - jnp.sum(acc))
                for q in range(j):
                    contained = jnp.ones((B,), bool)
                    for _ in range(len(self.smems[q].rels)):
                        contained = contained & found[p]
                        p += 1
                    acc = acc & ~contained
                perm = jnp.argsort(~acc)
                out_cols.append(tuple(rows_j[j][a][perm][None]
                                      for a in out_attrs))
                okc.append(jnp.sum(wok_j[j]))
                accc.append(jnp.sum(acc))
            okc = jnp.stack(okc).astype(jnp.int32)[None]
            resc = jnp.stack(resc).astype(jnp.int32)[None]
            accc = jnp.stack(accc).astype(jnp.int32)[None]
            return need[None], okc, resc, accc, out_cols

        return jax.jit(shard_map(
            round_fn, mesh=mesh,
            in_specs=(P(), P(), P(), P(), P(axis)),
            out_specs=P(axis), check_rep=False))

    # -- host-format adapter --------------------------------------------------
    def _sharded_round(self, probs_cum, carry_need, extra_target, key):
        """Run one mesh round; return it in the unsharded host-loop format.

        ``out_cols[j]`` holds piece ``j``'s accepted candidates first (the
        host loop reads ``[:take]`` and banks ``[take:accepted]``); per-shard
        counts merge by summation — the shortfall/surplus algebra is global.
        """
        need, okc, resc, accc, out_cols = self._round_prog(
            probs_cum, carry_need, extra_target, key, self._state)
        need = np.asarray(need)[0].astype(np.int64)
        ok_counts = np.asarray(okc).sum(axis=0)
        res_counts = np.asarray(resc).sum(axis=0)
        acc_ps = np.asarray(accc)                   # (world, nj)
        acc_counts = acc_ps.sum(axis=0)
        take = np.minimum(need, acc_counts)
        shortfall = need - take
        cols: List[Tuple[np.ndarray, ...]] = []
        for j in range(len(self.order)):
            if self.world == 1:
                cols.append(tuple(np.asarray(c)[0] for c in out_cols[j]))
            else:
                per_attr = []
                for c in out_cols[j]:
                    c = np.asarray(c)               # (world, B)
                    per_attr.append(np.concatenate(
                        [c[s, :acc_ps[s, j]] for s in range(self.world)])
                        if acc_counts[j] else c[0, :0])
                cols.append(tuple(per_attr))
        return cols, ok_counts, res_counts, acc_counts, take, shortfall
