"""§3: the union sampling framework (Algorithm 1 + baselines).

* :class:`DisjointUnionSampler` — Definition 1: pick ``J_j ∝ |J_j|``, sample
  uniformly inside, emit.  No rejection.
* :class:`BernoulliUnionSampler` — the §3 "union trick": per iteration each
  join fires independently with ``P = |J_j|/|U|``; a fired join's sample is
  kept only when the join is the *canonical first* join containing the tuple.
  Uniform with only ``|J_j|`` statistics, but rejection grows with overlap.
* :class:`SetUnionSampler` — Algorithm 1 (non-Bernoulli cover selection).
  Joins are selected with ``P = |J'_j|/|U|`` from a :class:`Cover`; inside the
  selected join we draw until the candidate lands in the cover piece
  ``J'_j`` — per Theorem 1's proof the yield of every iteration is then
  exactly ``P(f(u)) · 1/|g(f(u))| = 1/|U|``.  (The paper's pseudocode as
  printed re-selects a join after a rejection, which does *not* reproduce the
  proof's distribution — see DESIGN.md §7; ``strict_paper_loop=True``
  reproduces the printed behaviour for the ablation benchmark.)

  Two cover-membership modes:

  - ``membership="probe"``  — exact batched membership probes against the
    earlier joins (the centralised setting; zero revisions, exactly uniform).
  - ``membership="record"`` — the paper's lazy ``orig_join`` record with
    **revision**: a tuple's home join is discovered over time; when a tuple
    recorded at join ``i`` is re-sampled from an earlier join ``j < i``, the
    old copies are removed from the output and the record moves to ``j``
    (Alg 1 lines 10–12).

All samplers draw candidates and probe membership through the backend layer
(:mod:`repro.core.backends`): ``backend="numpy"`` (default) is the host
reference engine, behaviour-identical to the pre-backend code;
``backend="jax"`` runs whole Algorithm-1 rounds as one jitted device program
(:class:`repro.core.backends.jax_backend.JaxUnionSampler`).  §8.3 predicates
run inside the fused loop in both modes — ``pushdown()`` provenance becomes
build-time validity masks, rejection predicates (union-wide ``predicate=`` or
per-join ``JoinSpec.reject_preds``) lower to in-round acceptance masks — and
``membership="record"`` keeps the ``orig_join`` record as a device-resident
sorted-fingerprint multiset (:class:`~repro.core.backends.jax_backend.
JaxRecordUnionSampler`).  Only ``strict_paper_loop`` remains a host-only
ablation (it degrades with a ``repro_engine_fallback_total`` event); device-
unlowerable predicates likewise degrade to the host loop.  Adding
``mesh=`` lifts the fused rounds onto a device mesh
(:class:`repro.core.sharding.ShardedUnionSampler`: per-shard draws from the
mesh-partitioned catalog, hash-partition membership exchange; a 1-device
mesh reproduces the unsharded engine bit for bit).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .backends import Backend, get_backend
from .cover import Cover
from .index import Catalog
from .joins import JoinSpec
from .membership import rows_concat, rows_length, rows_subset
from .relation import fingerprint128

Rows = Dict[str, np.ndarray]


@dataclasses.dataclass
class SamplerStats:
    iterations: int = 0
    candidate_draws: int = 0       # ψ of §3.3 (samples obtained from join subroutine)
    cover_rejects: int = 0
    residual_rejects: int = 0      # §8.2 cyclic: walks killed by the Π d/M test
    pred_rejects: int = 0          # §8.3 rejection-mode predicate failures
    canonical_rejects: int = 0
    revisions: int = 0
    dropped_slots: int = 0
    reuse_accepts: int = 0
    reuse_rejects: int = 0
    backtrack_removed: int = 0
    samples_emitted: int = 0       # denominator of psi(): rows handed out

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    def psi(self) -> float:
        """ψ of §3.3 as a ratio: candidate draws per emitted sample.

        1.0 is the no-waste optimum; the adaptive round planner drives the
        fused engines toward it.  0.0 until anything has been emitted.
        """
        if self.samples_emitted <= 0:
            return 0.0
        return self.candidate_draws / self.samples_emitted

    def merge(self, other: "SamplerStats") -> "SamplerStats":
        """Associative in-place merge (counter sum); returns ``self``.

        The counter twin of :meth:`repro.core.size_estimation.RunningMean.
        merge` — used by :func:`repro.core.distributed.merge_streams` and the
        serve queue to combine per-stream cost accounting.
        """
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))
        return self

    def snapshot(self) -> "SamplerStats":
        """Point-in-time copy (engines mutate their stats in place)."""
        return dataclasses.replace(self)


@dataclasses.dataclass
class SampleSet:
    """N accepted samples (with-replacement) from the set union."""

    attrs: List[str]
    rows: Rows                      # each (N,)
    home: np.ndarray                # (N,) index of the join the sample credits
    fingerprint: np.ndarray         # (N, 2) uint64
    stats: SamplerStats

    def __len__(self) -> int:
        return int(self.home.shape[0])

    def matrix(self) -> np.ndarray:
        return np.stack([self.rows[a] for a in self.attrs], axis=1)


def _fp_to_int(fp_row: np.ndarray) -> int:
    return (int(fp_row[0]) << 64) | int(fp_row[1])


def pop_residual_rejects(source) -> int:
    """Drain a candidate source's §8.2 residual-rejection counter (0 when the
    source has none — acyclic joins, custom backends)."""
    pop = getattr(source, "pop_residual_rejects", None)
    return int(pop()) if pop is not None else 0


def empty_sample_set(attrs: Sequence[str], stats: SamplerStats) -> SampleSet:
    rows = {a: np.zeros(0, dtype=np.int64) for a in attrs}
    fp = fingerprint128([rows[a] for a in sorted(attrs)])
    return SampleSet(list(attrs), rows, np.zeros(0, dtype=np.int64), fp, stats)


class ReadySample:
    """Resolved async-sample handle (host engines compute eagerly)."""

    def __init__(self, ss: SampleSet):
        self._ss = ss

    def result(self) -> SampleSet:
        return self._ss


class DisjointUnionSampler:
    """Definition 1 — sampling the disjoint union ⨄ J_j."""

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec],
                 join_sizes: Dict[str, float], join_method: str = "ew",
                 seed: int = 0, backend: str | Backend = "numpy"):
        self.joins = list(joins)
        self.backend = get_backend(backend, cat, self.joins, join_method=join_method,
                                   seed=seed)
        self.sources = [self.backend.source(j.name) for j in self.joins]
        sizes = np.array([max(join_sizes[j.name], 0.0) for j in self.joins])
        total = sizes.sum()
        if not np.isfinite(total) or total <= 0:
            raise ValueError(
                f"DisjointUnionSampler: degenerate join sizes {join_sizes!r} "
                "(all zero/negative or non-finite) — cannot form a selection "
                "distribution")
        self.probs = sizes / total
        self.rng = np.random.default_rng(seed)
        self.attrs = list(self.joins[0].output_attrs)
        self.stats = SamplerStats()

    def sample(self, n: int) -> SampleSet:
        if n <= 0:
            return empty_sample_set(self.attrs, self.stats)
        picks = self.rng.choice(len(self.joins), size=n, p=self.probs)
        parts: List[Rows] = []
        homes: List[np.ndarray] = []
        for j in range(len(self.joins)):
            c = int((picks == j).sum())
            if c == 0:
                continue
            rows, draws = self.sources[j].draw(self.rng, c, batch=1024)
            self.stats.candidate_draws += draws
            self.stats.residual_rejects += pop_residual_rejects(self.sources[j])
            parts.append(rows)
            homes.append(np.full(c, j, dtype=np.int64))
        rows = rows_concat(parts)
        home = np.concatenate(homes)
        perm = self.rng.permutation(n)
        rows = {a: c[perm] for a, c in rows.items()}
        fp = fingerprint128([rows[a] for a in sorted(self.attrs)])
        self.stats.iterations += n
        self.stats.samples_emitted += n
        return SampleSet(self.attrs, rows, home[perm], fp, self.stats)


class BernoulliUnionSampler:
    """§3 union-trick baseline (canonical first-join acceptance)."""

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec],
                 join_sizes: Dict[str, float], union_size: float,
                 join_method: str = "ew", seed: int = 0,
                 backend: str | Backend = "numpy"):
        self.cat = cat
        self.joins = list(joins)
        self.backend = get_backend(backend, cat, self.joins, join_method=join_method,
                                   seed=seed)
        self.sources = [self.backend.source(j.name) for j in self.joins]
        self.prober = self.backend.oracle()
        self.sizes = np.array([max(join_sizes[j.name], 1e-12) for j in self.joins])
        self.union_size = max(union_size, self.sizes.max())
        self.rng = np.random.default_rng(seed)
        self.attrs = list(self.joins[0].output_attrs)
        self.stats = SamplerStats()

    def sample(self, n: int, round_size: int = 256, max_rounds: int = 100_000) -> SampleSet:
        if n <= 0:
            return empty_sample_set(self.attrs, self.stats)
        acc_rows: List[Rows] = []
        acc_home: List[int] = []
        names = [j.name for j in self.joins]
        p_fire = np.minimum(self.sizes / self.union_size, 1.0)
        count = 0
        for _ in range(max_rounds):
            if count >= n:
                break
            self.stats.iterations += round_size
            # Bernoulli fire matrix (round, joins)
            fires = self.rng.random((round_size, len(self.joins))) < p_fire[None, :]
            for j, name in enumerate(names):
                c = int(fires[:, j].sum())
                if c == 0:
                    continue
                rows, draws = self.sources[j].draw(self.rng, c, batch=1024)
                self.stats.candidate_draws += draws
                self.stats.residual_rejects += pop_residual_rejects(
                    self.sources[j])
                # canonical acceptance: no earlier-indexed join contains the tuple
                keep = np.ones(c, dtype=bool)
                for i in range(j):
                    keep &= ~self.prober.contains(names[i], rows)
                self.stats.canonical_rejects += int((~keep).sum())
                kidx = np.nonzero(keep)[0]
                if kidx.shape[0]:
                    acc_rows.append(rows_subset(rows, kidx))
                    acc_home.extend([j] * kidx.shape[0])
                    count += kidx.shape[0]
        if count < n:
            raise RuntimeError("BernoulliUnionSampler: round budget exhausted")
        rows = {a: c[:n] for a, c in rows_concat(acc_rows).items()}
        home = np.asarray(acc_home[:n], dtype=np.int64)
        fp = fingerprint128([rows[a] for a in sorted(self.attrs)])
        self.stats.samples_emitted += n
        return SampleSet(self.attrs, rows, home, fp, self.stats)


class SetUnionSampler:
    """Algorithm 1 — non-Bernoulli cover-based set-union sampling."""

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec], cover: Cover,
                 membership: str = "probe", join_method: str = "ew",
                 strict_paper_loop: bool = False,
                 seed: int = 0, retry_rounds: int = 64,
                 candidate_batch: int = 32, predicate=None,
                 backend: str | Backend = "numpy",
                 round_batch: Optional[int] = 4096, mesh=None,
                 fused_rounds: str = "device", plan: str = "static"):
        if membership not in ("probe", "record"):
            raise ValueError("membership must be 'probe' or 'record'")
        if plan not in ("static", "adaptive"):
            raise ValueError("plan must be 'static' or 'adaptive', got "
                             f"{plan!r}")
        self.cat = cat
        self.joins = list(joins)
        self.by_name = {j.name: j for j in self.joins}
        self.cover = cover
        self.order = list(cover.order)                      # cover order (names)
        self.backend = get_backend(backend, cat, self.joins, join_method=join_method,
                                   seed=seed)
        self.sources = {j.name: self.backend.source(j.name) for j in self.joins}
        # lazy: the fused/sharded engines never probe through the host-facing
        # oracle, and the jax backend builds its replicated membership
        # indexes on first oracle access only
        self._prober = None
        self.membership = membership
        self.strict_paper_loop = strict_paper_loop
        self.rng = np.random.default_rng(seed)
        self.attrs = list(self.joins[0].output_attrs)
        self.retry_rounds = retry_rounds
        self.candidate_batch = candidate_batch
        # §8.3 rejection-mode selection predicate (RejectingPredicate or None):
        # applied to candidates before cover acceptance — appropriate for
        # non-selective predicates (pushdown() is the pre-filter alternative)
        self.predicate = predicate
        self.stats = SamplerStats()
        # record mode state: fingerprint -> home join order-index
        self._record: Dict[int, int] = {}
        # fused device engine: one jitted program per Algorithm-1 round
        # (mesh= lifts it onto the sharded multi-device layer)
        self._engine = None
        if mesh is not None and not self.backend.supports_fused_rounds():
            raise ValueError("mesh= requires a fused-round backend; use "
                             "backend='jax'")
        fused = self.backend.supports_fused_rounds()
        if fused and strict_paper_loop:
            # host-only ablation (re-selects a join after every rejection —
            # inherently sequential); degrade rather than refuse
            if mesh is not None:
                raise ValueError("strict_paper_loop is a host-only ablation; "
                                 "it cannot run on a mesh")
            from .. import obs
            obs.record_fallback("strict_paper_loop",
                                detail="host-only ablation loop")
            fused = False
        if fused and (predicate is not None
                      or any(j.reject_preds for j in self.joins)):
            # §8.3 rejection predicates lower to in-round masks when the
            # comparisons are device-supported; otherwise the whole union
            # degrades to the host loop (per-join membership must see the
            # same filtered joins the sampler does)
            from .predicates import device_lower_reason
            reason = None
            for j in self.joins:
                preds = list(j.reject_preds)
                if predicate is not None:
                    preds += list(predicate.preds)
                reason = device_lower_reason(preds, j.output_attrs)
                if reason is not None:
                    break
            if reason is not None:
                if mesh is not None:
                    raise ValueError(
                        f"predicate not device-lowerable ({reason}); drop "
                        "mesh= to fall back to the host engine")
                from .. import obs
                obs.record_fallback("predicate_unsupported", detail=reason,
                                    join=j.name)
                fused = False
        # round_batch=None consults the autotuning cost model
        # (planner.PLAN_CACHE, fed by timed device calls this process) and
        # falls back to the 4096 default while the cache is cold
        self.autotuned_plan = None
        engine_surplus_cap = None
        if round_batch is None:
            from . import planner as _planner
            self.autotuned_plan = _planner.PLAN_CACHE.suggest(
                _planner.plan_key(cat, self.joins, cover))
            if self.autotuned_plan is not None:
                round_batch = self.autotuned_plan.round_batch
                engine_surplus_cap = self.autotuned_plan.surplus_cap
            else:
                round_batch = 4096
        self.plan = plan
        if fused:
            if membership == "record" and mesh is not None:
                raise ValueError(
                    "membership='record' is not supported on the sharded "
                    "engine (the record multiset is device-global); drop "
                    "mesh= or use membership='probe'")
            if mesh is not None:
                from .sharding import ShardedCatalog, ShardedUnionSampler
                scat = ShardedCatalog(cat, self.joins, mesh=mesh,
                                      backend=self.backend)
                self._engine = ShardedUnionSampler(
                    scat, cover, seed=seed, round_batch=round_batch,
                    surplus_cap=engine_surplus_cap,
                    stats=self.stats, fused_rounds=fused_rounds,
                    predicate=predicate, plan=plan)
            elif membership == "record":
                from .backends.jax_backend import JaxRecordUnionSampler
                self._engine = JaxRecordUnionSampler(
                    self.backend, cover, seed=seed, round_batch=round_batch,
                    surplus_cap=engine_surplus_cap,
                    stats=self.stats, fused_rounds=fused_rounds,
                    predicate=predicate, plan=plan)
            else:
                from .backends.jax_backend import JaxUnionSampler
                self._engine = JaxUnionSampler(
                    self.backend, cover, seed=seed, round_batch=round_batch,
                    surplus_cap=engine_surplus_cap,
                    stats=self.stats, fused_rounds=fused_rounds,
                    predicate=predicate, plan=plan)

    # ------------------------------------------------------------------ util
    @property
    def prober(self):
        if self._prober is None:
            self._prober = self.backend.oracle()
        return self._prober

    def _selection_probs(self) -> np.ndarray:
        p = np.asarray(self.cover.selection_probs(), dtype=np.float64)
        p = np.maximum(p, 0)
        s = p.sum()
        return p / s if s > 0 else np.full(len(p), 1.0 / len(p))

    def _uniform_candidates(self, name: str, count: int) -> Optional[Rows]:
        from .join_sampler import EmptyJoinError
        try:
            rows, draws = self.sources[name].draw(self.rng, count,
                                                  batch=max(count, 64))
        except EmptyJoinError:
            # the estimate gave a positive piece size to an empty join —
            # treat the slots as dropped (estimation noise, logged)
            return None
        self.stats.candidate_draws += draws
        self.stats.residual_rejects += pop_residual_rejects(self.sources[name])
        return rows

    def _cover_accept_probe(self, oidx: int, rows: Rows) -> np.ndarray:
        """accept iff no earlier join in cover order contains the tuple."""
        n = next(iter(rows.values())).shape[0]
        keep = np.ones(n, dtype=bool)
        for i in range(oidx):
            if not keep.any():
                break
            keep &= ~self.prober.contains(self.order[i], rows)
        return keep

    def _pred_ok(self, name: str, rows: Rows) -> Optional[np.ndarray]:
        """§8.3 own-join predicate mask (per-join ``reject_preds`` AND the
        union-wide ``predicate=``), or ``None`` when there is none."""
        from .predicates import pred_mask_np
        spec = self.by_name[name]
        mask = None
        if spec.reject_preds:
            mask = pred_mask_np(spec.reject_preds, rows)
        if self.predicate is not None:
            m = self.predicate.accept(rows)
            mask = m if mask is None else mask & m
        return mask

    # --------------------------------------------------------------- sampling
    def sample(self, n: int) -> SampleSet:
        if n <= 0:
            return empty_sample_set(self.attrs, self.stats)
        if self._engine is not None:
            return self._engine.sample(n)
        if self.membership == "probe" and not self.strict_paper_loop:
            return self._sample_probe(n)
        return self._sample_sequential(n)

    def sample_async(self, n: int):
        """Dispatch ``sample(n)`` without blocking on the result.

        With a fused device engine the whole multi-round loop is dispatched
        (JAX async dispatch) and the returned handle's ``result()`` performs
        the single device→host fetch — the serving path uses this to launch
        batch *k+1* before draining batch *k*.  Host engines compute eagerly
        and return an already-resolved handle.
        """
        if self._engine is not None and hasattr(self._engine,
                                                "sample_async"):
            return self._engine.sample_async(n)
        return ReadySample(self.sample(n))

    # -- exact mode: batched, stateless, provably uniform ---------------------
    def _sample_probe(self, n: int) -> SampleSet:
        acc_rows: List[Rows] = []
        acc_home: List[np.ndarray] = []
        total = 0
        topups = 0
        target = n
        dead_pieces: set = set()
        while total < n:
            probs = self._selection_probs()
            for oidx in dead_pieces:
                probs[oidx] = 0.0
            if probs.sum() <= 0:
                raise RuntimeError("all cover pieces unreachable")
            probs = probs / probs.sum()
            need_by_join = self.rng.multinomial(target, probs)
            for oidx, name in enumerate(self.order):
                need = int(need_by_join[oidx])
                got = 0
                rounds = 0
                while got < need:
                    rounds += 1
                    if rounds > self.retry_rounds:
                        self.stats.dropped_slots += need - got
                        dead_pieces.add(oidx)
                        break
                    want = max((need - got) * self.candidate_batch, 64)
                    rows = self._uniform_candidates(name, want)
                    if rows is None:
                        self.stats.dropped_slots += need - got
                        dead_pieces.add(oidx)
                        break
                    pred_ok = self._pred_ok(name, rows)
                    if pred_ok is None:
                        pred_ok = np.ones(rows_length(rows), dtype=bool)
                    else:
                        self.stats.pred_rejects += int((~pred_ok).sum())
                    cover_ok = self._cover_accept_probe(oidx, rows)
                    # cover_rejects counts candidates that pass the predicate
                    # but land outside the piece (the device round's split)
                    self.stats.cover_rejects += int((pred_ok & ~cover_ok).sum())
                    keep = pred_ok & cover_ok
                    kidx = np.nonzero(keep)[0][: need - got]
                    self.stats.iterations += want
                    if kidx.shape[0]:
                        acc_rows.append(rows_subset(rows, kidx))
                        acc_home.append(np.full(kidx.shape[0], oidx, dtype=np.int64))
                        got += int(kidx.shape[0])
                total += got
            target = n - total
            topups += 1
            if topups > 64 and total < n:
                raise RuntimeError("SetUnionSampler: top-up budget exhausted")
        rows = {a: c[:n] for a, c in rows_concat(acc_rows).items()}
        home = np.concatenate(acc_home)[:n]
        perm = self.rng.permutation(home.shape[0])
        rows = {a: c[perm] for a, c in rows.items()}
        fp = fingerprint128([rows[a] for a in sorted(self.attrs)])
        self.stats.samples_emitted += n
        return SampleSet(self.attrs, rows, home[perm], fp, self.stats)

    # -- record mode / strict paper loop: faithful sequential Alg 1 ----------
    def _sample_sequential(self, n: int) -> SampleSet:
        probs = self._selection_probs()
        out_rows: List[Dict[str, int]] = []
        out_home: List[int] = []
        out_fp: List[int] = []
        guard = 0
        max_guard = max(200 * n, 10_000)
        while len(out_rows) < n:
            guard += 1
            if guard > max_guard:
                raise RuntimeError("Algorithm 1 budget exhausted (check parameters)")
            oidx = int(self.rng.choice(len(self.order), p=probs))
            name = self.order[oidx]
            accepted = None
            inner = self.retry_rounds if not self.strict_paper_loop else 1
            for _ in range(inner):
                rows = self._uniform_candidates(name, 1)
                if rows is None:
                    self.stats.dropped_slots += 1
                    break
                self.stats.iterations += 1
                fp2 = fingerprint128([rows[a] for a in sorted(self.attrs)])[0]
                fpi = _fp_to_int(fp2)
                pred_ok = self._pred_ok(name, rows)
                if pred_ok is not None and not bool(pred_ok[0]):
                    self.stats.pred_rejects += 1
                    continue
                if self.membership == "probe":
                    ok = bool(self._cover_accept_probe(oidx, rows)[0])
                    if ok:
                        accepted = (rows, fpi)
                        break
                    self.stats.cover_rejects += 1
                else:
                    home = self._record.get(fpi)
                    if home is not None and home < oidx:
                        self.stats.cover_rejects += 1
                        continue  # Alg 1 line 8: reject
                    if home is not None and home > oidx:
                        # Alg 1 lines 10-12: revision
                        self.stats.revisions += 1
                        removed = [k for k, f in enumerate(out_fp) if f == fpi]
                        for k in reversed(removed):
                            out_rows.pop(k)
                            out_home.pop(k)
                            out_fp.pop(k)
                        self.stats.backtrack_removed += len(removed)
                    self._record[fpi] = oidx
                    accepted = (rows, fpi)
                    break
            if accepted is None:
                continue
            rows, fpi = accepted
            out_rows.append({a: int(rows[a][0]) for a in self.attrs})
            out_home.append(oidx)
            out_fp.append(fpi)
        rows = {a: np.asarray([r[a] for r in out_rows[:n]], dtype=np.int64)
                for a in self.attrs}
        home = np.asarray(out_home[:n], dtype=np.int64)
        fp = fingerprint128([rows[a] for a in sorted(self.attrs)])
        self.stats.samples_emitted += n
        return SampleSet(self.attrs, rows, home, fp, self.stats)
