"""Small shared helpers for the core package."""

from __future__ import annotations

from typing import Iterable, Sequence, Tuple, Union


def as_tuple(x: Union[str, Sequence[str], None]) -> Tuple[str, ...]:
    if x is None:
        return ()
    if isinstance(x, str):
        return (x,)
    return tuple(x)


def powerset_with(items: Sequence, member, min_size: int = 2) -> Iterable[Tuple]:
    """All subsets of ``items`` of size >= min_size that contain ``member``."""
    others = [x for x in items if x != member]
    n = len(others)
    for mask in range(1 << n):
        sub = [others[i] for i in range(n) if mask >> i & 1]
        if len(sub) + 1 >= min_size:
            yield tuple(sorted(sub + [member], key=str))


def subsets_of_size(items: Sequence, k: int) -> Iterable[Tuple]:
    import itertools

    return itertools.combinations(items, k)
