"""§3.1: cover construction and |J'_i| by inclusion–exclusion.

A cover ``C = {J'_1..J'_n}`` is an ordering of the joins with
``J'_i = J_i \\ ∪_{j<i} J'_j``.  Its sizes come from inclusion–exclusion over
overlap sizes (the paper's Eq. for |J'_i|):

    |J'_i| = |J_i| + Σ_{m=1..i-1} Σ_{Δ⊆S_i, |Δ|=m} (−1)^m |O_{Δ ∪ {J_i}}|

where ``S_i`` = joins before ``J_i``.  ``Σ_i |J'_i|`` is the (estimated)
union size used for the join-selection distribution of Algorithm 1.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Sequence

from .joins import JoinSpec
from .koverlap import OverlapOracle


@dataclasses.dataclass
class Cover:
    order: List[str]                 # join names, cover order
    piece_sizes: Dict[str, float]    # |J'_i| (estimates; >= 0)
    join_sizes: Dict[str, float]     # |J_i| (estimates)

    @property
    def union_size(self) -> float:
        return sum(self.piece_sizes.values())

    def selection_probs(self) -> List[float]:
        u = self.union_size
        if u <= 0:
            return [1.0 / len(self.order)] * len(self.order)
        return [self.piece_sizes[n] / u for n in self.order]


def build_cover(oracle: OverlapOracle, order: Sequence[str] | None = None) -> Cover:
    names = [j.name for j in oracle.joins]
    order = list(order) if order is not None else names
    piece: Dict[str, float] = {}
    for i, name in enumerate(order):
        before = order[:i]
        size = oracle.size(name)
        v = size
        for m in range(1, i + 1):
            sign = -1.0 if m % 2 == 1 else 1.0
            for sub in itertools.combinations(before, m):
                v += sign * oracle.overlap((name,) + sub)
        piece[name] = min(max(v, 0.0), size)
    return Cover(order, piece, {n: oracle.size(n) for n in order})


def largest_first_order(oracle: OverlapOracle) -> List[str]:
    """Heuristic cover order: largest join first (maximises the no-probe piece)."""
    return sorted((j.name for j in oracle.joins),
                  key=lambda n: -oracle.size(n))
