"""Estimator backend contracts — §5–§7 size/overlap estimation, pluggable.

The ONLINE-UNION sampler (Algorithm 2) and the warm-up facade consume a small
estimation surface, mirroring the candidate/membership split of
:mod:`repro.core.backends`:

* batched **wander-join observation**: walk a pivot join, probe the walk
  endpoints for membership in the other joins of ``Δ``, and fold the
  Horvitz–Thompson draws ``indicator(t)/p(t)`` into running mean/variance
  accumulators (``observe`` / ``estimate`` / ``join_size``),
* **accumulator views**: per-join size statistics and per-Δ overlap
  statistics exposed as :class:`StatView` objects (mean / count /
  CI half-width — the quantities Algorithm 2's refinement and backtracking
  read),
* a **walk pool**: completed walk tuples with their exact probabilities,
  drained by the reuse phase of §7 (``drain_pool``),
* a **histogram oracle** for the cheap §5 initialisation (``histogram``).

Two implementations ship: :class:`~repro.core.estimators.numpy_estimator.
NumpyEstimator` (the behaviour-identical host reference, extracted from the
original ``RandomWalkOverlap``) and :class:`~repro.core.estimators.
jax_estimator.JaxEstimator` (whole walk batches + membership probes + HT
reduction as one jitted device program per join).  See DESIGN.md
("Estimation subsystem").
"""

from __future__ import annotations

import dataclasses
from typing import (Dict, FrozenSet, List, Mapping, Optional, Protocol,
                    Sequence, Tuple, runtime_checkable)

import numpy as np

from ..joins import JoinSpec

Rows = Dict[str, np.ndarray]
PoolBatch = Tuple[Rows, np.ndarray]          # (walk rows, walk probabilities)


@dataclasses.dataclass
class OverlapEstimate:
    """Point estimate of |O_Δ| with its CI half-width and walk count."""

    value: float
    half_width: float
    walks: int


@runtime_checkable
class StatView(Protocol):
    """Read surface of a running mean/variance accumulator (host or device)."""

    @property
    def count(self) -> int: ...

    @property
    def mean(self) -> float: ...

    @property
    def variance(self) -> float: ...

    def half_width(self, confidence: float = 0.90) -> float: ...


@runtime_checkable
class EstimatorBackend(Protocol):
    """Batched wander-join estimation over one union of joins."""

    name: str

    def observe(self, delta: Sequence[JoinSpec], rounds: int = 1
                ) -> OverlapEstimate:
        """Run ``rounds`` walk batches on Δ's pivot; update |J| and |O_Δ|."""
        ...

    def estimate(self, delta: Sequence[JoinSpec], confidence: float = 0.90,
                 rel_halfwidth: float = 0.25, max_walks: int = 50_000,
                 min_walks: int = 512) -> OverlapEstimate:
        """Walk until the CI is tight (or budget exhausted); Eq. 2 estimate."""
        ...

    def join_size(self, join: JoinSpec, min_walks: int = 512) -> float:
        """HT size estimate of one join (walked as a Δ of size 1)."""
        ...

    @property
    def size_stats(self) -> Mapping[str, StatView]:
        """Per-join |J| accumulators, keyed by join name."""
        ...

    @property
    def overlap_stats(self) -> Mapping[FrozenSet[str], StatView]:
        """Per-Δ |O_Δ| accumulators, keyed by frozenset of join names."""
        ...

    def drain_pool(self) -> Dict[str, List[PoolBatch]]:
        """Hand the accumulated walk pool to the caller and reset it (§7)."""
        ...

    def histogram(self, mode: str = "max"):
        """§5 degree-statistics overlap estimator for cheap initialisation."""
        ...


class EstimationLoop:
    """Shared control flow over an ``observe``-driven estimator.

    Pivot selection and the CI stopping rules live here once so the host and
    device engines cannot diverge; subclasses supply ``observe`` plus the
    ``cat`` / ``_stats`` / ``_size_stats`` attributes it updates.
    """

    def _pivot(self, delta: Sequence[JoinSpec]) -> JoinSpec:
        # pivot = join with the smallest Olken bound (lowest-variance walks)
        from ..size_estimation import olken_bound
        return min(delta, key=lambda j: olken_bound(self.cat, j))

    def estimate(self, delta: Sequence[JoinSpec], confidence: float = 0.90,
                 rel_halfwidth: float = 0.25, max_walks: int = 50_000,
                 min_walks: int = 512) -> OverlapEstimate:
        """Walk until the CI is tight (or budget exhausted); Eq. 2 estimate."""
        delta = list(delta)
        key = frozenset(j.name for j in delta)
        while True:
            est = self.observe(delta, rounds=1)
            stat = self._stats[key]
            if stat.count >= min_walks:
                hw = stat.half_width(confidence)
                if est.value <= 0 and stat.count >= min_walks * 4:
                    break  # looks empty
                if est.value > 0 and hw <= rel_halfwidth * est.value:
                    break
            if stat.count >= max_walks:
                break
        stat = self._stats[key]
        return OverlapEstimate(max(stat.mean, 0.0), stat.half_width(confidence),
                               stat.count)

    def join_size(self, join: JoinSpec, min_walks: int = 512) -> float:
        """HT size of one join (walked as a Δ of size 1)."""
        st = self._size_stats.get(join.name)
        while st is None or st.count < min_walks:
            self.observe([join], rounds=1)
            st = self._size_stats[join.name]
        return max(st.mean, 0.0)


class ReservoirPool:
    """Bounded per-join pool of walk batches (reservoir over batches).

    ``observe`` produces one ``(rows, prob)`` batch per round; an unbounded
    run would append forever.  Up to ``cap`` batches per join are kept
    verbatim (behaviour-identical to the historical unbounded pool for any
    run that stays under the cap); beyond that, batch ``i`` replaces a
    uniformly random slot with probability ``cap/i`` (Algorithm R), so the
    retained batches stay a uniform sample of all batches seen.  A dedicated
    generator drives the replacement draws so engaging the cap never
    perturbs the estimator's main random stream.
    """

    def __init__(self, cap: int = 512, seed: int = 0):
        if cap <= 0:
            raise ValueError(f"pool cap must be positive, got {cap}")
        self.cap = int(cap)
        self.pools: Dict[str, List[PoolBatch]] = {}
        self._seen: Dict[str, int] = {}
        self._rng = np.random.default_rng(np.uint64(seed) ^ np.uint64(0x9E3779B97F4A7C15))

    def add(self, name: str, batch: PoolBatch) -> None:
        pool = self.pools.setdefault(name, [])
        seen = self._seen.get(name, 0)
        if len(pool) < self.cap:
            pool.append(batch)
        else:
            slot = int(self._rng.integers(0, seen + 1))
            if slot < self.cap:
                pool[slot] = batch
        self._seen[name] = seen + 1

    def drain(self) -> Dict[str, List[PoolBatch]]:
        out = self.pools
        self.pools = {}
        self._seen = {}
        return out

    def n_batches(self, name: str) -> int:
        return len(self.pools.get(name, []))
