"""Device (JAX) estimation engine — batched wander-join walks on accelerator.

Mirrors :class:`~repro.core.estimators.numpy_estimator.NumpyEstimator`
semantics with the whole observation pipeline fused into one jitted program
per ``(pivot, Δ)``:

* :class:`DeviceWalkJoin` — a whole batch of wander-join walks (§6.1) as one
  traced program: uniform root pick, then per relation in expansion order a
  composite-key range probe + ranged uniform pick with dead-walk masking and
  per-walk probability accumulation ``p(t) = 1/|R_root| · Π 1/d_i``.  On TPU
  each hop routes through the fused Pallas ``hop_refine_pick`` kernel of
  :mod:`repro.kernels.walk` (fence sweep → row gather → fused refine+pick);
  on CPU it lowers via ``jnp.searchsorted``.  Residual (cycle-closing) edges
  are plain hops for wander join, so cyclic joins walk too.
* :class:`DeviceRunning` — Horvitz–Thompson mean/variance accumulators kept
  as device scalars ``(count, mean, M2)``; each batch folds in via the
  associative Chan/Welford merge (algebraically identical to the host
  reference's sequential Welford update).
* the fused observe program — walks + membership indicators (probing walk
  endpoints against the PR-1 :class:`~repro.core.backends.jax_backend.
  DeviceJoinMembership` sorted-fingerprint oracle) + the HT reduction into
  the ``|J|`` and ``|O_Δ|`` accumulators, all in one jit.  Only the walk
  pool (reuse, §7) is pulled back to the host.
* :class:`DeviceHistogramOverlap` — §5 / Theorem 4 bucketed join-size and
  overlap bounds with the per-value histogram algebra (intersect / min /
  sum) as vectorised device ops, so ONLINE-UNION initialisation is also
  off-host.

Limits match the PR-1 device engine: non-negative dict-encoded values whose
packed edge-key domains fit in int32 (checked at build time with clear
errors).  Accumulation is float32 on device; the equivalence tests bound the
drift against the float64 host reference.
"""

from __future__ import annotations

import math
from typing import Dict, FrozenSet, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..index import Catalog
from ..join_sampler import JoinSampler
from ..joins import JoinSpec
from ..overlap import HistogramOverlap
from ..size_estimation import z_value
from .base import EstimationLoop, OverlapEstimate, PoolBatch, ReservoirPool

from ..backends.jax_backend import (DeviceJoinMembership, _as_i32,
                                    _attr_widths, _pack_jnp, _pack_np,
                                    _I32_LIM)

Rows = Dict[str, np.ndarray]

_TINY = 1e-30


# ---------------------------------------------------------------------------
# Device walker: batched wander-join walks over one join
# ---------------------------------------------------------------------------


class DeviceWalkJoin:
    """One join prepared for jitted batched wander-join walks."""

    def __init__(self, cat: Catalog, spec: JoinSpec,
                 use_pallas: Optional[bool] = None):
        if use_pallas is None:
            from ...kernels.ops import on_tpu
            use_pallas = on_tpu()
        self.use_pallas = bool(use_pallas)
        self.name = spec.name
        self.spec = spec
        self.attrs = tuple(spec.output_attrs)

        js = JoinSampler(cat, spec, method="wj")   # host walk plan (no weights)
        widths = _attr_widths(spec)
        self.node_edge_attrs: List[Tuple[str, ...]] = []
        self.node_radices: List[Tuple[int, ...]] = []
        self.sorted_keys: List[jnp.ndarray] = []
        self.perm: List[jnp.ndarray] = []
        self.cols: List[Dict[str, jnp.ndarray]] = []
        self._prepped: List[object] = []

        produced = set(js.root_rel.attrs)
        for n in js.order[1:]:
            rel = js._reduced[n.alias]
            radices = tuple(widths[a] for a in n.edge_attrs)
            dom = 1
            for w in radices:
                dom *= w
            if dom >= _I32_LIM:
                raise ValueError(
                    f"jax estimator: packed edge-key domain of node "
                    f"{n.alias!r} ({dom}) exceeds int32; use the numpy "
                    "estimator")
            key = _pack_np([rel.columns[a] for a in n.edge_attrs], radices)
            perm = np.argsort(key, kind="stable")
            new_attrs = tuple(a for a in rel.attrs if a not in produced)
            produced.update(rel.attrs)
            self.node_edge_attrs.append(tuple(n.edge_attrs))
            self.node_radices.append(radices)
            self.sorted_keys.append(jnp.asarray(key[perm].astype(np.int32)))
            self.perm.append(jnp.asarray(perm.astype(np.int32)))
            self.cols.append({a: jnp.asarray(_as_i32(c, f"{rel.name}.{a}"))
                              for a, c in rel.columns.items()
                              if a in new_attrs})
            if self.use_pallas:
                from ...kernels.searchsorted import PreparedKeys
                self._prepped.append(PreparedKeys(key[perm]))
            else:
                self._prepped.append(None)

        self.root_cols = {a: jnp.asarray(_as_i32(c, f"root.{a}"))
                          for a, c in js.root_rel.columns.items()}
        self.n_root = js.root_rel.nrows
        self._empty = (self.n_root == 0 or
                       any(k.shape[0] == 0 for k in self.sorted_keys))

    def is_empty(self) -> bool:
        return self._empty

    # -- one hop: (pos, degree) per walk --------------------------------------
    def _hop(self, i: int, q: jnp.ndarray, u: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if not self.use_pallas:
            sk = self.sorted_keys[i]
            lo = jnp.searchsorted(sk, q, side="left").astype(jnp.int32)
            hi = jnp.searchsorted(sk, q, side="right").astype(jnp.int32)
            d = hi - lo
            off = jnp.floor(u * jnp.maximum(d, 1).astype(jnp.float32)
                            ).astype(jnp.int32)
            off = jnp.minimum(off, jnp.maximum(d - 1, 0))
            return lo + off, d
        from ...kernels.ops import default_interpret
        from ...kernels.searchsorted import QUERY_TILE
        from ...kernels.walk import _hop_i32
        prep = self._prepped[i]
        b = q.shape[0]
        pad = (-b) % QUERY_TILE
        qp = jnp.pad(q, (0, pad))
        up = jnp.pad(u.astype(jnp.float32), (0, pad))
        qt = qp.shape[0] // QUERY_TILE
        # keys are non-negative int32, so the 64-bit split is (hi=0, lo=q^MIN)
        q_lo = (qp ^ jnp.int32(-(1 << 31))).reshape(qt, QUERY_TILE)
        q_hi = jnp.zeros_like(q_lo)
        pos, deg = _hop_i32(q_hi, q_lo, up.reshape(qt, QUERY_TILE),
                            prep.f_hi2, prep.f_lo2,
                            prep.keys2d_hi, prep.keys2d_lo,
                            n_chunks=prep.n_chunks, n_fences=prep.n_blocks,
                            interpret=default_interpret())
        return pos.reshape(-1)[:b], deg.reshape(-1)[:b]

    # -- one batch of walks (traced; jit at the call site) --------------------
    def draw(self, key: jax.Array, batch: int
             ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray, jnp.ndarray]:
        """``batch`` wander-join walks: (rows, p(t), ok).  p(t)=0 for dead."""
        keys = jax.random.split(key, len(self.sorted_keys) + 1)
        r_pos = jax.random.randint(keys[0], (batch,), 0, max(self.n_root, 1))
        rows = {a: c[r_pos] for a, c in self.root_cols.items()}
        ok = jnp.full((batch,), self.n_root > 0)
        prob = jnp.full((batch,), 1.0 / max(self.n_root, 1), jnp.float32)
        for i, (edge_attrs, radices) in enumerate(
                zip(self.node_edge_attrs, self.node_radices)):
            q = _pack_jnp(rows, edge_attrs, radices)
            u = jax.random.uniform(keys[i + 1], (batch,))
            pos, d = self._hop(i, q, u)
            alive = ok & (d > 0)
            prob = jnp.where(alive,
                             prob / jnp.maximum(d, 1).astype(jnp.float32), 0.0)
            ok = alive
            n_i = self.perm[i].shape[0]
            child = self.perm[i][jnp.clip(pos, 0, n_i - 1)]
            for a, c in self.cols[i].items():
                rows[a] = c[child]
        return rows, prob, ok


# ---------------------------------------------------------------------------
# Device-resident HT accumulators
# ---------------------------------------------------------------------------


def _batch_moments(x: jnp.ndarray):
    """(n, mean, M2) of one batch — every element counts (zeros included)."""
    mean = jnp.mean(x)
    m2 = jnp.sum((x - mean) ** 2)
    return jnp.int32(x.shape[0]), mean, m2


def _merge_moments(count, mean, m2, bn, bmean, bm2):
    """Chan's associative merge — the batched form of Welford's update."""
    n = count + bn
    nf = jnp.maximum(n.astype(jnp.float32), 1.0)
    bnf = bn.astype(jnp.float32)
    d = bmean - mean
    return (n,
            mean + d * bnf / nf,
            m2 + bm2 + d * d * count.astype(jnp.float32) * bnf / nf)


class DeviceRunning:
    """Running mean/variance kept as device scalars (count, mean, M2).

    Read surface matches :class:`~repro.core.size_estimation.RunningMean`
    (``count`` / ``mean`` / ``variance`` / ``half_width``); reads pull the
    scalars to host lazily.
    """

    def __init__(self):
        self.state = (jnp.int32(0), jnp.float32(0.0), jnp.float32(0.0))

    @property
    def count(self) -> int:
        return int(self.state[0])

    @property
    def mean(self) -> float:
        return float(self.state[1])

    @property
    def m2(self) -> float:
        return float(self.state[2])

    @property
    def variance(self) -> float:
        c = self.count
        return self.m2 / (c - 1) if c > 1 else 0.0

    def half_width(self, confidence: float = 0.90) -> float:
        c = self.count
        if c < 2:
            return math.inf
        return z_value(confidence) * math.sqrt(self.variance / c)

    def update_zeros(self, n: int) -> None:
        """Fold in ``n`` all-zero observations (walks on an empty join)."""
        self.state = _merge_moments(*self.state, jnp.int32(n),
                                    jnp.float32(0.0), jnp.float32(0.0))


# ---------------------------------------------------------------------------
# The estimator backend
# ---------------------------------------------------------------------------


class JaxEstimator(EstimationLoop):
    """Device-resident |J| / |O_Δ| estimation: walks + probes + HT in one jit."""

    name = "jax"

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec], seed: int = 0,
                 batch: int = 512, pool_cap: int = 512,
                 use_pallas: Optional[bool] = None,
                 members: Optional[Dict[str, DeviceJoinMembership]] = None,
                 mesh=None, mesh_axis: str = "shards"):
        self.cat = cat
        # mesh=: run each observation as `world` independent walk batches
        # under shard_map (walker arrays replicated, per-shard fold-in keys)
        # and merge the per-shard HT moments on-mesh in one psum
        # (repro.core.sharding.stats.psum_merge_moments) before folding them
        # into the host-visible DeviceRunning accumulators.
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.world = int(mesh.shape[mesh_axis]) if mesh is not None else 1
        self.joins = list(joins)
        self.by_name = {j.name: j for j in self.joins}
        schemas = {tuple(sorted(j.output_attrs)) for j in self.joins}
        if len(schemas) > 1:
            raise ValueError(
                f"joins must share an output schema; got {sorted(schemas)}")
        self.batch = int(batch)
        self.key = jax.random.PRNGKey(seed)
        self.walkers: Dict[str, DeviceWalkJoin] = {
            j.name: DeviceWalkJoin(cat, j, use_pallas=use_pallas)
            for j in self.joins}
        # reuse the sampling backend's membership indexes when handed in
        # (OnlineUnionSampler shares them) — otherwise build our own
        self.members: Dict[str, DeviceJoinMembership] = (
            members if members is not None
            else {j.name: DeviceJoinMembership(j) for j in self.joins})
        self._stats: Dict[FrozenSet[str], DeviceRunning] = {}
        self._size_stats: Dict[str, DeviceRunning] = {}
        self._pool = ReservoirPool(cap=pool_cap, seed=seed)
        self._observe_fns: Dict[Tuple[str, Tuple[str, ...]], object] = {}

    # -- accumulator views / pool ---------------------------------------------
    @property
    def size_stats(self) -> Mapping[str, DeviceRunning]:
        return self._size_stats

    @property
    def overlap_stats(self) -> Mapping[FrozenSet[str], DeviceRunning]:
        return self._stats

    @property
    def walk_pool(self) -> Dict[str, List[PoolBatch]]:
        return self._pool.pools

    def drain_pool(self) -> Dict[str, List[PoolBatch]]:
        return self._pool.drain()

    # -- fused observe program ------------------------------------------------
    def _observe_fn(self, pivot_name: str, other_names: Tuple[str, ...]):
        key = (pivot_name, other_names)
        fn = self._observe_fns.get(key)
        if fn is None:
            walker = self.walkers[pivot_name]
            members = [self.members[n] for n in other_names]
            batch = self.batch

            if self.mesh is None:
                def run(k, size_state, overlap_state):
                    rows, prob, ok = walker.draw(k, batch)
                    inv = jnp.where(ok & (prob > 0),
                                    1.0 / jnp.maximum(prob, _TINY), 0.0)
                    ind = ok
                    for m in members:
                        ind = ind & m.contains(rows)
                    contrib = jnp.where(ind, inv, 0.0)
                    size_state = _merge_moments(*size_state,
                                                *_batch_moments(inv))
                    overlap_state = _merge_moments(*overlap_state,
                                                   *_batch_moments(contrib))
                    return rows, prob, size_state, overlap_state
            else:
                from jax.experimental.shard_map import shard_map
                from jax.sharding import PartitionSpec as P
                from ..sharding.stats import psum_merge_moments
                axis, world = self.mesh_axis, self.world

                def shard_run(k):
                    sid = jax.lax.axis_index(axis)
                    ks = jax.random.fold_in(k, sid) if world > 1 else k
                    rows, prob, ok = walker.draw(ks, batch)
                    inv = jnp.where(ok & (prob > 0),
                                    1.0 / jnp.maximum(prob, _TINY), 0.0)
                    ind = ok
                    for m in members:
                        ind = ind & m.contains(rows)
                    contrib = jnp.where(ind, inv, 0.0)
                    smom = psum_merge_moments(*_batch_moments(inv), axis)
                    omom = psum_merge_moments(*_batch_moments(contrib), axis)
                    return ({a: v[None] for a, v in rows.items()},
                            prob[None],
                            tuple(x[None] for x in smom),
                            tuple(x[None] for x in omom))

                sharded = shard_map(shard_run, mesh=self.mesh,
                                    in_specs=(P(),), out_specs=P(axis),
                                    check_rep=False)

                def run(k, size_state, overlap_state):
                    rows, prob, smom, omom = sharded(k)
                    size_state = _merge_moments(
                        *size_state, smom[0][0], smom[1][0], smom[2][0])
                    overlap_state = _merge_moments(
                        *overlap_state, omom[0][0], omom[1][0], omom[2][0])
                    return rows, prob, size_state, overlap_state

            fn = self._observe_fns[key] = jax.jit(run)
        return fn

    def observe(self, delta: Sequence[JoinSpec], rounds: int = 1
                ) -> OverlapEstimate:
        """Run ``rounds`` device walk+probe batches on Δ's pivot."""
        delta = list(delta)
        dkey = frozenset(j.name for j in delta)
        stat = self._stats.setdefault(dkey, DeviceRunning())
        pivot = self._pivot(delta)
        sstat = self._size_stats.setdefault(pivot.name, DeviceRunning())
        walker = self.walkers[pivot.name]
        if walker.is_empty():
            # every walk fails: HT draws are observations of zero
            for _ in range(rounds):
                sstat.update_zeros(self.batch * self.world)
                stat.update_zeros(self.batch * self.world)
            return OverlapEstimate(stat.mean, stat.half_width(0.90), stat.count)
        others = tuple(sorted(j.name for j in delta if j.name != pivot.name))
        fn = self._observe_fn(pivot.name, others)
        for _ in range(rounds):
            self.key, sub = jax.random.split(self.key)
            rows, prob, sstat.state, stat.state = fn(sub, sstat.state,
                                                     stat.state)
            # on a mesh the shards' batches come back stacked (world, batch);
            # flatten into one pool batch (dead walks keep prob 0)
            self._pool.add(pivot.name, (
                {a: np.asarray(v, dtype=np.int64).reshape(-1)
                 for a, v in rows.items()},
                np.asarray(prob, dtype=np.float64).reshape(-1)))
        return OverlapEstimate(stat.mean, stat.half_width(0.90), stat.count)

    # -- §5 initialisation ----------------------------------------------------
    def histogram(self, mode: str = "max") -> "DeviceHistogramOverlap":
        return DeviceHistogramOverlap(self.cat, self.joins, mode=mode)


# ---------------------------------------------------------------------------
# Device histogram overlap (§5 / Theorem 4 on device)
# ---------------------------------------------------------------------------


def _lookup_sorted(v: jnp.ndarray, c: jnp.ndarray, valid: jnp.ndarray,
                   q: jnp.ndarray):
    """Per-query (hit, count) lookup into a sorted unique value histogram."""
    n = v.shape[0]
    if n == 0:
        z = jnp.zeros(q.shape[0], bool)
        return z, jnp.zeros(q.shape[0], jnp.float32)
    pos = jnp.searchsorted(v, q)
    posc = jnp.clip(pos, 0, n - 1)
    hit = (pos < n) & (v[posc] == q) & valid[posc]
    return hit, jnp.where(hit, c[posc], 0.0)


class DeviceHistogramOverlap(HistogramOverlap):
    """§5 histogram bounds with the per-value algebra as device ops.

    The split-plan construction and the Theorem-4 scalar multipliers stay on
    host (they are O(#pairs) scalars); the heavy part — per-value histogram
    intersection, min-reduction, and summation over the first-edge domain
    K(1) — runs as vectorised jnp ops over device-resident histograms.
    Counts are float32 on device: exact for integer counts below 2^24, which
    the equivalence tests verify against the float64 host path.
    """

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec],
                 template: Optional[Sequence[str]] = None,
                 mode: str = "max", cap_with_join_bound: bool = True):
        super().__init__(cat, joins, template=template, mode=mode,
                         cap_with_join_bound=cap_with_join_bound)
        self._dev_hists: Dict[Tuple[str, int, str],
                              Tuple[jnp.ndarray, jnp.ndarray]] = {}

    def _pair_hist_dev(self, plan, i: int, attr: str
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        key = (plan.join.name, i, attr)
        if key not in self._dev_hists:
            vals, counts = self._pair_degree_hist(plan, i, attr)
            self._dev_hists[key] = (jnp.asarray(vals.astype(np.int64)),
                                    jnp.asarray(counts.astype(np.float32)))
        return self._dev_hists[key]

    def estimate(self, delta: Sequence[JoinSpec]) -> float:
        """Upper bound (mode='max') or refined estimate (mode='avg') of |O_Δ|."""
        delta = list(delta)
        if len(delta) == 1:
            return float(self._join_bounds[delta[0].name])
        plans = [self.plans[j.name] for j in delta]
        k = len(self.template) - 1  # number of pairs

        # K(1): per join, the per-value count over the first edge's shared
        # attr (pair0 × pair1 when the edge is real) — each as (values,
        # counts, valid) device triples with masks standing in for the host
        # path's materialised intersections.
        first_attr = self.template[1]
        per_join: List[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = []
        for plan in plans:
            v0, c0 = self._pair_hist_dev(plan, 0, first_attr)
            valid0 = jnp.ones(v0.shape[0], bool)
            if k >= 2:
                p1 = plan.pairs[1]
                if p1.fake_edge_to_prev:
                    # row identity: pairs with A2=v == d(v) rows
                    per_join.append((v0, c0, valid0))
                    continue
                v1, c1 = self._pair_hist_dev(plan, 1, first_attr)
                hit, cc = _lookup_sorted(v1, c1,
                                         jnp.ones(v1.shape[0], bool), v0)
                per_join.append((v0, c0 * cc, hit))
            else:
                per_join.append((v0, c0, valid0))

        # intersect the value domains across joins and take the min count
        base_v, acc, valid = per_join[0]
        for v2, c2, m2 in per_join[1:]:
            hit, cc = _lookup_sorted(v2, c2, m2, base_v)
            valid = valid & hit
            acc = jnp.minimum(acc, jnp.where(hit, cc, jnp.inf))
        k1 = float(jnp.sum(jnp.where(valid, acc, 0.0)))
        if k1 <= 0:
            return 0.0

        # K(i) for the remaining pairs: multiply by min over joins of M_{j,i}
        bound = k1
        for i in range(2, k):
            bound *= min(self._pair_multiplier(plan, i) for plan in plans)
        if self.cap:
            bound = min(bound, min(self._join_bounds[j.name] for j in delta))
        return float(bound)
