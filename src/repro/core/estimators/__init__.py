"""Estimation backends for §5–§7 size/overlap estimation.

``get_estimator("numpy" | "jax" | <EstimatorBackend instance>, ...)`` is the
single entry point the ONLINE-UNION sampler and the warm-up facade use; see
:mod:`repro.core.estimators.base` for the :class:`EstimatorBackend` contract
and DESIGN.md ("Estimation subsystem") for the architecture.
"""

from __future__ import annotations

from typing import Sequence, Union

from ..index import Catalog
from ..joins import JoinSpec
from .base import (EstimatorBackend, OverlapEstimate, PoolBatch,
                   ReservoirPool, StatView)
from .numpy_estimator import NumpyEstimator

__all__ = [
    "EstimatorBackend", "JaxEstimator", "NumpyEstimator", "OverlapEstimate",
    "PoolBatch", "ReservoirPool", "StatView", "get_estimator",
]


def get_estimator(spec: Union[str, EstimatorBackend], cat: Catalog,
                  joins: Sequence[JoinSpec], seed: int = 0, batch: int = 512,
                  **kwargs) -> EstimatorBackend:
    """Resolve an estimator selector (``"numpy"``, ``"jax"``, or an instance)."""
    if isinstance(spec, EstimatorBackend) and not isinstance(spec, str):
        return spec
    if spec == "numpy":
        return NumpyEstimator(cat, joins, seed=seed, batch=batch, **kwargs)
    if spec == "jax":
        from .jax_estimator import JaxEstimator  # keep base import light
        return JaxEstimator(cat, joins, seed=seed, batch=batch, **kwargs)
    raise ValueError(
        f"unknown estimator backend {spec!r} (expected 'numpy' or 'jax')")


def __getattr__(name: str):
    if name == "JaxEstimator":                   # lazy: importing jax is heavy
        from .jax_estimator import JaxEstimator
        return JaxEstimator
    raise AttributeError(name)
