"""Host (numpy) estimation engine — the behaviour-identical reference.

This is the original ``RandomWalkOverlap`` implementation (§6.2 / Eq. 2
wander-join overlap estimation + §6.1 HT join sizes) extracted behind the
:class:`~repro.core.estimators.base.EstimatorBackend` protocol so the device
engine can slot in beside it.  The random stream, batch shapes, and update
order are unchanged from the pre-refactor class: seeded runs reproduce
bit-for-bit as long as the walk pool stays under its (new, configurable)
reservoir cap — the cap only changes which batches are *retained* for reuse,
never the estimates.

``repro.core.overlap.RandomWalkOverlap`` remains as a thin alias for
backward compatibility.
"""

from __future__ import annotations

from typing import Dict, FrozenSet, List, Mapping, Optional, Sequence

import numpy as np

from ..index import Catalog
from ..join_sampler import JoinSampler
from ..joins import JoinSpec
from ..membership import MembershipProber
from ..size_estimation import RunningMean
from .base import EstimationLoop, OverlapEstimate, PoolBatch, ReservoirPool


class NumpyEstimator(EstimationLoop):
    """Unbiased |J| / |O_Δ| estimation from host wander-join walks."""

    name = "numpy"

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec], seed: int = 0,
                 batch: int = 512, pool_cap: int = 512):
        self.cat = cat
        self.joins = list(joins)
        self.by_name = {j.name: j for j in self.joins}
        self.prober = MembershipProber(cat, self.joins)
        self.batch = batch
        self._samplers: Dict[str, JoinSampler] = {}
        self._rng = np.random.default_rng(seed)
        # per-Δ running statistics: HT mean of indicator/p (=|O|) and of 1/p (=|J|)
        self._stats: Dict[FrozenSet[str], RunningMean] = {}
        self._size_stats: Dict[str, RunningMean] = {}
        # reuse pool: walk tuples + probabilities per join (feeds ONLINE-UNION §7)
        self._pool = ReservoirPool(cap=pool_cap, seed=seed)

    # -- walk pool (bounded; `walk_pool` kept as the historical attribute) ----
    @property
    def walk_pool(self) -> Dict[str, List[PoolBatch]]:
        return self._pool.pools

    @walk_pool.setter
    def walk_pool(self, value: Dict[str, List[PoolBatch]]) -> None:
        self._pool.drain()
        for name, batches in value.items():
            for b in batches:
                self._pool.add(name, b)

    def drain_pool(self) -> Dict[str, List[PoolBatch]]:
        return self._pool.drain()

    # -- accumulator views ----------------------------------------------------
    @property
    def size_stats(self) -> Mapping[str, RunningMean]:
        return self._size_stats

    @property
    def overlap_stats(self) -> Mapping[FrozenSet[str], RunningMean]:
        return self._stats

    # -- walks ----------------------------------------------------------------
    def sampler(self, name: str) -> JoinSampler:
        if name not in self._samplers:
            self._samplers[name] = JoinSampler(self.cat, self.by_name[name],
                                               method="wj")
        return self._samplers[name]

    def observe(self, delta: Sequence[JoinSpec], rounds: int = 1
                ) -> OverlapEstimate:
        """Run ``rounds`` batches of walks on the pivot and update estimates."""
        delta = list(delta)
        key = frozenset(j.name for j in delta)
        stat = self._stats.setdefault(key, RunningMean())
        pivot = self._pivot(delta)
        others = [j for j in delta if j.name != pivot.name]
        smp = self.sampler(pivot.name)
        for _ in range(rounds):
            sb = smp.sample_batch(self._rng, self.batch)
            inv = np.where(sb.ok & (sb.prob > 0),
                           1.0 / np.maximum(sb.prob, 1e-300), 0.0)
            self._size_stats.setdefault(pivot.name, RunningMean()).update_batch(inv)
            ind = sb.ok.copy()
            if others and ind.any():
                member = np.ones(self.batch, dtype=bool)
                for j in others:
                    member &= self.prober.contains(j.name, sb.rows)
                ind &= member
            stat.update_batch(np.where(ind, inv, 0.0))
            self._pool.add(pivot.name, (sb.rows, sb.prob))
        return OverlapEstimate(stat.mean, stat.half_width(0.90), stat.count)

    # -- §5 initialisation ----------------------------------------------------
    def histogram(self, mode: str = "max"):
        from ..overlap import HistogramOverlap
        return HistogramOverlap(self.cat, self.joins, mode=mode)
