"""§4: k-overlap decomposition (Theorem 3) and union size (Eq. 1).

``A_j^k`` = size of the subset of ``J_j`` shared with exactly ``k-1`` other
joins.  Theorem 3 computes it top-down from overlap sizes ``|O_Δ|``:

    |A_j^n| = |O_S|
    |A_j^k| = Σ_{Δ∈P_k, J_j∈Δ} |O_Δ|  −  Σ_{r=k+1..n} C(r-1, k-1) |A_j^r|
    |A_j^1| = |J_j| − Σ_{r=2..n} |A_j^r|

and Eq. 1 gives  |U| = Σ_j Σ_k (1/k) |A_j^k|.

``OverlapOracle`` abstracts where |O_Δ| comes from (exact / histogram /
random-walk); results are memoised so the bottom-up lattice traversal reuses
shared subsets, as §4 suggests.  With *estimated* overlaps the telescoping can
go slightly negative — we clamp at 0 (documented; estimation noise only
affects sampling efficiency, and ONLINE-UNION's backtracking re-calibrates).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, FrozenSet, List, Sequence

import numpy as np

from .joins import JoinSpec


class OverlapOracle:
    """Memoising wrapper around an |O_Δ| estimator and a |J| estimator."""

    def __init__(self,
                 overlap_fn: Callable[[Sequence[JoinSpec]], float],
                 size_fn: Callable[[JoinSpec], float],
                 joins: Sequence[JoinSpec]):
        self.joins = list(joins)
        self.by_name = {j.name: j for j in self.joins}
        self._overlap_fn = overlap_fn
        self._size_fn = size_fn
        self._cache: Dict[FrozenSet[str], float] = {}

    def overlap(self, names: Sequence[str]) -> float:
        key = frozenset(names)
        if len(key) == 1:
            return self.size(next(iter(key)))
        if key not in self._cache:
            delta = [self.by_name[n] for n in sorted(key)]
            self._cache[key] = max(float(self._overlap_fn(delta)), 0.0)
        return self._cache[key]

    def size(self, name: str) -> float:
        key = frozenset([name])
        if key not in self._cache:
            self._cache[key] = max(float(self._size_fn(self.by_name[name])), 0.0)
        return self._cache[key]

    @property
    def calls(self) -> int:
        return len(self._cache)


@dataclasses.dataclass
class KOverlaps:
    names: List[str]
    # a[j][k] = |A_j^k| for k in 1..n (index k-1)
    a: Dict[str, List[float]]

    def union_size(self) -> float:
        """Eq. 1: |U| = Σ_j Σ_k (1/k)·|A_j^k|."""
        total = 0.0
        for name in self.names:
            for k, v in enumerate(self.a[name], start=1):
                total += v / k
        return total


def k_overlaps(oracle: OverlapOracle, clamp: bool = True) -> KOverlaps:
    """Theorem 3 for every join, top-down from k=n to k=1."""
    names = [j.name for j in oracle.joins]
    n = len(names)
    import itertools

    a: Dict[str, List[float]] = {name: [0.0] * n for name in names}
    for name in names:
        others = [m for m in names if m != name]
        # k = n
        a[name][n - 1] = oracle.overlap(names) if n > 1 else oracle.size(name)
        # k = n-1 .. 2
        for k in range(n - 1, 1, -1):
            s = 0.0
            for sub in itertools.combinations(others, k - 1):
                s += oracle.overlap((name,) + sub)
            corr = 0.0
            for r in range(k + 1, n + 1):
                corr += math.comb(r - 1, k - 1) * a[name][r - 1]
            v = s - corr
            a[name][k - 1] = max(v, 0.0) if clamp else v
        # k = 1
        v = oracle.size(name) - sum(a[name][1:])
        a[name][0] = max(v, 0.0) if clamp else v
    return KOverlaps(names, a)
