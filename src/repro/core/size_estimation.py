"""Join-size estimation (§3.2 bound + §6.1 wander-join Horvitz–Thompson).

* :func:`olken_bound` — the extended Olken upper bound
  ``|J| <= |R_1| * prod_i M_{A_i}(R_{i+1})`` generalised to trees/cyclic
  (product of per-edge max degrees), as adopted by the paper for all
  accept/reject ratios.
* :class:`WanderJoinSizeEstimator` — batched random walks give i.i.d.
  ``1/p(t)`` draws whose mean is ``|J|`` (failed walks contribute 0 — they
  are *observations of zero*, keeping the estimator unbiased).  Supports the
  paper's streaming update
  ``|J|_{S∪t0} = |J|_S + ( 1/p(t0) - |J|_S ) / (m+1)``
  and the CLT stopping rule: stop when the half-width
  ``z_alpha * sigma / sqrt(m)`` falls below a threshold.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

from .index import Catalog
from .joins import JoinSpec
from .join_sampler import JoinSampler

Z_TABLE = {0.80: 1.2816, 0.90: 1.6449, 0.95: 1.9600, 0.99: 2.5758}


def z_value(confidence: float) -> float:
    if confidence in Z_TABLE:
        return Z_TABLE[confidence]
    # rational approximation (Beasley–Springer/Moro would be overkill here)
    from math import sqrt, log
    p = 1.0 - (1.0 - confidence) / 2.0
    # Acklam-lite inverse normal CDF
    t = sqrt(-2.0 * log(1.0 - p))
    return t - (2.30753 + 0.27061 * t) / (1.0 + 0.99229 * t + 0.04481 * t * t)


def olken_bound(cat: Catalog, spec: JoinSpec) -> float:
    """Extended Olken upper bound on |J| (paper §3.2).

    Joins carrying §8.3 rejection predicates are scaled by the estimated
    predicate selectivity — the bound must describe the *filtered* join the
    sampler actually targets, or φ initialisation overestimates selective
    pieces by 1/selectivity (see predicates.selectivity_factor).
    """
    order = spec.expansion_order()
    b = float(order[0].relation.nrows)
    for n in order[1:]:
        idx = cat.index(n.relation, list(n.edge_attrs))
        b *= max(idx.max_degree(), 0)
    if spec.reject_preds:
        from .predicates import selectivity_factor
        b *= selectivity_factor(spec)
    return b


@dataclasses.dataclass
class RunningMean:
    """Streaming mean/variance (Welford) — the paper's online update rule."""

    count: int = 0
    mean: float = 0.0
    m2: float = 0.0

    def update(self, x: float) -> None:
        self.count += 1
        d = x - self.mean
        self.mean += d / self.count          # == paper's |J|_{S∪t0} update
        self.m2 += d * (x - self.mean)

    def update_batch(self, xs: np.ndarray) -> None:
        for x in np.asarray(xs, dtype=np.float64).ravel():
            self.update(float(x))

    def merge(self, other: "RunningMean") -> "RunningMean":
        """Associative merge — used by the distributed sampler's all-gather."""
        if other.count == 0:
            return self
        if self.count == 0:
            self.count, self.mean, self.m2 = other.count, other.mean, other.m2
            return self
        n = self.count + other.count
        d = other.mean - self.mean
        self.mean += d * other.count / n
        self.m2 += other.m2 + d * d * self.count * other.count / n
        self.count = n
        return self

    @property
    def variance(self) -> float:
        return self.m2 / (self.count - 1) if self.count > 1 else 0.0

    def half_width(self, confidence: float = 0.90) -> float:
        if self.count < 2:
            return math.inf
        return z_value(confidence) * math.sqrt(self.variance / self.count)


class WanderJoinSizeEstimator:
    """HT estimate of |J| from batched wander-join walks, with CI stopping.

    ``backend="numpy"`` (default) walks on host; ``backend="jax"`` runs the
    walk batches and HT accumulation as one jitted device program via the
    estimator subsystem (:mod:`repro.core.estimators`).
    """

    def __init__(self, cat: Catalog, spec: JoinSpec, seed: int = 0,
                 batch: int = 512, backend: str = "numpy"):
        self.spec = spec
        self.batch = batch
        self.walks = 0
        self._est = None
        if backend == "numpy":
            self.sampler = JoinSampler(cat, spec, method="wj")
            self.rng = np.random.default_rng(seed)
            self.stat = RunningMean()
        elif backend == "jax":
            from .estimators.jax_estimator import JaxEstimator
            self._est = JaxEstimator(cat, [spec], seed=seed, batch=batch)
            self._est.observe([spec], rounds=0)   # materialise the accumulator
            self.stat = self._est.size_stats[spec.name]
        else:
            raise ValueError(
                f"unknown backend {backend!r} (expected 'numpy' or 'jax')")

    def step(self) -> Tuple[float, float]:
        """One batch of walks; returns (estimate, half_width@90%)."""
        if self._est is not None:
            self._est.observe([self.spec], rounds=1)
            self.stat = self._est.size_stats[self.spec.name]
            self.walks += self.batch
            return self.stat.mean, self.stat.half_width(0.90)
        sb = self.sampler.sample_batch(self.rng, self.batch)
        inv = np.where(sb.ok & (sb.prob > 0), 1.0 / np.maximum(sb.prob, 1e-300), 0.0)
        self.stat.update_batch(inv)
        self.walks += sb.draws
        return self.stat.mean, self.stat.half_width(0.90)

    def run(self, confidence: float = 0.90, rel_halfwidth: float = 0.10,
            max_walks: int = 100_000, min_walks: int = 256) -> float:
        """Sample until CI half-width <= rel_halfwidth * estimate (paper §6.1)."""
        while self.walks < max_walks:
            est, _ = self.step()
            if self.walks >= min_walks and est > 0:
                hw = self.stat.half_width(confidence)
                if hw <= rel_halfwidth * est:
                    break
        return self.stat.mean

    @property
    def estimate(self) -> float:
        return self.stat.mean
