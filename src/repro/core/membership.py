"""Batched membership probes: is output tuple ``t`` in join ``J``?

Because every join keeps its full concatenated output schema (no projection —
the paper's same-output-schema assumption), a tuple belongs to a join iff each
base relation of the join contains the tuple's projection onto that relation's
attributes, AND (for tree joins, which follow the running-intersection
property) those projections connect — which the shared join attributes enforce
automatically since they appear once in the output.

So the probe is: for each relation of ``J``, one :class:`RowSetIndex` lookup of
the projected sub-tuple; AND-reduce across relations.  Fully batched: probing
B tuples against a join of m relations costs m sorted searches of B queries —
the access pattern the `searchsorted` Pallas kernel tiles.

Tuple identity (set-union semantics) uses the 128-bit fingerprint of the
output-schema values (host-side dictionaries only; probes compare values).
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from .index import Catalog
from .joins import JoinSpec
from .relation import fingerprint128


class MembershipProber:
    """Caches per-relation row-set indexes for a set of joins."""

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec]):
        self.cat = cat
        self.joins = {j.name: j for j in joins}
        self._schema_check(joins)

    def _schema_check(self, joins: Sequence[JoinSpec]) -> None:
        schemas = [tuple(sorted(j.output_attrs)) for j in joins]
        if len(set(schemas)) > 1:
            raise ValueError(
                f"joins must share an output schema; got {sorted(set(schemas))}"
            )
        self.output_attrs: List[str] = list(joins[0].output_attrs)

    # -- probes ---------------------------------------------------------------
    def contains(self, join_name: str, rows: Dict[str, np.ndarray]) -> np.ndarray:
        """Vector of booleans: does ``join_name`` contain each tuple of ``rows``?"""
        spec = self.joins[join_name]
        n = next(iter(rows.values())).shape[0]
        ok = np.ones(n, dtype=bool)
        # §8.3 per-join rejection predicates define the filtered join: a tuple
        # is a member iff the base join contains it AND its own columns pass
        for p in spec.reject_preds:
            ok &= p.mask(rows)
        for node in spec.nodes:
            if not ok.any():
                break
            attrs = node.relation.attrs
            rs = self.cat.rowset(node.relation, attrs)
            ok &= rs.contains_rows(rows)
        return ok

    def membership_matrix(self, rows: Dict[str, np.ndarray],
                          join_names: Sequence[str] | None = None) -> np.ndarray:
        """(n_tuples, n_joins) boolean membership matrix."""
        names = list(join_names) if join_names is not None else list(self.joins)
        cols = [self.contains(name, rows) for name in names]
        return np.stack(cols, axis=1)

    def fingerprints(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        """(n, 2) uint64 tuple-value fingerprints in output-schema order."""
        return fingerprint128([np.asarray(rows[a]) for a in self.output_attrs])


def rows_subset(rows: Dict[str, np.ndarray], idx: np.ndarray) -> Dict[str, np.ndarray]:
    return {a: c[idx] for a, c in rows.items()}


def rows_concat(parts: Sequence[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    keys = list(parts[0].keys())
    return {a: np.concatenate([p[a] for p in parts]) for a in keys}


def rows_length(rows: Dict[str, np.ndarray]) -> int:
    return next(iter(rows.values())).shape[0] if rows else 0
