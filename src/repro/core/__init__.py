"""Core: the paper's contribution — sampling over the union of joins."""

from .backends import (Backend, CandidateSource, MembershipOracle,
                       NumpyBackend, get_backend)
from .cover import Cover, build_cover, largest_first_order
from .estimators import (EstimatorBackend, NumpyEstimator, OverlapEstimate,
                         ReservoirPool, get_estimator)
from .distributed import DistributedUnionSampler, merge_statistics, merge_streams
from .framework import (UnionEstimates, WarmupResult, estimate_union,
                        make_set_union_sampler, warmup)
from .index import Catalog, SortedIndex, build_index
from .join_sampler import JoinSampler, SampleBatch
from .jax_sampler import JaxChainSampler
from .joins import (JoinNode, JoinSpec, chain_join, full_join,
                    full_join_matrix, join_size, materialize_residual)
from .koverlap import KOverlaps, OverlapOracle, k_overlaps
from .membership import MembershipProber
from .online import OnlineUnionSampler
from .overlap import (HistogramOverlap, RandomWalkOverlap, exact_overlap,
                      exact_union_size)
from .predicates import Pred, RejectingPredicate, pushdown
from .relation import Relation, combine_columns, fingerprint128
from .size_estimation import (RunningMean, WanderJoinSizeEstimator, olken_bound)
from .splitting import build_template, split_join, split_plans
from .union_sampler import (BernoulliUnionSampler, DisjointUnionSampler,
                            SampleSet, SetUnionSampler)

__all__ = [
    "Backend", "BernoulliUnionSampler", "CandidateSource", "Catalog",
    "Cover", "DisjointUnionSampler", "EstimatorBackend", "MembershipOracle",
    "NumpyBackend", "NumpyEstimator", "OverlapEstimate", "ReservoirPool",
    "get_backend", "get_estimator",
    "DistributedUnionSampler", "HistogramOverlap", "JaxChainSampler", "JoinNode", "JoinSampler",
    "JoinSpec", "KOverlaps", "MembershipProber", "OnlineUnionSampler",
    "OverlapOracle", "Pred", "RandomWalkOverlap", "RejectingPredicate",
    "Relation", "RunningMean", "SampleBatch", "SampleSet", "SetUnionSampler",
    "SortedIndex", "UnionEstimates", "WanderJoinSizeEstimator", "WarmupResult",
    "build_cover", "build_index", "build_template", "chain_join",
    "combine_columns", "estimate_union", "exact_overlap", "exact_union_size",
    "fingerprint128", "full_join", "full_join_matrix", "join_size",
    "k_overlaps", "largest_first_order", "make_set_union_sampler",
    "materialize_residual", "merge_statistics", "merge_streams",
    "olken_bound", "pushdown", "split_join", "split_plans", "warmup",
]
