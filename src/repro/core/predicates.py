"""§8.3: selection predicates — pushdown and rejection modes.

* ``pushdown(spec, preds)`` filters base relations during preprocessing and
  returns a new :class:`JoinSpec` over the filtered relations (works for both
  HISTOGRAM-BASED and RANDOM-WALK instantiations).  The returned spec carries
  **provenance** (``pushdown_base`` + ``pushed_preds``) so the device engine
  can rebuild the same filtered join as per-relation validity *masks* over the
  unfiltered base relations — mask-aware EW prefix sums instead of relation
  copies — and share the base sorted indexes across predicate flavours
  (the UQ2 regime: one base join, several overlapping filters).
* ``rejection(spec, preds)`` attaches sampler-side **per-join** predicates
  (``JoinSpec.reject_preds``): candidates failing them are rejected during
  sampling (random-walk-compatible mode; adds a rejection factor —
  appropriate for non-selective predicates, as the paper notes).  Membership
  probes, exact/histogram size estimation, and both host and device engines
  consume ``reject_preds`` so the filtered join is what gets sampled.
* ``RejectingPredicate`` wraps a *union-wide* sampler-side filter (the same
  predicate applied to every member join) — the historical host API, now also
  lowered to the device loop when the comparisons are device-supported.

Predicates are simple column comparisons on the dict-encoded domain:
``Pred(attr, op, value)`` with op in {==, !=, <, <=, >, >=, in}.  Device
lowering (:func:`compile_preds_jnp`) supports exactly these ops over int32
values; anything else degrades to the host engine per-join (see
``JaxBackend.degraded`` / the ``repro_engine_fallback_total`` counter).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from .index import Catalog
from .joins import JoinNode, JoinSpec
from .relation import Relation

_OPS: Dict[str, Callable[[np.ndarray, object], np.ndarray]] = {
    "==": lambda c, v: c == v,
    "!=": lambda c, v: c != v,
    "<": lambda c, v: c < v,
    "<=": lambda c, v: c <= v,
    ">": lambda c, v: c > v,
    ">=": lambda c, v: c >= v,
    "in": lambda c, v: np.isin(c, np.asarray(list(v))),
}

_I32_MIN, _I32_MAX = -(2 ** 31), 2 ** 31 - 1


@dataclasses.dataclass(frozen=True)
class Pred:
    attr: str
    op: str
    value: object

    def mask(self, cols: Dict[str, np.ndarray]) -> np.ndarray:
        return _OPS[self.op](np.asarray(cols[self.attr]), self.value)


def pred_mask_np(preds: Sequence[Pred], rows: Dict[str, np.ndarray]) -> np.ndarray:
    """AND-reduced host mask of ``preds`` over a batch of output tuples."""
    n = next(iter(rows.values())).shape[0]
    keep = np.ones(n, dtype=bool)
    for p in preds:
        keep &= p.mask(rows)
    return keep


def relation_mask(rel: Relation, preds: Sequence[Pred]) -> Optional[np.ndarray]:
    """Validity mask of ``preds`` restricted to ``rel``'s attributes, or
    ``None`` when no predicate touches the relation (the rule
    :func:`pushdown` filters by, exposed for the device mask build)."""
    mask = None
    for p in preds:
        if p.attr in rel.attrs:
            m = p.mask(rel.columns)
            mask = m if mask is None else mask & m
    return mask


def _pred_tag(preds: Sequence[Pred]) -> str:
    """Deterministic 8-hex signature of a predicate list (filtered-relation
    names must be unique per filter — :class:`Catalog` caches indexes by
    relation name — yet shared across joins pushing the *same* filter)."""
    import hashlib
    parts = []
    for p in preds:
        v = (tuple(sorted(int(x) for x in p.value)) if p.op == "in"
             else p.value)
        parts.append((p.attr, p.op, v))
    return hashlib.blake2s(repr(parts).encode(), digest_size=4).hexdigest()


def pushdown(spec: JoinSpec, preds: Sequence[Pred],
             name_suffix: str = "#sel", name: Optional[str] = None) -> JoinSpec:
    """Filter each base relation by the predicates touching its attributes.

    The result records provenance: ``out.pushdown_base`` is the unfiltered
    spec (composing across chained pushdowns) and ``out.pushed_preds`` the
    accumulated filter list — the device engine rebuilds the filtered join
    from these as validity masks over the base relations.
    """
    nodes: List[JoinNode] = []
    for n in spec.nodes:
        rel = n.relation
        mask = relation_mask(rel, preds)
        if mask is not None:
            applicable = [p for p in preds if p.attr in rel.attrs]
            new_rel = rel.filter(
                mask, name=f"{rel.name}{name_suffix}{_pred_tag(applicable)}")
        else:
            new_rel = rel
        nodes.append(JoinNode(n.alias, new_rel, n.parent, n.edge_attrs, n.kind))
    out = JoinSpec(name if name is not None else spec.name + name_suffix, nodes)
    out.pushdown_base = spec.pushdown_base if spec.pushdown_base is not None else spec
    out.pushed_preds = tuple(spec.pushed_preds) + tuple(preds)
    out.reject_preds = tuple(spec.reject_preds)
    return out


def rejection(spec: JoinSpec, preds: Sequence[Pred],
              name: Optional[str] = None) -> JoinSpec:
    """Attach per-join §8.3 rejection predicates (no relation filtering).

    The returned spec shares ``spec``'s nodes; samplers reject candidates
    failing ``preds`` (counted in ``SamplerStats.pred_rejects``), membership
    probes AND the predicate mask, and size estimation scales by
    :func:`selectivity_factor` — so the *filtered* join is the set-union
    member everywhere.
    """
    out = JoinSpec(name if name is not None else spec.name + "#rej",
                   list(spec.nodes))
    out.pushdown_base = spec.pushdown_base
    out.pushed_preds = tuple(spec.pushed_preds)
    out.reject_preds = tuple(spec.reject_preds) + tuple(preds)
    return out


class RejectingPredicate:
    """Union-wide sampler-side predicate: rejection factor = selectivity
    (§8.3 mode 2, applied identically to every member join)."""

    def __init__(self, preds: Sequence[Pred]):
        self.preds = list(preds)

    def accept(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        return pred_mask_np(self.preds, rows)


# ---------------------------------------------------------------------------
# Device lowering (dict-encoded int32 domain)
# ---------------------------------------------------------------------------


def device_lower_reason(preds: Sequence[Pred],
                        attrs: Optional[Sequence[str]] = None) -> Optional[str]:
    """Why ``preds`` cannot run inside the jitted round (``None`` = they can).

    Device rows are int32 dict codes, so only integer comparisons within the
    int32 domain lower; anything else keeps the join on the host engine.
    """
    def _int_ok(v) -> bool:
        return (isinstance(v, (int, np.integer))
                and not isinstance(v, bool)
                and _I32_MIN <= int(v) <= _I32_MAX)

    for p in preds:
        if p.op not in _OPS:
            return f"unknown predicate op {p.op!r}"
        if attrs is not None and p.attr not in attrs:
            return f"predicate attr {p.attr!r} not in the join output schema"
        if p.op == "in":
            try:
                vals = list(p.value)
            except TypeError:
                return f"'in' predicate value {p.value!r} is not iterable"
            if not all(_int_ok(v) for v in vals):
                return "'in' predicate values outside the int32 dict domain"
        elif not _int_ok(p.value):
            return (f"predicate value {p.value!r} outside the int32 dict "
                    "domain")
    return None


def compile_preds_jnp(preds: Sequence[Pred],
                      attrs: Optional[Sequence[str]] = None):
    """Compile ``preds`` to a traced mask function over device candidate rows.

    Returns ``fn(rows: Dict[str, int32 jnp array]) -> bool jnp array`` (the
    AND of all predicates), or raises ``ValueError`` with the
    :func:`device_lower_reason` when the predicates cannot lower.
    """
    reason = device_lower_reason(preds, attrs)
    if reason is not None:
        raise ValueError(f"predicate not device-lowerable: {reason}")
    import jax.numpy as jnp  # deferred: predicates stays importable sans jax

    # bind the comparison constants now (host-side) so tracing sees literals
    bound = []
    for p in preds:
        if p.op == "in":
            vals = np.unique(np.asarray(sorted(int(v) for v in p.value),
                                        dtype=np.int32))
            bound.append((p.attr, "in", vals))
        else:
            bound.append((p.attr, p.op, np.int32(int(p.value))))

    def fn(rows):
        keep = None
        for attr, op, val in bound:
            c = rows[attr]
            if op == "in":
                m = (jnp.zeros(c.shape, dtype=bool) if val.size == 0
                     else jnp.isin(c, jnp.asarray(val)))
            elif op == "==":
                m = c == val
            elif op == "!=":
                m = c != val
            elif op == "<":
                m = c < val
            elif op == "<=":
                m = c <= val
            elif op == ">":
                m = c > val
            else:
                m = c >= val
            keep = m if keep is None else keep & m
        if keep is None:
            keep = jnp.ones(next(iter(rows.values())).shape, dtype=bool)
        return keep

    return fn


# ---------------------------------------------------------------------------
# Predicate-aware size estimation (§5 bounds under rejection predicates)
# ---------------------------------------------------------------------------


def selectivity_factor(spec: JoinSpec) -> float:
    """Estimated fraction of ``spec``'s join rows surviving its
    ``reject_preds`` (1.0 when there are none).

    Per predicate: the surviving-row fraction of the most selective base
    relation holding the attribute; factors multiply across predicates.
    An *estimate*, not a bound — join fan-out can correlate with predicate
    columns — but it keeps §5 histogram bounds and the Olken bound from
    overestimating filtered pieces by 1/selectivity, which is what φ
    initialisation/refinement needs (Algorithm 1's cover acceptance step
    corrects residual error; see DESIGN.md §4c).
    """
    preds = spec.reject_preds
    if not preds:
        return 1.0
    cached = spec.__dict__.get("_sel_factor")
    if cached is not None:
        return cached
    f = 1.0
    for p in preds:
        frac = 1.0
        for n in spec.nodes:
            rel = n.relation
            if p.attr in rel.attrs and rel.nrows > 0:
                frac = min(frac, float(p.mask(rel.columns).sum()) / rel.nrows)
        f *= frac
    spec.__dict__["_sel_factor"] = f
    return f


def scaled_overlap_estimate(fn):
    """Wrap an overlap estimator ``fn(delta) -> float`` so overlaps of joins
    carrying ``reject_preds`` are scaled by the most selective member's
    :func:`selectivity_factor` (membership in the overlap implies every
    member's predicate holds)."""
    def est(delta):
        v = float(fn(delta))
        f = min((selectivity_factor(j) for j in delta), default=1.0)
        return v * f
    return est


def scaled_size_fn(fn):
    """Wrap a join-size estimator ``fn(join) -> float`` with the per-join
    :func:`selectivity_factor`."""
    def size(j):
        return float(fn(j)) * selectivity_factor(j)
    return size
