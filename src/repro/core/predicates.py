"""§8.3: selection predicates — pushdown and rejection modes.

* ``pushdown(cat, spec, preds)`` filters base relations during preprocessing
  and returns a new :class:`JoinSpec` over the filtered relations (works for
  both HISTOGRAM-BASED and RANDOM-WALK instantiations).
* ``RejectingPredicate`` wraps a sampler-side filter: samples failing the
  predicate are rejected during sampling (random-walk-compatible mode; adds a
  rejection factor — appropriate for non-selective predicates, as the paper
  notes).

Predicates are simple column comparisons on the dict-encoded domain:
``Pred(attr, op, value)`` with op in {==, !=, <, <=, >, >=, in}.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

import numpy as np

from .index import Catalog
from .joins import JoinNode, JoinSpec
from .relation import Relation

_OPS: Dict[str, Callable[[np.ndarray, object], np.ndarray]] = {
    "==": lambda c, v: c == v,
    "!=": lambda c, v: c != v,
    "<": lambda c, v: c < v,
    "<=": lambda c, v: c <= v,
    ">": lambda c, v: c > v,
    ">=": lambda c, v: c >= v,
    "in": lambda c, v: np.isin(c, np.asarray(list(v))),
}


@dataclasses.dataclass(frozen=True)
class Pred:
    attr: str
    op: str
    value: object

    def mask(self, cols: Dict[str, np.ndarray]) -> np.ndarray:
        return _OPS[self.op](np.asarray(cols[self.attr]), self.value)


def pushdown(spec: JoinSpec, preds: Sequence[Pred],
             name_suffix: str = "#sel") -> JoinSpec:
    """Filter each base relation by the predicates touching its attributes."""
    nodes: List[JoinNode] = []
    for n in spec.nodes:
        rel = n.relation
        mask = np.ones(rel.nrows, dtype=bool)
        touched = False
        for p in preds:
            if p.attr in rel.attrs:
                mask &= p.mask(rel.columns)
                touched = True
        new_rel = rel.filter(mask, name=rel.name + name_suffix) if touched else rel
        nodes.append(JoinNode(n.alias, new_rel, n.parent, n.edge_attrs, n.kind))
    return JoinSpec(spec.name + name_suffix, nodes)


class RejectingPredicate:
    """Sampler-side predicate: rejection factor = selectivity (§8.3 mode 2)."""

    def __init__(self, preds: Sequence[Pred]):
        self.preds = list(preds)

    def accept(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        n = next(iter(rows.values())).shape[0]
        keep = np.ones(n, dtype=bool)
        for p in self.preds:
            keep &= p.mask(rows)
        return keep
