"""Columnar relation store — the TPU-native substrate for the union sampler.

The paper's reference implementation keeps relations in Python hash tables and
probes them tuple-at-a-time.  On TPU there is no efficient pointer-chasing, so
the whole substrate is columnar: a relation is a struct-of-arrays of
dict-encoded ``int64`` columns.  Every probe/degree/membership primitive in
:mod:`repro.core` is expressed as batched tensor algebra over these columns
(sorted search, segment reduction, gather), which is exactly what the Pallas
kernels in :mod:`repro.kernels` tile for VMEM.

Rows are identified positionally (row id = index).  Composite keys are built
by :func:`combine_columns` (reversible mixed-radix packing when domains are
small, 64-bit hash-mix otherwise).  Tuple *values* (for set-union semantics)
are summarised by 128-bit fingerprints — two independent 64-bit
multiplicative-hash mixes — used only for host-side bookkeeping dictionaries;
all correctness-critical membership probes compare actual column values.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# 64-bit mixing (splitmix64 finalizer) — vectorised, overflow-safe via uint64.
# ---------------------------------------------------------------------------

_U64 = np.uint64


def mix64(x: np.ndarray, salt: int = 0) -> np.ndarray:
    """SplitMix64 finalizer over an int/uint array. Returns uint64."""
    z = x.astype(np.uint64, copy=True)
    with np.errstate(over="ignore"):
        z = z + _U64(0x9E3779B97F4A7C15) * _U64(salt + 1)
        z = (z ^ (z >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> _U64(27))) * _U64(0x94D049BB133111EB)
        z = z ^ (z >> _U64(31))
    return z


def fingerprint_columns(cols: Sequence[np.ndarray], salt: int = 0) -> np.ndarray:
    """Order-sensitive 64-bit fingerprint of a tuple of columns (row-wise)."""
    if not cols:
        raise ValueError("fingerprint of zero columns")
    acc = np.zeros(cols[0].shape[0], dtype=np.uint64)
    with np.errstate(over="ignore"):
        for i, c in enumerate(cols):
            acc = acc * _U64(0x100000001B3) ^ mix64(np.asarray(c), salt=salt * 1000 + i)
    return acc


def fingerprint128(cols: Sequence[np.ndarray]) -> np.ndarray:
    """(n, 2) uint64 — two independent 64-bit fingerprints per row."""
    return np.stack([fingerprint_columns(cols, salt=1), fingerprint_columns(cols, salt=2)], axis=1)


def combine_columns(cols: Sequence[np.ndarray]) -> np.ndarray:
    """Pack several int64 columns into one int64 composite key.

    Uses exact mixed-radix packing when the combined domain fits in 63 bits
    (reversible, collision-free); otherwise falls back to a 63-bit hash mix
    (collisions astronomically unlikely for our data scales; callers that
    need exactness verify candidates by comparing raw columns).
    """
    cols = [np.asarray(c, dtype=np.int64) for c in cols]
    if len(cols) == 1:
        return cols[0]
    widths = []
    ok = True
    for c in cols:
        lo = int(c.min(initial=0))
        hi = int(c.max(initial=0))
        if lo < 0:
            ok = False
            break
        widths.append(hi + 1)
    if ok:
        total = 1
        for w in widths:
            total *= max(w, 1)
        if total < (1 << 62):
            out = np.zeros_like(cols[0])
            for c, w in zip(cols, widths):
                out = out * np.int64(max(w, 1)) + c
            return out
    return fingerprint_columns(cols, salt=7).astype(np.int64) & np.int64(0x7FFFFFFFFFFFFFFF)


# ---------------------------------------------------------------------------
# Relation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Relation:
    """A named, columnar relation with dict-encoded integer columns."""

    name: str
    columns: Dict[str, np.ndarray]

    def __post_init__(self) -> None:
        n = None
        fixed = {}
        for a, c in self.columns.items():
            c = np.asarray(c)
            if c.dtype not in (np.int64, np.int32):
                c = c.astype(np.int64)
            else:
                c = c.astype(np.int64, copy=False)
            if n is None:
                n = c.shape[0]
            elif c.shape[0] != n:
                raise ValueError(
                    f"column {a!r} of {self.name!r} has {c.shape[0]} rows, expected {n}"
                )
            fixed[a] = c
        self.columns = fixed
        self._nrows = 0 if n is None else int(n)

    # -- basic accessors ----------------------------------------------------
    @property
    def nrows(self) -> int:
        return self._nrows

    @property
    def attrs(self) -> List[str]:
        return list(self.columns.keys())

    def column(self, attr: str) -> np.ndarray:
        return self.columns[attr]

    def rows(self, idx: np.ndarray, attrs: Optional[Sequence[str]] = None) -> Dict[str, np.ndarray]:
        attrs = list(attrs) if attrs is not None else self.attrs
        idx = np.asarray(idx)
        return {a: self.columns[a][idx] for a in attrs}

    def project(self, attrs: Sequence[str], name: Optional[str] = None) -> "Relation":
        return Relation(name or f"{self.name}[{','.join(attrs)}]",
                        {a: self.columns[a] for a in attrs})

    def filter(self, mask: np.ndarray, name: Optional[str] = None) -> "Relation":
        mask = np.asarray(mask)
        return Relation(name or self.name, {a: c[mask] for a, c in self.columns.items()})

    def take(self, idx: np.ndarray, name: Optional[str] = None) -> "Relation":
        idx = np.asarray(idx)
        return Relation(name or self.name, {a: c[idx] for a, c in self.columns.items()})

    def with_column(self, attr: str, col: np.ndarray) -> "Relation":
        cols = dict(self.columns)
        cols[attr] = col
        return Relation(self.name, cols)

    def rename(self, mapping: Mapping[str, str], name: Optional[str] = None) -> "Relation":
        return Relation(name or self.name,
                        {mapping.get(a, a): c for a, c in self.columns.items()})

    def key(self, attrs: Sequence[str]) -> np.ndarray:
        """Composite key column over ``attrs`` (single column passes through)."""
        return combine_columns([self.columns[a] for a in attrs])

    def row_fingerprints(self, attrs: Optional[Sequence[str]] = None) -> np.ndarray:
        attrs = list(attrs) if attrs is not None else sorted(self.attrs)
        return fingerprint128([self.columns[a] for a in attrs])

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Relation({self.name!r}, rows={self.nrows}, attrs={self.attrs})"


def concat_relations(rels: Sequence[Relation], name: str) -> Relation:
    attrs = rels[0].attrs
    for r in rels[1:]:
        if r.attrs != attrs:
            raise ValueError("concat requires identical schemas")
    return Relation(name, {a: np.concatenate([r.columns[a] for r in rels]) for a in attrs})


def tuples_as_array(rows: Mapping[str, np.ndarray], attrs: Sequence[str]) -> np.ndarray:
    """(n, len(attrs)) int64 matrix of tuple values in schema order."""
    return np.stack([np.asarray(rows[a], dtype=np.int64) for a in attrs], axis=1)


def unique_tuple_count(mat: np.ndarray) -> int:
    """Number of distinct rows in an (n, k) value matrix."""
    if mat.shape[0] == 0:
        return 0
    return np.unique(mat, axis=0).shape[0]
