"""Execution backends for the union sampling engine.

``get_backend("numpy" | "jax" | <Backend instance>, ...)`` is the single
entry point the samplers use; see :mod:`repro.core.backends.base` for the
:class:`CandidateSource` / :class:`MembershipOracle` contracts and DESIGN.md
for the architecture overview.
"""

from __future__ import annotations

from typing import Sequence, Union

from ..index import Catalog
from ..joins import JoinSpec
from .base import Backend, CandidateSource, MembershipOracle, Rows
from .numpy_backend import NumpyBackend, NumpyCandidateSource

__all__ = [
    "Backend", "CandidateSource", "MembershipOracle", "Rows",
    "NumpyBackend", "NumpyCandidateSource", "get_backend",
]


def get_backend(spec: Union[str, Backend], cat: Catalog,
                joins: Sequence[JoinSpec], join_method: str = "ew",
                seed: int = 0, **kwargs) -> Backend:
    """Resolve a backend selector (``"numpy"``, ``"jax"``, or an instance)."""
    if isinstance(spec, Backend):
        return spec
    if spec == "numpy":
        return NumpyBackend(cat, joins, join_method=join_method, seed=seed)
    if spec == "jax":
        from .jax_backend import JaxBackend  # keep base import light
        return JaxBackend(cat, joins, join_method=join_method, seed=seed,
                          **kwargs)
    raise ValueError(f"unknown backend {spec!r} (expected 'numpy' or 'jax')")
