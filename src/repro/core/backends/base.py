"""Backend contracts for the union sampling engine.

Algorithm 1 consumes exactly two primitives, and every execution substrate
(host numpy, device JAX, mesh-sharded JAX — see
:mod:`repro.core.sharding`) supplies the same pair:

* :class:`CandidateSource` — batched uniform candidate draws from one join
  (§3.2's sampling subroutine).
* :class:`MembershipOracle` — batched "is tuple ``t`` in join ``J``?" probes
  (the cover-acceptance test of §3.1).

A :class:`Backend` bundles one source per join plus one oracle over all of
them.  The union samplers in :mod:`repro.core.union_sampler` and
:mod:`repro.core.online` are written against these protocols only; selecting
``backend="jax"`` swaps the host engine for the device-resident one without
touching the algorithm layer.  Both engines cover every join shape of the
paper — chain, acyclic tree, and cyclic (§8.2 skeleton+residual) — and both
§8.3 predicate modes (pushdown provenance → build-time validity masks;
rejection predicates → fused in-round acceptance masks) as well as
``membership="record"`` (device sorted-fingerprint multiset).  A device join
that trips an engine limit (packed edge-key domain beyond int32, negative
dict values, predicates outside the int32 comparison set) degrades to a host
candidate source per join with a warning and a
``repro_engine_fallback_total`` event rather than failing the union; of the
union-sampler modes only ``strict_paper_loop`` remains host-only (its
re-select-on-reject control flow is inherently sequential).  Backends that
can fuse a whole
Algorithm-1 round on device additionally expose a ``union_engine`` (see
:class:`repro.core.backends.jax_backend.JaxUnionSampler`); callers feature-test
with :func:`Backend.supports_fused_rounds`.  The third execution layer —
mesh-partitioned catalogs and ``shard_map``'d Algorithm-1 rounds across many
devices — lives in :mod:`repro.core.sharding` (:class:`ShardedCatalog` /
:class:`ShardedUnionSampler`) and plugs in above the fused device engine via
``SetUnionSampler(backend="jax", mesh=...)``.

Sources may optionally expose ``pop_residual_rejects() -> int`` (drain-style
counter of §8.2 residual rejections); the union samplers fold it into
``SamplerStats.residual_rejects`` after every ``draw``.

Engines running the persistent device round loop (DESIGN.md §4a) optionally
expose ``sample_async(n) -> SampleHandle``: the call *dispatches* the whole
multi-round program and returns immediately; ``result()`` blocks on the
device computation and assembles the ``SampleSet``.  Consumers feature-test
with ``getattr(engine, "sample_async", None)`` — the serve front-end uses it
for dispatch-then-drain double buffering (launch batch *k+1* before draining
batch *k*).  Synchronous engines are wrapped by the facade's ready-handle
fallback (:class:`repro.core.union_sampler.ReadySample`), so the handle
contract is uniform.

See DESIGN.md ("Backend architecture") for the full contract and the guide to
adding a new backend.
"""

from __future__ import annotations

from typing import Dict, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

Rows = Dict[str, np.ndarray]


@runtime_checkable
class CandidateSource(Protocol):
    """Uniform candidate draws from a single join.

    ``draw`` returns ``(rows, draws)``: ``count`` uniform-with-replacement
    samples of the join's output tuples plus the number of candidate walks
    spent obtaining them (ψ of §3.3).  Implementations raise
    :class:`repro.core.join_sampler.EmptyJoinError` when the join is
    structurally empty.  ``rng`` is the host generator; device-resident
    sources that carry their own PRNG state may ignore it (documented
    per-implementation).
    """

    join_name: str

    def draw(self, rng: np.random.Generator, count: int,
             batch: Optional[int] = None) -> Tuple[Rows, int]:
        ...

    def is_empty(self) -> bool:
        ...


@runtime_checkable
class SampleHandle(Protocol):
    """In-flight ``sample_async`` dispatch; ``result()`` blocks and
    assembles.  A handle is single-use and must be resolved in dispatch
    order for engines whose carry state is donated between calls."""

    def result(self):
        ...


@runtime_checkable
class MembershipOracle(Protocol):
    """Batched membership probes against the joins of one union."""

    def contains(self, join_name: str, rows: Rows) -> np.ndarray:
        """Boolean vector: does ``join_name`` contain each tuple of ``rows``?"""
        ...

    def membership_matrix(self, rows: Rows,
                          join_names: Optional[Sequence[str]] = None
                          ) -> np.ndarray:
        """(n_tuples, n_joins) boolean membership matrix."""
        ...


class Backend:
    """One candidate source per join + one membership oracle over the union."""

    name: str = "abstract"

    def source(self, join_name: str) -> CandidateSource:
        raise NotImplementedError

    def oracle(self) -> MembershipOracle:
        raise NotImplementedError

    def supports_fused_rounds(self) -> bool:
        """True when the backend can run a whole Algorithm-1 round on device."""
        return False
