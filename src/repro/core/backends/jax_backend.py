"""Device (JAX) backend — the union sampling engine resident on accelerator.

Three layers, bottom-up:

* :class:`DeviceTreeJoin` — generalises the jitted chain sampler to arbitrary
  acyclic (tree) joins **and to cyclic joins via the paper's §8.2
  skeleton+residual scheme**.  Each non-root node keeps its child rows sorted
  by a **composite mixed-radix key** over the node's edge attributes (radices
  are per-attribute domain widths shared across the whole join, so
  parent-side query keys pack identically and probes stay exact), plus
  prefix-summed EW weights; one draw is root inverse-CDF + per-node
  ``searchsorted`` → ranged weighted pick → payload gathers, all ``jax.lax``
  over fixed shapes.  For cyclic joins the EW weights cover the acyclic
  skeleton only; each residual (cycle-closing) edge is then verified inside
  the same traced draw with a batched sorted-key membership probe — uniform
  pick among the ``d`` matches + an accumulated ``Π d/M`` acceptance test —
  mirroring the host :class:`~repro.core.join_sampler.JoinSampler`
  semantics exactly.  On TPU the per-node range probe routes through the
  two-phase Pallas pipeline of :mod:`repro.kernels.searchsorted`
  (``use_pallas``); on CPU it lowers via ``jnp.searchsorted``.
* :class:`DeviceJoinMembership` — batched "is tuple in join J" probes as
  sorted-row-fingerprint lookups resident on device: per base relation, rows
  are indexed by a 32-bit primary fingerprint (sorted) with a 32-bit
  secondary for verification (64 bits total; the host oracle uses 128 — see
  DESIGN.md for the collision budget).  A probe is one ``searchsorted`` per
  relation plus a ``kmax``-wide duplicate window check, AND-reduced.
* :class:`JaxUnionSampler` — runs the *entire multi-round* Algorithm-1 loop
  as one device-resident jitted program: a ``lax.while_loop`` over fused
  rounds (multinomial cover selection, candidate generation for all joins,
  cover-membership acceptance with **retry-within-the-selected-join** — the
  distribution-correct loop, see union_sampler's module docstring on the
  printed-pseudocode pitfall), with the per-piece shortfall vector, FIFO
  ring-buffer surplus banks, dead-piece flags and the stats counters all as
  donated device carry.  ``sample(n)`` crosses the host boundary once;
  ``sample_async(n)`` exposes the dispatch for double-buffered serving.
  ``fused_rounds="host"`` drives the identical round program from a host
  loop (one sync per round) for parity testing.

:class:`JaxBackend` packages the per-join pieces behind the
:class:`~repro.core.backends.base.Backend` protocols so
``SetUnionSampler(backend="jax")`` / ``OnlineUnionSampler(backend="jax")``
select the device engine without touching the algorithm layer.

Limits (all checked at build time with clear errors): ``method="ew"``
weights, non-negative dict-encoded values whose packed edge domains fit in
int32 (the device substrate is 32-bit; see DESIGN.md).  Chain, acyclic, and
cyclic (§8.2 skeleton+residual) join shapes all run on device, as do §8.3
predicates (pushdown provenance becomes build-time validity masks; rejection
predicates lower to in-round acceptance masks via
:func:`repro.core.predicates.compile_preds_jnp`) and ``membership="record"``
(:class:`JaxRecordUnionSampler`).  A union whose *individual* joins trip a
device limit degrades those joins to host candidate draws with a single
warning (and a ``repro_engine_fallback_total`` event) instead of rejecting
the whole union.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ... import obs
from ..index import Catalog
from ..join_sampler import EmptyJoinError, JoinSampler
from ..joins import JoinSpec
from ..membership import rows_length
from .. import planner
from .base import Backend, Rows

_I32_LIM = 1 << 31


# ---------------------------------------------------------------------------
# 32-bit row fingerprints — identical arithmetic on host (build) and device
# (probe): murmur3-style finalizer, FNV-style column combine, uint32 wraps.
# ---------------------------------------------------------------------------


def _mix32_consts(salt: int) -> Tuple[int, int, int]:
    return ((0x9E3779B9 * (salt + 1)) & 0xFFFFFFFF, 0x85EBCA6B, 0xC2B2AE35)


def mix32_np(x: np.ndarray, salt: int = 0) -> np.ndarray:
    add, m1, m2 = _mix32_consts(salt)
    z = (np.asarray(x, np.int64) & 0xFFFFFFFF).astype(np.uint32)
    with np.errstate(over="ignore"):
        z = z + np.uint32(add)
        z = (z ^ (z >> np.uint32(16))) * np.uint32(m1)
        z = (z ^ (z >> np.uint32(13))) * np.uint32(m2)
        z = z ^ (z >> np.uint32(16))
    return z


def mix32_jnp(x: jnp.ndarray, salt: int = 0) -> jnp.ndarray:
    add, m1, m2 = _mix32_consts(salt)
    z = x.astype(jnp.uint32)
    z = z + jnp.uint32(add)
    z = (z ^ (z >> jnp.uint32(16))) * jnp.uint32(m1)
    z = (z ^ (z >> jnp.uint32(13))) * jnp.uint32(m2)
    z = z ^ (z >> jnp.uint32(16))
    return z


_FNV32 = 16777619


def fp32_np(cols: Sequence[np.ndarray], salt: int) -> np.ndarray:
    acc = np.zeros(np.asarray(cols[0]).shape[0], dtype=np.uint32)
    with np.errstate(over="ignore"):
        for i, c in enumerate(cols):
            acc = acc * np.uint32(_FNV32) ^ mix32_np(c, salt=salt * 1000 + i)
    return acc


def fp32_jnp(cols: Sequence[jnp.ndarray], salt: int) -> jnp.ndarray:
    acc = jnp.zeros(cols[0].shape[0], dtype=jnp.uint32)
    for i, c in enumerate(cols):
        acc = acc * jnp.uint32(_FNV32) ^ mix32_jnp(c, salt=salt * 1000 + i)
    return acc


# ---------------------------------------------------------------------------
# Composite-key encoding
# ---------------------------------------------------------------------------


def _attr_widths(spec: JoinSpec) -> Dict[str, int]:
    """Per-attribute mixed-radix width over *all* relations of the join.

    Using the join-wide width (not the per-relation one) makes the packing a
    single injective code over the joint domain, so a parent-side query key
    and a child-side index key for the same tuple of values always coincide.
    """
    widths: Dict[str, int] = {}
    for node in spec.nodes:
        for a, c in node.relation.columns.items():
            lo = int(c.min(initial=0))
            if lo < 0:
                raise ValueError(
                    f"jax backend: attribute {a!r} of {node.relation.name!r} "
                    "has negative values; device engine requires non-negative "
                    "dict-encoded columns")
            hi = int(c.max(initial=0))
            widths[a] = max(widths.get(a, 1), hi + 1)
    return widths


def _pack_np(cols: Sequence[np.ndarray], radices: Sequence[int]) -> np.ndarray:
    key = np.zeros(np.asarray(cols[0]).shape[0], dtype=np.int64)
    for c, w in zip(cols, radices):
        key = key * np.int64(w) + np.asarray(c, np.int64)
    return key


def _pack_jnp(rows: Dict[str, jnp.ndarray], attrs: Sequence[str],
              radices: Sequence[int]) -> jnp.ndarray:
    key = jnp.zeros(rows[attrs[0]].shape[0], dtype=jnp.int32)
    for a, w in zip(attrs, radices):
        key = key * jnp.int32(w) + rows[a]
    return key


def _as_i32(col: np.ndarray, what: str) -> np.ndarray:
    col = np.asarray(col, np.int64)
    if col.size and (int(col.min()) < 0 or int(col.max()) >= _I32_LIM):
        lo, hi = int(col.min()), int(col.max())
        raise ValueError(
            f"jax backend: {what} outside the int32 device domain "
            f"(values span [{lo}, {hi}], needing {max(hi, abs(lo)).bit_length()}"
            " bits but the device substrate has 31 usable bits). Re-encode the"
            " dictionary, use backend='numpy', or see the ROADMAP item on"
            " int64/two-limb packed keys for the device-side fix")
    return col.astype(np.int32)


def _inverse_cdf_pick(prefix: jnp.ndarray, lo, hi, u):
    """Weighted pick within [lo, hi) via prefix sums (vectorised)."""
    tot = prefix[hi] - prefix[lo]
    tgt = prefix[lo] + u * jnp.maximum(tot, 1e-30)
    pos = jnp.searchsorted(prefix, tgt, side="right") - 1
    pos = jnp.clip(pos, lo, jnp.maximum(hi - 1, lo))
    return pos, tot > 0


# ---------------------------------------------------------------------------
# Device-resident tree join (generalised EW candidate source)
# ---------------------------------------------------------------------------


def _device_index_cache(cat: Catalog) -> Dict:
    """Catalog-level cache of device-side sorted indexes and column uploads,
    keyed by relation identity.  Pushdown flavours of one base join (the UQ2
    regime: one base chain, several overlapping §8.3 filters) share the base
    relation's sorted keys, permutation and payload buffers instead of
    re-sorting and re-uploading per flavour.  Cache entries keep a strong
    reference to the relation so ``id()`` keys cannot be reused after GC."""
    cache = cat.__dict__.get("_device_index_cache")
    if cache is None:
        cache = cat.__dict__["_device_index_cache"] = {}
    return cache


def _cached_node_index(cache: Dict, rel, edge_attrs: Tuple[str, ...],
                       radices: Tuple[int, ...], use_pallas: bool):
    """Sorted composite-key index over ``rel`` (host perm + device arrays),
    shared across :class:`DeviceTreeJoin` flavours through the catalog cache.
    The caller has already verified the packed domain fits in int32."""
    k = ("idx", id(rel), rel.name, edge_attrs, radices, bool(use_pallas))
    hit = cache.get(k)
    if hit is None:
        key = _pack_np([rel.columns[a] for a in edge_attrs], radices)
        perm = np.argsort(key, kind="stable")
        prepped = None
        if use_pallas:
            from ...kernels.searchsorted import PreparedKeys
            prepped = PreparedKeys(key[perm])
        hit = (rel, perm, jnp.asarray(key[perm].astype(np.int32)),
               jnp.asarray(perm.astype(np.int32)), prepped)
        cache[k] = hit
    return hit[1], hit[2], hit[3], hit[4]


def _cached_col(cache: Dict, rel, attr: str) -> jnp.ndarray:
    """Device upload of one relation column, shared across flavours."""
    k = ("col", id(rel), rel.name, attr)
    hit = cache.get(k)
    if hit is None:
        hit = (rel, jnp.asarray(_as_i32(rel.columns[attr],
                                        f"{rel.name}.{attr}")))
        cache[k] = hit
    return hit[1]


@dataclasses.dataclass(frozen=True)
class _NodeCfg:
    alias: str
    edge_attrs: Tuple[str, ...]
    radices: Tuple[int, ...]
    new_attrs: Tuple[str, ...]
    kind: str = "tree"               # "tree" | "residual" (§8.2 cycle closer)
    max_degree: int = 0              # residual only: M of the d/M acceptance
    uniform: bool = False            # all EW weights equal: pick by floor(u*d)


class DeviceTreeJoin:
    """Join prepared for jitted EW sampling (chain ⊂ tree ⊂ skeleton+residual).

    Acyclic (tree) joins draw with zero rejection.  Cyclic joins follow the
    paper's §8.2 scheme, all inside the same traced draw: the EW weights are
    computed over the acyclic *skeleton* only, each residual (cycle-closing)
    node keeps the identical sorted composite-key index as a tree node, and a
    draw resolves every residual edge with the same batched sorted-key range
    probe — a uniform pick among the ``d`` matches plus an accumulated
    ``Π d/M`` acceptance test (``M`` = the residual index's max degree, as in
    the host :class:`~repro.core.join_sampler.JoinSampler`).  Residual
    rejections surface through the third element of ``draw``'s return.
    """

    def __init__(self, cat: Catalog, spec: JoinSpec,
                 use_pallas: Optional[bool] = None):
        if use_pallas is None:
            from ...kernels.ops import on_tpu
            use_pallas = on_tpu()
        self.use_pallas = bool(use_pallas)
        self.name = spec.name
        self.spec = spec
        self.attrs = tuple(spec.output_attrs)
        if spec.pushdown_base is not None and spec.pushed_preds:
            # §8.3 pushdown provenance: rebuild the filtered join as validity
            # masks over the shared *base* relations (masked EW prefix sums,
            # cache-shared sorted indexes).  A base-only device limit (the
            # unfiltered columns may span a wider packed domain than the
            # filtered ones) falls back to indexing the filtered relations
            # directly — same sampling law, no index sharing.
            try:
                self._build(cat, spec, spec.pushdown_base, spec.pushed_preds)
                return
            except ValueError:
                pass
        self._build(cat, spec, None, ())

    def _build(self, cat: Catalog, spec: JoinSpec, base: Optional[JoinSpec],
               preds: Tuple) -> None:
        """Build the device state.  ``base is None`` indexes ``spec``'s own
        relations (the standard build).  Otherwise ``spec`` must be a
        :func:`repro.core.predicates.pushdown` of ``base``: tree-node indexes
        are built over the base relations (shared across flavours through the
        catalog-level device cache) and the filters are baked in as
        zero-weight rows in the EW prefix sums — masked-out rows are
        unreachable because their prefix region is flat (``searchsorted``
        side='right' never lands inside it).  Residual (§8.2) nodes keep
        per-flavour *filtered* indexes — their match count ``d`` feeds the
        ``Π d/M`` acceptance, so the index must hold surviving rows only —
        and the ``uniform`` floor(u·d) shortcut is disabled under a mask for
        the same reason."""
        js = JoinSampler(cat, spec, method="ew")  # reuse host weight computation
        self.node_cfgs: List[_NodeCfg] = []
        self.sorted_keys: List[jnp.ndarray] = []
        self.perm: List[jnp.ndarray] = []
        self.wprefix: List[jnp.ndarray] = []
        self.cols: List[Dict[str, jnp.ndarray]] = []
        self._prepped: List[object] = []
        masked = base is not None
        if masked:
            from ..predicates import relation_mask
            base_rels = {bn.alias: bn.relation for bn in base.nodes}
            cache = _device_index_cache(cat)
            widths = _attr_widths(base)
        else:
            widths = _attr_widths(spec)

        def _mask_of(alias: str, filtered_nrows: int):
            rel_b = base_rels.get(alias)
            if rel_b is None:
                raise ValueError(
                    f"jax backend: pushdown base of {spec.name!r} has no "
                    f"node {alias!r}")
            m = relation_mask(rel_b, preds)
            if m is None:
                m = np.ones(rel_b.nrows, dtype=bool)
            if int(m.sum()) != filtered_nrows:
                raise ValueError(
                    f"jax backend: pushdown provenance of {spec.name!r} is "
                    f"stale for node {alias!r} (mask keeps {int(m.sum())} "
                    f"rows, the filtered relation has {filtered_nrows})")
            return rel_b, m

        produced = set(js.root_rel.attrs)
        for n in js.order[1:]:
            rel = js._reduced[n.alias]
            radices = tuple(widths[a] for a in n.edge_attrs)
            dom = 1
            for w in radices:
                dom *= w
            if dom >= _I32_LIM:
                raise ValueError(
                    f"jax backend: packed edge-key domain of node {n.alias!r} "
                    f"(relation {rel.name!r}, edge attrs "
                    f"{tuple(n.edge_attrs)!r}) spans {dom} key combinations "
                    f"needing {int(dom).bit_length()} bits, but the device "
                    "key substrate is int32 (31 usable bits). Re-encode the "
                    "dictionary, use backend='numpy', or see the ROADMAP item "
                    "on int64/two-limb packed keys for the device-side fix")
            use_base = masked and n.kind != "residual"
            if use_base:
                rel_b, m = _mask_of(n.alias, rel.nrows)
                perm, skeys_dev, perm_dev, prepped = _cached_node_index(
                    cache, rel_b, tuple(n.edge_attrs), radices,
                    self.use_pallas)
                # scatter the filtered EW weights onto the base rows (the
                # pushdown filter preserves row order) — masked-out rows get
                # weight 0 and are never picked by the inverse-CDF step
                w = np.zeros(rel_b.nrows, dtype=np.float64)
                w[np.nonzero(m)[0]] = js.node_weights[n.alias]
                # the uniform floor(u·d) shortcut picks among *index* rows,
                # so any mask forces the weighted inverse-CDF path
                uniform = (bool(m.all()) and bool(w.size)
                           and float(w.flat[0]) > 0
                           and bool(np.all(w == w.flat[0])))
                if uniform:
                    wp = np.zeros(1, dtype=np.float64)
                else:
                    wp = np.zeros(rel_b.nrows + 1, dtype=np.float64)
                    np.cumsum(w[perm], out=wp[1:])
                col_rel = rel_b
                cols = {a: _cached_col(cache, rel_b, a)
                        for a in rel_b.attrs if a not in produced}
            else:
                key = _pack_np([rel.columns[a] for a in n.edge_attrs],
                               radices)
                perm = np.argsort(key, kind="stable")
                skeys_dev = jnp.asarray(key[perm].astype(np.int32))
                perm_dev = jnp.asarray(perm.astype(np.int32))
                prepped = None
                if self.use_pallas:
                    from ...kernels.searchsorted import PreparedKeys
                    prepped = PreparedKeys(key[perm])
                uniform = False
                if n.kind == "residual":
                    # §8.2: residual picks are uniform among matches via
                    # floor(u*d) in _residual_step — no weight prefix needed;
                    # the EW weights cover the skeleton only (host parity)
                    wp = np.zeros(1, dtype=np.float64)
                else:
                    w = js.node_weights[n.alias]
                    # equal-weight nodes (leaves always; any node whose rows
                    # all continue identically) pick uniformly among the d
                    # matches — same law as the inverse-CDF pick, one
                    # searchsorted cheaper
                    uniform = (bool(w.size) and float(w.flat[0]) > 0
                               and bool(np.all(w == w.flat[0])))
                    if uniform:
                        wp = np.zeros(1, dtype=np.float64)
                    else:
                        wp = np.zeros(rel.nrows + 1, dtype=np.float64)
                        np.cumsum(w[perm], out=wp[1:])
                col_rel = rel
                cols = {a: jnp.asarray(_as_i32(c, f"{rel.name}.{a}"))
                        for a, c in rel.columns.items()
                        if a not in produced}
            new_attrs = tuple(a for a in col_rel.attrs if a not in produced)
            produced.update(col_rel.attrs)
            self.node_cfgs.append(_NodeCfg(
                n.alias, tuple(n.edge_attrs), radices, new_attrs,
                kind=n.kind, max_degree=int(js.edges[n.alias].max_degree),
                uniform=uniform))
            self.sorted_keys.append(skeys_dev)
            self.perm.append(perm_dev)
            self.wprefix.append(jnp.asarray(wp, jnp.float32))
            self.cols.append(cols)
            self._prepped.append(prepped)

        self.has_residual = any(c.kind == "residual" for c in self.node_cfgs)
        if masked:
            rel_b0, m0 = _mask_of(js.order[0].alias, js.root_rel.nrows)
            w0 = np.zeros(rel_b0.nrows, dtype=np.float64)
            w0[np.nonzero(m0)[0]] = np.diff(
                np.asarray(js.root_weight_prefix, np.float64))
            self.host_root_cols = {a: _as_i32(c, f"root.{a}")
                                   for a, c in rel_b0.columns.items()}
            self.root_cols = {a: _cached_col(cache, rel_b0, a)
                              for a in rel_b0.columns}
            wp0 = np.zeros(rel_b0.nrows + 1, dtype=np.float64)
            np.cumsum(w0, out=wp0[1:])
            self.host_root_wprefix = wp0
            self.n_root = rel_b0.nrows
        else:
            self.host_root_cols = {a: _as_i32(c, f"root.{a}")
                                   for a, c in js.root_rel.columns.items()}
            self.root_cols = {a: jnp.asarray(c)
                              for a, c in self.host_root_cols.items()}
            # float64 host prefix retained: the sharding layer cuts
            # weight-quantile root ranges from it
            # (repro.core.sharding.catalog.ShardedTreeJoin)
            self.host_root_wprefix = np.asarray(js.root_weight_prefix,
                                                np.float64)
            self.n_root = js.root_rel.nrows
        self.root_wprefix = jnp.asarray(self.host_root_wprefix, jnp.float32)
        self.total_weight = float(js.root_weight_total)
        self._empty = js.is_empty()

    def is_empty(self) -> bool:
        return self._empty

    # -- range probe: jnp.searchsorted, or the two-phase Pallas pipeline ------
    # analysis: traced
    def _ranges(self, i: int, q: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if not self.use_pallas:
            sk = self.sorted_keys[i]
            return (jnp.searchsorted(sk, q, side="left").astype(jnp.int32),
                    jnp.searchsorted(sk, q, side="right").astype(jnp.int32))
        from ...kernels.ops import default_interpret
        from ...kernels.searchsorted import QUERY_TILE, _searchsorted_i32
        prep = self._prepped[i]
        b = q.shape[0]
        pad = (-b) % QUERY_TILE
        qp = jnp.pad(q, (0, pad))
        qt = qp.shape[0] // QUERY_TILE
        # keys are non-negative int32, so the 64-bit split is (hi=0, lo=q^MIN)
        q_lo = (qp ^ jnp.int32(-(1 << 31))).reshape(qt, QUERY_TILE)
        q_hi = jnp.zeros_like(q_lo)
        lo, hi = _searchsorted_i32(q_hi, q_lo, prep.f_hi2, prep.f_lo2,
                                   prep.keys2d_hi, prep.keys2d_lo,
                                   n_chunks=prep.n_chunks,
                                   n_fences=prep.n_blocks,
                                   interpret=default_interpret())
        n = jnp.int32(prep.n)
        return (jnp.minimum(lo.reshape(-1)[:b], n),
                jnp.minimum(hi.reshape(-1)[:b], n))

    # -- one batch of EW tree draws (traced; jit at the call site) ------------
    # analysis: traced
    def draw(self, key: jax.Array, batch: int
             ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray, jnp.ndarray]:
        return self.draw_with_root(key, batch, self.root_wprefix,
                                   self.root_cols, self.n_root)

    # analysis: traced
    def _residual_step(self, i: int, cfg: _NodeCfg, rows, ok, acc_ratio, u):
        """One residual edge: sorted-key probe, uniform pick, d/M factor."""
        q = _pack_jnp(rows, cfg.edge_attrs, cfg.radices)
        lo, hi = self._ranges(i, q)
        d = hi - lo
        off = jnp.floor(u * jnp.maximum(d, 1).astype(jnp.float32)
                        ).astype(jnp.int32)
        pos = lo + jnp.minimum(off, jnp.maximum(d - 1, 0))
        ok = ok & (d > 0)
        acc_ratio = acc_ratio * (d.astype(jnp.float32)
                                 / jnp.float32(max(cfg.max_degree, 1)))
        child = self.perm[i][jnp.clip(pos, 0, self.perm[i].shape[0] - 1)]
        for a, c in self.cols[i].items():
            rows[a] = c[child]
        return rows, ok, acc_ratio

    # analysis: traced
    def draw_with_root(self, key: jax.Array, batch: int,
                       root_wprefix: jnp.ndarray,
                       root_cols: Dict[str, jnp.ndarray], n_root
                       ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray,
                                  jnp.ndarray]:
        """Tree draw with a caller-supplied root slice.

        The sharding layer passes each shard's local root range (weight
        prefix, payload columns, row count); the non-root node indexes are
        this tree's replicated device arrays.  ``draw`` is the degenerate
        whole-root call, so both paths share one op sequence (and a 1-shard
        mesh reproduces unsharded draws bit for bit).

        Returns ``(rows, accept, walk_ok)``: ``walk_ok`` marks walks whose
        every edge (tree and residual) had a match; ``accept`` additionally
        applies the §8.2 residual ``Π d/M`` acceptance test, so
        ``walk_ok & ~accept`` are exactly the residual rejections.  On
        acyclic joins the two are the same array.
        """
        keys = jax.random.split(key, len(self.node_cfgs) + 1
                                + (1 if self.has_residual else 0))
        u0 = jax.random.uniform(keys[0], (batch,))
        r_pos, ok = _inverse_cdf_pick(
            root_wprefix, jnp.zeros((batch,), jnp.int32),
            jnp.full((batch,), n_root, jnp.int32), u0)
        rows = {a: c[r_pos] for a, c in root_cols.items()}
        acc_ratio = jnp.ones((batch,), jnp.float32)
        for i, cfg in enumerate(self.node_cfgs):
            u = jax.random.uniform(keys[i + 1], (batch,))
            if cfg.kind == "residual":
                rows, ok, acc_ratio = self._residual_step(
                    i, cfg, rows, ok, acc_ratio, u)
                continue
            q = _pack_jnp(rows, cfg.edge_attrs, cfg.radices)
            lo, hi = self._ranges(i, q)
            if cfg.uniform:
                d = hi - lo
                off = jnp.floor(u * jnp.maximum(d, 1).astype(jnp.float32)
                                ).astype(jnp.int32)
                pos = lo + jnp.minimum(off, jnp.maximum(d - 1, 0))
                ok = ok & (d > 0)
            else:
                pos, alive = _inverse_cdf_pick(self.wprefix[i], lo, hi, u)
                ok = ok & alive & (hi > lo)
            child = self.perm[i][jnp.clip(pos, 0, self.perm[i].shape[0] - 1)]
            for a, c in self.cols[i].items():
                rows[a] = c[child]
        if not self.has_residual:
            return rows, ok, ok
        u_acc = jax.random.uniform(keys[-1], (batch,))
        return rows, ok & (u_acc < acc_ratio), ok


# ---------------------------------------------------------------------------
# Device-resident membership (sorted-row-fingerprint lookups)
# ---------------------------------------------------------------------------


class DeviceJoinMembership:
    """Batched 'is tuple in join J' probes on device.

    Mirrors the host :class:`~repro.core.membership.MembershipProber`
    semantics: a tuple is in the join iff every base relation contains the
    tuple's projection onto that relation's attributes (the shared output
    schema makes connectivity automatic).
    """

    def __init__(self, spec: JoinSpec):
        self.join_name = spec.name
        # §8.3 rejection predicates: membership in the *filtered* join is the
        # base membership AND the predicate over the tuple's own columns
        # (predicates constrain output attributes, so no relation filtering
        # is needed).  Unlowerable predicates raise ValueError here and the
        # backend degrades probing to the host prober.
        self._pred_fn = None
        if spec.reject_preds:
            from ..predicates import compile_preds_jnp
            self._pred_fn = compile_preds_jnp(spec.reject_preds,
                                              spec.output_attrs)
        # (attrs, sorted_fp1, fp2_in_fp1_order, kmax, nrows) per base relation
        self.rels: List[Tuple[Tuple[str, ...], jnp.ndarray, jnp.ndarray,
                              int, int]] = []
        seen = set()
        for node in spec.nodes:
            rel = node.relation
            attrs = tuple(sorted(rel.attrs))
            # dedup on the host Catalog.rowset cache key, so repeated nodes
            # over one relation build one index but distinct relations that
            # merely share a name are still probed (host parity)
            if (rel.name, attrs) in seen:
                continue
            seen.add((rel.name, attrs))
            for a in attrs:
                _as_i32(rel.columns[a], f"{rel.name}.{a}")  # domain check
            fp1 = fp32_np([rel.columns[a] for a in attrs], salt=1)
            fp2 = fp32_np([rel.columns[a] for a in attrs], salt=2)
            order = np.argsort(fp1, kind="stable")
            s1 = fp1[order]
            if s1.shape[0]:
                _, counts = np.unique(s1, return_counts=True)
                kmax = int(counts.max())
            else:
                kmax = 0
            self.rels.append((attrs, jnp.asarray(s1), jnp.asarray(fp2[order]),
                              kmax, int(rel.nrows)))

    # analysis: traced
    def contains(self, rows: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Traced probe: rows are device int32 columns of the output schema."""
        b = rows[next(iter(rows))].shape[0]
        res = (jnp.ones((b,), bool) if self._pred_fn is None
               else self._pred_fn(rows))
        for attrs, s1, s2, kmax, n in self.rels:
            if n == 0:
                return jnp.zeros((b,), bool)
            q1 = fp32_jnp([rows[a] for a in attrs], salt=1)
            q2 = fp32_jnp([rows[a] for a in attrs], salt=2)
            lo = jnp.searchsorted(s1, q1, side="left")
            m = jnp.zeros((b,), bool)
            for k in range(kmax):  # duplicate window (kmax is tiny, static)
                pos = jnp.minimum(lo + k, n - 1)
                m = m | ((lo + k < n) & (s1[pos] == q1) & (s2[pos] == q2))
            res = res & m
        return res


# ---------------------------------------------------------------------------
# Backend protocol implementations
# ---------------------------------------------------------------------------


class JaxCandidateSource:
    """CandidateSource over a :class:`DeviceTreeJoin`.

    Carries its own PRNG key; the host ``rng`` argument of ``draw`` is
    ignored (documented deviation — the numpy and jax engines are
    distributionally, not bitwise, equivalent).
    """

    def __init__(self, tree: DeviceTreeJoin, seed: int = 0,
                 device_batch: int = 4096):
        self.join_name = tree.name
        self.tree = tree
        self.attrs = tree.attrs
        self.key = jax.random.PRNGKey(seed)
        self._batch = int(device_batch)
        self._draw_jit = jax.jit(functools.partial(tree.draw,
                                                   batch=self._batch))
        # buffer of accepted-but-unserved rows: device rounds are fixed-width,
        # so small draws (OnlineUnionSampler asks for 1 at a time) are served
        # from the remainder of the last round instead of a fresh round each.
        self._buf: Optional[Rows] = None
        self._buf_pos = 0
        self._res_rej = 0
        # double-buffered dispatch: the next device round is launched before
        # the current one's rows are compacted on the host, so device compute
        # hides behind the host-side top-up work (serving path)
        self._inflight = None

    def is_empty(self) -> bool:
        return self.tree.is_empty()

    def pop_residual_rejects(self) -> int:
        """Residual (§8.2 cyclic) rejections since the last pop."""
        n, self._res_rej = self._res_rej, 0
        return n

    def _dispatch(self):
        """Launch one device round without blocking (JAX async dispatch)."""
        self.key, sub = jax.random.split(self.key)
        return self._draw_jit(sub)

    def _refill(self) -> int:
        """Drain the in-flight device round into the buffer and immediately
        dispatch the next one, so round *k+1* computes on device while the
        host compacts round *k*'s rows.  Returns rows banked."""
        pending = self._inflight if self._inflight is not None \
            else self._dispatch()
        self._inflight = self._dispatch()
        rows, ok, walk_ok = pending
        ok = np.asarray(ok)
        if self.tree.has_residual:
            self._res_rej += int(np.asarray(walk_ok).sum() - ok.sum())
        idx = np.nonzero(ok)[0]
        # copy=False: the gather already materialises int64-compatible rows,
        # so a matching dtype round-trips without a second allocation
        self._buf = {a: np.asarray(rows[a])[idx].astype(np.int64, copy=False)
                     for a in self.attrs}
        self._buf_pos = 0
        return int(idx.shape[0])

    def draw(self, rng: np.random.Generator, count: int,
             batch: Optional[int] = None) -> Tuple[Rows, int]:
        if self.is_empty():
            raise EmptyJoinError(f"join {self.join_name!r} is empty")
        # fast path: the buffer already covers the request — serve one
        # zero-copy slice without re-entering the refill machinery at all
        if (self._buf is not None
                and self._buf_pos + count <= rows_length(self._buf)):
            lo, hi = self._buf_pos, self._buf_pos + count
            self._buf_pos = hi
            return {a: c[lo:hi] for a, c in self._buf.items()}, 0
        got: List[Rows] = []
        draws = 0
        have = 0
        # round budget scales with the request (device rounds are fixed-width;
        # the numpy source instead grows its batch with `count`)
        max_rounds = 1000 + 20 * (count // self._batch + 1)
        for _ in range(max_rounds):
            if self._buf is None or self._buf_pos >= rows_length(self._buf):
                draws += self._batch
                if self._refill() == 0:
                    continue
            lo = self._buf_pos
            hi = min(lo + count - have, rows_length(self._buf))
            got.append({a: c[lo:hi] for a, c in self._buf.items()})
            self._buf_pos = hi
            have += hi - lo
            if have >= count:
                break
        else:
            raise RuntimeError(f"JaxCandidateSource({self.join_name}): "
                               "round budget exhausted")
        if len(got) == 1:
            return got[0], draws
        return ({a: np.concatenate([g[a] for g in got])
                 for a in self.attrs}, draws)


class JaxMembershipOracle:
    """MembershipOracle facade over per-join device membership indexes.

    Host-facing: accepts numpy rows, pads to power-of-two buckets (bounding
    the number of jit retraces), probes on device, returns numpy booleans.
    """

    def __init__(self, members: Dict[str, DeviceJoinMembership],
                 output_attrs: Sequence[str]):
        self.members = members
        self.output_attrs = list(output_attrs)
        self._fns = {name: jax.jit(m.contains) for name, m in members.items()}

    @staticmethod
    def _bucket(n: int) -> int:
        b = 256
        while b < n:
            b <<= 1
        return b

    def contains(self, join_name: str, rows: Rows) -> np.ndarray:
        n = rows_length(rows)
        if n == 0:
            return np.zeros(0, dtype=bool)
        p = self._bucket(n)
        dev = {a: jnp.asarray(np.pad(_as_i32(np.asarray(rows[a])[:n],
                                             f"probe.{a}"), (0, p - n)))
               for a in self.output_attrs}
        out = self._fns[join_name](dev)
        return np.asarray(out)[:n]

    def membership_matrix(self, rows: Rows,
                          join_names: Optional[Sequence[str]] = None
                          ) -> np.ndarray:
        names = list(join_names) if join_names is not None else list(self.members)
        return np.stack([self.contains(nm, rows) for nm in names], axis=1)


class JaxBackend(Backend):
    """Device-resident engine: tree candidate sources + membership indexes."""

    name = "jax"

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec],
                 join_method: str = "ew", seed: int = 0,
                 device_batch: int = 4096,
                 use_pallas: Optional[bool] = None):
        if join_method != "ew":
            obs.record_fallback("join_method", detail=join_method)
            raise ValueError("jax backend: only method='ew' runs on device "
                             "(eo/wj walks stay on the numpy backend)")
        self.cat = cat
        self.joins = list(joins)
        schemas = {tuple(sorted(j.output_attrs)) for j in self.joins}
        if len(schemas) > 1:
            raise ValueError(
                f"joins must share an output schema; got {sorted(schemas)}")
        self.attrs = list(self.joins[0].output_attrs)
        # per-join degrade: a join that trips a device limit (packed edge-key
        # domain over int32, negative dict values) falls back to the host
        # candidate source instead of failing the whole union; fused rounds
        # need every piece on device, so they disable when any join degrades
        self.trees: Dict[str, DeviceTreeJoin] = {}
        self.degraded: Dict[str, str] = {}          # join name -> reason
        for j in self.joins:
            try:
                self.trees[j.name] = DeviceTreeJoin(cat, j,
                                                    use_pallas=use_pallas)
            except ValueError as e:
                self.degraded[j.name] = str(e)
                obs.record_fallback("int32_domain", detail=str(e),
                                    join=j.name)
        if self.degraded:
            import warnings
            warnings.warn(
                "jax backend: joins "
                f"{sorted(self.degraded)} fall back to host candidate draws "
                f"({'; '.join(sorted(set(self.degraded.values())))}); fused "
                "device rounds are disabled for this union", stacklevel=2)
        self._sources: Dict[str, object] = {}
        for i, j in enumerate(self.joins):
            if j.name in self.trees:
                self._sources[j.name] = JaxCandidateSource(
                    self.trees[j.name], seed=seed + i,
                    device_batch=device_batch)
            else:
                from .numpy_backend import NumpyCandidateSource
                self._sources[j.name] = NumpyCandidateSource(
                    cat, j, method=join_method)
        # replicated membership indexes are built lazily: the mesh-sharded
        # engine (repro.core.sharding) keeps its own hash-partitioned
        # indexes and must not pay for (or hold) the full replicated ones
        self._members: Optional[Dict[str, DeviceJoinMembership]] = None
        self._oracle = None

    @property
    def members(self) -> Dict[str, DeviceJoinMembership]:
        if self._members is None:
            self._members = {j.name: DeviceJoinMembership(j)
                             for j in self.joins}
        return self._members

    def source(self, join_name: str):
        return self._sources[join_name]

    def oracle(self):
        if self._oracle is None:
            try:
                self._oracle = JaxMembershipOracle(self.members, self.attrs)
            except ValueError as e:
                # same degrade rule as the draw side: out-of-domain values
                # keep membership on the (128-bit, exact) host prober
                import warnings
                warnings.warn(
                    f"jax backend: device membership unavailable ({e}); "
                    "probing through the host oracle", stacklevel=2)
                obs.record_fallback("host_oracle", detail=str(e))
                from ..membership import MembershipProber
                self._oracle = MembershipProber(self.cat, self.joins)
        return self._oracle

    def supports_fused_rounds(self) -> bool:
        return not self.degraded


# ---------------------------------------------------------------------------
# Fused Algorithm-1 rounds — one-round program + the persistent device loop
# ---------------------------------------------------------------------------


# SamplerStats fields the fused engines accumulate as one device vector
# (fetched once per sample() call in device mode)
_STAT_FIELDS = ("iterations", "candidate_draws", "cover_rejects",
                "residual_rejects", "pred_rejects", "dropped_slots")

# Per-piece round counters carried as one (nj, 5) int32 matrix in the
# persistent loop (device mode) / accumulated by the numpy twin (host mode)
# and surfaced at the same single host sync as the scalar stats vector.
# Columns: candidate draws, cover-accepted rows, §8.2 residual rejections,
# rows drained from the surplus bank, and the post-round bank-occupancy
# high-water mark (max over the call; folded with max across calls).
PIECE_STAT_FIELDS = ("draws", "accepts", "residual_rejects",
                     "bank_drained", "bank_hwm")


def _dispatch_annotation():
    """Host-side profiler annotation around loop dispatch (REPRO_OBS_TRACE)."""
    if obs.trace_annotations_enabled():
        return jax.profiler.TraceAnnotation("repro/sample_dispatch")
    return contextlib.nullcontext()


def _cover_cum(probs_base: jnp.ndarray, dead: jnp.ndarray):
    """Dead-masked, renormalised selection CDF + unreachable flag.

    Shared by the host-driven round wrapper and the device loop body so the
    float32 arithmetic (and hence every categorical pick) is identical on
    both paths — the parity tests pin them bit for bit."""
    p = jnp.where(dead, jnp.float32(0), probs_base)
    s = jnp.sum(p)
    return jnp.cumsum(p) / jnp.maximum(s, jnp.float32(1e-30)), s <= 0


def _piece_batches(probs, round_batch: int, balance: str,
                   slack: float) -> Tuple[int, ...]:
    """Static per-join candidate widths for one round.

    ``balance="cover"`` sizes each join's draw batch proportionally to its
    cover selection probability (head-room ``slack``, floor 256, rounded to
    multiples of 128 to bound shape variety) instead of drawing
    ``round_batch`` candidates from *every* join — most of a round's compute
    is the per-join draws, and a piece with 5 % selection mass can never
    emit more than ~5 % of the round's slots.  Undershoot is harmless: the
    shortfall carry tops the piece up next round.  ``balance="full"`` keeps
    the uniform-width behaviour."""
    nj = len(probs)
    if balance != "cover":
        return (int(round_batch),) * nj
    p = np.maximum(np.asarray(probs, np.float64), 0)
    s = p.sum()
    if s <= 0:
        return (int(round_batch),) * nj
    out = []
    for j in range(nj):
        want = int(np.ceil(slack * (p[j] / s) * round_batch))
        b = max(256, ((want + 127) // 128) * 128)
        out.append(min(int(round_batch), b))
    return tuple(out)


def _emit_and_bank(out, pos, bank, head, count,
                   cols, dt, ft, acc, cap: int, C: int, W: int,
                   bank_base=None, fresh_base=None):
    """Scatter one round's emission into the output buffer + roll the banks.

    Row layout: all attributes plus the home piece id travel as one
    ``(rows, A+1)`` int32 matrix, so every emission/banking step is a
    single scatter (or gather) op instead of one per attribute —
    XLA:CPU scatter has high per-op cost.  ``out`` is ``(C, A+1)``,
    ``bank`` is ``(nj, cap, A+1)``, ``cols[j]`` is the piece's
    accepted-compacted ``(B_j, A+1)`` matrix.

    Emission order (mirrored exactly by the host loop): pieces in cover
    order; per piece the ``dt`` banked rows (FIFO, oldest first) then the
    ``ft`` freshly accepted rows.  Surplus accepted rows are pushed at the
    ring tail — which the take leaves in place (``tail = head + count``
    before both operations).  All scatters use ``mode="drop"`` with an
    out-of-range destination (``C`` / ``cap``) as the mask.

    ``bank_base``/``fresh_base`` override the per-piece output offsets of
    the banked/fresh rows — the sharded loop passes globally computed
    offsets so each shard scatters its rows straight to their final global
    positions (the default packs this shard's take contiguously at ``pos``).
    """
    nj = dt.shape[0]
    take = dt + ft
    if bank_base is None:
        base = pos + jnp.cumsum(take) - take        # exclusive prefix
        bank_base = base
        fresh_base = base + dt
    # banked rows: one (nj, W, A+1) ring gather + one masked scatter
    r = jnp.arange(W, dtype=jnp.int32)
    bmask = r[None, :] < dt[:, None]
    bidx = (head[:, None] + r[None, :]) % cap
    bdst = jnp.where(bmask, bank_base[:, None] + r[None, :], C).reshape(-1)
    jrow = jnp.arange(nj, dtype=jnp.int32)[:, None]
    bvals = bank[jrow, bidx]                        # (nj, W, A+1)
    out = out.at[bdst].set(bvals.reshape(nj * W, -1), mode="drop")
    # fresh rows + surplus push, per piece (static per-join widths)
    push = jnp.minimum(acc - ft, cap - (count - dt))
    for j in range(nj):
        cj = cols[j]
        bj = cj.shape[0]
        rj = jnp.arange(bj, dtype=jnp.int32)
        fdst = jnp.where(rj < ft[j], fresh_base[j] + rj, C)
        pidx = jnp.where((rj >= ft[j]) & (rj < ft[j] + push[j]),
                         (head[j] + count[j] + rj - ft[j]) % cap, cap)
        out = out.at[fdst].set(cj, mode="drop")
        bank = bank.at[j, pidx].set(cj, mode="drop")
    head = (head + dt) % cap
    count = count - dt + push
    return out, pos + jnp.sum(take), bank, head, count


class _ReadyHandle:
    """Degenerate async handle: the sample already exists."""

    def __init__(self, ss):
        self._ss = ss

    def result(self):
        return self._ss


class _PendingSample:
    """In-flight device-loop sample.

    The whole multi-round loop is already dispatched (JAX async dispatch);
    ``result()`` performs the single device→host fetch, folds the stats
    vector, applies the host-drawn output shuffle and builds the SampleSet.
    The serving path dispatches call *k+1* before draining call *k*.
    """

    def __init__(self, sampler, n, out, total, rounds, fail,
                 stats_vec, piece_vec, shuffle):
        self._sampler = sampler
        self._n = int(n)
        self._out = out
        self._total = total
        self._rounds = rounds
        self._fail = fail
        self._stats_vec = stats_vec
        self._piece_vec = piece_vec
        self._shuffle = shuffle
        self._done = None

    def result(self):
        if self._done is not None:
            return self._done
        s = self._sampler
        t0 = time.perf_counter() if obs.enabled() else 0.0
        if bool(np.asarray(self._fail)):
            raise RuntimeError("all cover pieces unreachable")
        total = int(np.asarray(self._total))
        s.last_rounds = int(np.asarray(self._rounds))
        if total < self._n:
            raise RuntimeError("JaxUnionSampler: top-up budget exhausted")
        vec = np.asarray(self._stats_vec)
        for f, v in zip(_STAT_FIELDS, vec):
            setattr(s.stats, f, getattr(s.stats, f) + int(v))
        ema = None
        if s.plan == "adaptive" and obs.enabled() and s._dev_state is not None:
            # snapshot the latest carried EMAs (tiny fetch; result() already
            # syncs) for the repro_engine_piece_ema gauges
            ema = np.asarray(s._dev_state["ema"])
        s._fold_piece_stats(np.asarray(self._piece_vec),
                            rounds=s.last_rounds, samples=self._n, ema=ema)
        mat = s._merge_out(self._out)[:self._n].astype(np.int64)[
            self._shuffle]
        rows = {a: np.ascontiguousarray(mat[:, i])
                for i, a in enumerate(s.attrs)}
        home = np.ascontiguousarray(mat[:, -1])
        from ..relation import fingerprint128
        from ..union_sampler import SampleSet
        fp = fingerprint128([rows[a] for a in sorted(s.attrs)])
        self._done = SampleSet(list(s.attrs), rows, home, fp, s.stats)
        if obs.enabled():
            s._obs_drain_hist().observe(time.perf_counter() - t0)
        return self._done


class JaxUnionSampler:
    """The multi-round Algorithm-1 loop as a single device-resident program.

    Per round (fixed shapes; ``piece_batches[j]`` candidates for join j):

    1. **multinomial cover selection** — per-slot categorical on the piece
       probabilities, histogrammed into per-piece targets (an i.i.d.
       factorisation of the host path's multinomial) and added to the
       shortfall carried from earlier rounds,
    2. **candidate generation for all joins** — one batched EW tree draw per
       join; cyclic pieces verify their residual edges inside the same
       program (sorted-key probes + ``Π d/M`` acceptance, §8.2), so a
       residual rejection simply leaves the slot unaccepted and its target
       flows into the per-piece shortfall carry like any other rejection —
       round shapes stay static and no piece is ever re-selected,
    3. **cover-membership acceptance** — a candidate of piece ``j`` survives
       iff no earlier cover piece contains it (batched device probes),
    4. **compaction** — accepted candidates ranked to the front per join
       (a cumsum scatter, not a sort); the round serves each per-piece
       target first from that piece's FIFO surplus bank, then from the
       fresh accepts, and pushes leftover accepts back into the bank.

    Crucially the shortfall of piece ``j`` stays *assigned to piece j* across
    rounds (it is carried, never re-drawn from the selection distribution):
    re-selecting a piece after a rejection is the printed-pseudocode pitfall
    documented in union_sampler.  Since each round's accepted candidates are
    i.i.d. uniform over their piece, serving a target from the bank (a
    deterministic FIFO over an i.i.d. stream) is unbiased — this is what
    makes the engine a streaming source for serving.

    ``fused_rounds="device"`` (default) runs the *whole* loop — shortfall
    vector, ring-buffer banks, dead-piece detection, output compaction and
    the SamplerStats counters — inside one ``lax.while_loop`` program with
    donated carry buffers, so ``sample(n)`` crosses the device boundary
    once.  ``fused_rounds="host"`` drives the identical round program from a
    host loop with numpy twin banks (one sync per round) — kept for parity
    testing and debugging; the two modes produce bit-identical samples and
    stats from the same seed.
    """

    def __init__(self, backend: JaxBackend, cover, seed: int = 0,
                 round_batch: int = 4096,
                 dead_rounds: int = 8, max_rounds: int = 4096,
                 surplus_cap: Optional[int] = None, stats=None,
                 fused_rounds: str = "device", balance: str = "cover",
                 balance_slack: float = 1.5, predicate=None,
                 plan: str = "static"):
        self.backend = backend
        self.cover = cover
        self.order = list(cover.order)
        self.trees = [backend.trees[n] for n in self.order]
        self.attrs = tuple(backend.attrs)
        # §8.3 predicate lowering, two flavours per cover piece (None = none):
        #  * _pred_fns[j]   — the piece's own acceptance mask: its
        #    reject_preds AND the union-wide predicate, fused between the
        #    candidate draw and the earlier-piece probes;
        #  * _cont_pred_fns[j] — the piece's reject_preds only, ANDed into
        #    *containment* checks against piece j by engines that probe raw
        #    relation fingerprints (the sharded exchange; the replicated
        #    DeviceJoinMembership carries its own equivalent mask).  The
        #    union-wide predicate is excluded: candidates already passed it,
        #    so it cannot separate a tuple from an earlier filtered piece.
        self.predicate = predicate
        from ..predicates import compile_preds_jnp
        gp = tuple(predicate.preds) if predicate is not None else ()
        self._pred_fns = []
        self._cont_pred_fns = []
        for name in self.order:
            spec = backend.trees[name].spec
            own = tuple(spec.reject_preds) + gp
            self._pred_fns.append(
                compile_preds_jnp(own, spec.output_attrs) if own else None)
            self._cont_pred_fns.append(
                compile_preds_jnp(spec.reject_preds, spec.output_attrs)
                if spec.reject_preds else None)
        self.key = jax.random.PRNGKey(seed)
        self.host_rng = np.random.default_rng(seed)
        self.round_batch = int(round_batch)
        self.dead_rounds = int(dead_rounds)
        self.max_rounds = int(max_rounds)
        self.surplus_cap = max(1, 8 * self.round_batch if surplus_cap is None
                               else int(surplus_cap))
        if fused_rounds not in ("device", "host"):
            raise ValueError("fused_rounds must be 'device' or 'host', got "
                             f"{fused_rounds!r}")
        self.fused_rounds = fused_rounds
        if stats is None:
            from ..union_sampler import SamplerStats
            stats = SamplerStats()
        self.stats = stats
        base = np.maximum(np.asarray(cover.selection_probs(), np.float64), 0)
        s = base.sum()
        self._probs_base = jnp.asarray(base / s if s > 0 else base,
                                       jnp.float32)
        self.piece_batches = _piece_batches(base, self.round_batch,
                                            balance, balance_slack)
        # per-piece bank drain cap per round (a semantics constant — the
        # host twin uses the same cap, keeping dt = min(need, count, W)
        # identical).  It bounds the ring gather/scatter width inside the
        # device loop, where XLA:CPU per-op scatter cost dominates; banks
        # stay shallow under cover-balanced batches, so a narrow window
        # drains them just as fast while the wide one mostly moves padding.
        self._drain_w = min(self.round_batch, 256)
        # adaptive round planner (plan="adaptive"): per-piece acceptance
        # EMAs carried on device budget the candidate draws each round and
        # the draw widths shrink to the demand-matched schedule below;
        # plan="static" traces exactly the pre-planner program and stays
        # the bitwise parity oracle.
        if plan not in ("static", "adaptive"):
            raise ValueError(f"plan must be 'static' or 'adaptive', got "
                             f"{plan!r}")
        self.plan = plan
        if plan == "adaptive":
            # masked draw slots still cost full compute under XLA's static
            # shapes, so the planner re-sizes the *widths* themselves:
            # piece j draws ~ slot * p_j / seeded-acceptance candidates,
            # where the slot array is expanded to amortize the fixed
            # per-round dispatch cost (planner.SLOT_EXPANSION)
            self.piece_batches = planner.alloc_batches(
                self.piece_batches, base,
                planner.seed_rates(cover, self._tree_specs())[:, 0],
                planner.adaptive_slot(self.round_batch))
        self._setup_planner()
        self.last_rounds = 0
        # per-piece telemetry (PIECE_STAT_FIELDS columns): counters sum
        # across sample() calls, the bank high-water column folds with max.
        # Filled once per call at the single host sync in both loop modes.
        self.piece_stats = np.zeros((len(self.order),
                                     len(PIECE_STAT_FIELDS)), np.int64)
        self._obs_metrics = None
        self._round_jit = jax.jit(self._round_impl)
        # persistent device-loop state (fused_rounds="device"): PRNG key,
        # shortfall vector, ring banks and dead-piece flags all live on
        # device and carry across sample() calls.  The compile cache is
        # keyed by (capacity class, plan, mode) — not kwargs identity — so
        # flipping `plan` post-build retraces instead of silently reusing
        # the other plan's program, and each class compiles exactly once
        # (audited by repro.analysis.recompile).
        self._loop_cache: Dict[Tuple[int, str, str], object] = {}
        # one entry appended per *trace* of the loop body (Python executes
        # the body only while tracing); the recompile audit reads this
        self._trace_events: List[Tuple[str, int, str]] = []
        self._dev_state = None
        # host-loop twin state (fused_rounds="host"): numpy ring banks with
        # identical FIFO semantics; allocated on first host sample
        nj = len(self.order)
        self._h_dead = np.zeros(nj, dtype=bool)
        self._h_streak = np.zeros(nj, dtype=np.int64)
        self._h_bank = None
        self._h_head = np.zeros(nj, dtype=np.int64)
        self._h_count = np.zeros(nj, dtype=np.int64)

    def _tree_specs(self) -> Dict[str, object]:
        return {n: self.backend.trees[n].spec
                for n in self.order if n in self.backend.trees}

    def _setup_planner(self) -> None:
        """Derive planner constants from the (possibly overridden)
        ``piece_batches``.  Called again by the sharded engine after it
        rescales the per-piece widths to ``world`` shards."""
        if self.plan == "adaptive":
            # expanded selection slots amortize the fixed per-round cost;
            # the demand-matched widths above size the supply to fill them
            self._slot_width = planner.adaptive_slot(self.round_batch)
        else:
            self._slot_width = self.round_batch
        self._ema_shifts = planner.ema_shifts(self.piece_batches)
        self._ema_seed = planner.seed_rates(self.cover, self._tree_specs())
        self._h_ema = None          # host-twin EMA state (lazy copy of seed)
        self._pbatch_i32 = np.asarray(self.piece_batches, np.int32)
        try:
            self._plan_cache_key = planner.plan_key(
                self.backend.cat, self.backend.joins, self.cover)
        except Exception:
            self._plan_cache_key = None

    # -- the fused round program ----------------------------------------------
    def _ensure_device_inputs(self) -> None:
        """Materialise the replicated membership indexes *outside* any trace
        (their device buffers are stored on the index objects; building them
        lazily inside a jit/while_loop trace would store tracers instead).
        The sharded engine keeps its own hash-partitioned indexes and
        overrides this to a no-op."""
        _ = self.backend.members

    def _round_core(self, key: jax.Array, probs_cum: jnp.ndarray,
                    carry_need: jnp.ndarray, extra_target: jnp.ndarray,
                    ema: Optional[jnp.ndarray] = None,
                    bank_count: Optional[jnp.ndarray] = None):
        """One Algorithm-1 round (traceable; shared by the host-driven
        wrapper and the device loop body).  Returns per join the
        accepted-compacted candidate columns plus (ok, residual, accepted,
        predicate-reject) counts and the per-piece need = carry + this
        round's targets.  Under ``plan="adaptive"`` the acceptance EMAs and
        current bank occupancy come in too and the per-piece candidate
        budget goes out as a seventh element."""
        with jax.named_scope("algo1_fused_round"):
            return self._round_core_impl(key, probs_cum, carry_need,
                                         extra_target, ema, bank_count)

    def _round_core_impl(self, key: jax.Array, probs_cum: jnp.ndarray,
                         carry_need: jnp.ndarray, extra_target: jnp.ndarray,
                         ema: Optional[jnp.ndarray] = None,
                         bank_count: Optional[jnp.ndarray] = None):
        nj = len(self.trees)
        adaptive = self.plan == "adaptive"
        # resolved at trace time (first round): keeps the lazy backend
        # membership unbuilt for subclasses that override the round program
        members = [self.backend.members[n] for n in self.order]
        kpick, *jks = jax.random.split(key, nj + 1)
        # (1) multinomial cover selection: categorical picks → histogram
        u = jax.random.uniform(kpick, (self._slot_width,))
        pick = jnp.clip(jnp.searchsorted(probs_cum, u, side="right"
                                         ).astype(jnp.int32), 0, nj - 1)
        valid = (jnp.arange(self._slot_width)
                 < extra_target).astype(jnp.int32)
        need = carry_need + jnp.zeros((nj,), jnp.int32).at[pick].add(valid)
        budget = None
        if adaptive:
            # integer candidate budget from counts only (owed work minus
            # usable bank coverage over the accept EMA) — planner.budget_for
            # is the same fixed-point arithmetic the numpy twin runs, so
            # host/device budgets are bit-identical from identical carries
            budget = planner.budget_for(
                need, bank_count, ema[:, 0],
                jnp.asarray(self._pbatch_i32), self._drain_w, jnp)
        # (2)+(3) per join: batched candidate draw (incl. §8.2 residual-edge
        # verification for cyclic pieces) + fused §8.3 predicate acceptance
        # + earlier-piece rejection
        cols, okc, resc, accc, predc = [], [], [], [], []
        for j, tree in enumerate(self.trees):
            bj = self.piece_batches[j]
            rows, acc, walk_ok = tree.draw(jks[j], bj)
            if budget is not None:
                # budget mask: the first budget[j] slots of an i.i.d.
                # candidate stream — a count-derived prefix, so the
                # surviving candidates stay i.i.d. uniform
                elig = jnp.arange(bj) < budget[j]
                acc = acc & elig
                walk_ok = walk_ok & elig
            resc.append(jnp.sum(walk_ok) - jnp.sum(acc))
            pf = self._pred_fns[j]
            if pf is None:
                predc.append(jnp.int32(0))
            else:
                pok = pf(rows)
                predc.append(jnp.sum(acc & ~pok).astype(jnp.int32))
                acc = acc & pok
            for q in range(j):             # pieces earlier in cover order
                acc = acc & ~members[q].contains(rows)
            # (4) compaction: accepted rows to the front in slot order — a
            # rank scatter (cumsum - 1) on the (B_j, A+1) row matrix (last
            # column = home piece id, so it rides every later scatter for
            # free): one scatter per piece, cheaper than the per-attr argsort
            dst = jnp.where(acc, jnp.cumsum(acc) - 1, bj)
            mat = jnp.stack([rows[a].astype(jnp.int32)
                             for a in self.attrs]
                            + [jnp.full(bj, j, jnp.int32)], axis=1)
            cols.append(jnp.zeros((bj, mat.shape[1]), jnp.int32)
                        .at[dst].set(mat, mode="drop"))
            okc.append(jnp.sum(walk_ok))
            accc.append(jnp.sum(acc))
        out = (cols, jnp.stack(okc).astype(jnp.int32),
               jnp.stack(resc).astype(jnp.int32),
               jnp.stack(accc).astype(jnp.int32),
               jnp.stack(predc).astype(jnp.int32), need)
        if adaptive:
            out = out + (budget.astype(jnp.int32),)
        return out

    def _round_impl(self, probs_base: jnp.ndarray, dead: jnp.ndarray,
                    carry_need: jnp.ndarray, extra_target: jnp.ndarray,
                    key: jax.Array, ema: Optional[jnp.ndarray] = None,
                    bank_count: Optional[jnp.ndarray] = None):
        """Host-driven entry point: one jitted round (fused_rounds="host")."""
        probs_cum, bad = _cover_cum(probs_base, dead)
        res = self._round_core(key, probs_cum, carry_need, extra_target,
                               ema, bank_count)
        return res + (bad,)

    # -- the persistent device loop -------------------------------------------
    def _init_state(self):
        """Fresh device carry: key + shortfall + ring banks + dead flags
        (+ the planner's acceptance EMAs under ``plan="adaptive"``)."""
        nj, cap = len(self.order), self.surplus_cap
        st = {
            "key": self.key,
            "owed": jnp.zeros(nj, jnp.int32),
            "dead": jnp.zeros(nj, dtype=bool),
            "streak": jnp.zeros(nj, jnp.int32),
            "bank": jnp.zeros((nj, cap, len(self.attrs) + 1), jnp.int32),
            "bank_head": jnp.zeros(nj, jnp.int32),
            "bank_count": jnp.zeros(nj, jnp.int32),
        }
        if self.plan == "adaptive":
            st["ema"] = jnp.asarray(self._ema_seed)
        return st

    def _build_loop(self, C: int):
        """Compile the whole multi-round loop for output capacity ``C``.

        The carry (state + output buffers) is donated, so repeated calls
        reuse the same device allocations; everything the host needs back —
        samples, home pieces, total, round count and the stats vector —
        comes out of the single program invocation."""
        cap = self.surplus_cap
        W = min(self._drain_w, cap)
        bt = int(sum(self.piece_batches))
        adaptive = self.plan == "adaptive"
        max_rounds = jnp.int32(self.max_rounds)
        dead_rounds = jnp.int32(self.dead_rounds)

        pbatch = jnp.asarray(self.piece_batches, jnp.int32)
        shifts = jnp.asarray(self._ema_shifts)

        def loop_fn(state, out, n, probs_base):
            self._trace_events.append(("loop", C, self.plan))

            def cond(c):
                total, rounds, fail = c[2], c[3], c[4]
                return (total < n) & (rounds < max_rounds) & ~fail

            def body(c):
                state, out, total, rounds, fail, stats, pstats = c
                probs_cum, bad = _cover_cum(probs_base, state["dead"])
                key, kround = jax.random.split(state["key"])
                extra = jnp.clip(n - total - jnp.sum(state["owed"]),
                                 0, self._slot_width)
                if adaptive:
                    cols, okc, resc, accc, predc, need, budget = \
                        self._round_core(kround, probs_cum, state["owed"],
                                         extra, state["ema"],
                                         state["bank_count"])
                else:
                    budget = None
                    cols, okc, resc, accc, predc, need = self._round_core(
                        kround, probs_cum, state["owed"], extra)
                # bank take (FIFO, capped) → fresh take → carried shortfall
                dt = jnp.minimum(jnp.minimum(need, state["bank_count"]),
                                 self._drain_w)
                ft = jnp.minimum(need - dt, accc)
                out2, total2, bank2, head2, count2 = _emit_and_bank(
                    out, total, state["bank"],
                    state["bank_head"], state["bank_count"],
                    cols, dt, ft, accc, cap, C, W)
                shortfall = need - dt - ft
                # dead-piece bookkeeping (same rules as the host twin):
                # stray picks on dead pieces are dropped; a live piece that
                # keeps a target but yields nothing for dead_rounds rounds
                # is empty in reality (estimation noise) — drop it
                dropped = jnp.sum(jnp.where(state["dead"], shortfall, 0))
                shortfall = jnp.where(state["dead"], 0, shortfall)
                trig = (shortfall > 0) & (accc == 0) & (count2 == 0)
                streak = jnp.where(state["dead"], state["streak"],
                                   jnp.where(trig, state["streak"] + 1, 0))
                newly = ~state["dead"] & (streak >= dead_rounds)
                dropped = dropped + jnp.sum(jnp.where(newly, shortfall, 0))
                shortfall = jnp.where(newly, 0, shortfall)
                # adaptive rounds draw only the budgeted slots; static rounds
                # spend the full static width every round
                drawn = (jnp.sum(budget) if adaptive
                         else jnp.int32(bt))
                stats2 = stats + jnp.stack(
                    [drawn.astype(jnp.int32), drawn.astype(jnp.int32),
                     (jnp.sum(okc) - jnp.sum(resc) - jnp.sum(predc)
                      - jnp.sum(accc)).astype(jnp.int32),
                     jnp.sum(resc).astype(jnp.int32),
                     jnp.sum(predc).astype(jnp.int32),
                     dropped.astype(jnp.int32)])
                # per-piece telemetry rides the same carry (PIECE_STAT_FIELDS
                # columns); pure extra outputs — nothing feeds back into the
                # sampling arithmetic, so the emitted stream is unchanged
                pstats2 = jnp.stack(
                    [pstats[:, 0] + (budget if adaptive else pbatch),
                     pstats[:, 1] + accc,
                     pstats[:, 2] + resc,
                     pstats[:, 3] + dt.astype(jnp.int32),
                     jnp.maximum(pstats[:, 4], count2.astype(jnp.int32))],
                    axis=1)
                state2 = {"key": key,
                          "owed": shortfall.astype(jnp.int32),
                          "dead": state["dead"] | newly,
                          "streak": streak.astype(jnp.int32),
                          "bank": bank2,
                          "bank_head": head2.astype(jnp.int32),
                          "bank_count": count2.astype(jnp.int32)}
                if adaptive:
                    # one EMA step from this round's counts (accept /
                    # walk_ok / residual / pred per budgeted slot)
                    counts = jnp.stack([accc, okc, resc, predc], axis=1)
                    state2["ema"] = planner.ema_update(
                        state["ema"], budget, counts, shifts, jnp)
                # `bad` (unreachable cover) is terminal: the loop exits on
                # `fail` and the host raises, discarding the buffers — no
                # need to gate the state updates (which would force a full
                # copy of the banks + output every round)
                return (state2, out2, total2, rounds + 1,
                        fail | bad, stats2, pstats2)

            init = (state, out, jnp.int32(0), jnp.int32(0),
                    jnp.bool_(False), jnp.zeros(len(_STAT_FIELDS),
                                                jnp.int32),
                    jnp.zeros((len(self.order), len(PIECE_STAT_FIELDS)),
                              jnp.int32))
            return jax.lax.while_loop(cond, body, init)

        return jax.jit(loop_fn, donate_argnums=(0, 1))

    def _loop_for(self, C: int):
        lk = (C, self.plan, self.fused_rounds)
        fn = self._loop_cache.get(lk)
        if fn is None:
            fn = self._build_loop(C)
            self._loop_cache[lk] = fn
        return fn

    def sample_async(self, n: int):
        """Dispatch a full ``sample(n)`` without blocking; returns a handle
        whose ``result()`` fetches the answer.  Device mode dispatches the
        persistent loop (JAX async dispatch) so the serving path can launch
        call *k+1* before draining call *k*; host mode computes eagerly and
        returns a ready handle."""
        from ..union_sampler import empty_sample_set
        if n <= 0:
            return _ReadyHandle(empty_sample_set(list(self.attrs),
                                                 self.stats))
        if self.fused_rounds == "host":
            return _ReadyHandle(self._sample_host(n))
        t0 = time.perf_counter() if obs.enabled() else 0.0
        self._ensure_device_inputs()
        C = 1 << max(10, (int(n) - 1).bit_length())
        if self._dev_state is None:
            self._dev_state = self._init_state()
        out = self._out_buffer(C)
        with _dispatch_annotation():
            st, out, total, rounds, fail, stats, pstats = self._loop_for(C)(
                self._dev_state, out, jnp.int32(n), self._probs_base)
        self._dev_state = st
        # the output shuffle is host randomness, drawn at dispatch time so
        # both modes consume host_rng identically (one permutation per call)
        shuffle = self.host_rng.permutation(n)
        if obs.enabled():
            self._obs_dispatch_hist().observe(time.perf_counter() - t0)
        return _PendingSample(self, n, out, total, rounds, fail, stats,
                              pstats, shuffle)

    def _out_buffer(self, C: int):
        """Fresh output buffer for one device-loop call (donated away)."""
        return jnp.zeros((C, len(self.attrs) + 1), jnp.int32)

    def _merge_out(self, out) -> np.ndarray:
        """Collapse a fetched output buffer to one ``(C, A+1)`` matrix
        (the sharded loop returns one disjointly-filled buffer per shard)."""
        return np.asarray(out)

    def sample(self, n: int):
        if self.fused_rounds == "host":
            return self._sample_host(n)
        t0 = time.perf_counter()
        ss = self.sample_async(n).result()
        if self._plan_cache_key is not None and n > 0:
            # feed the host-side cost model (t_round = c0 + c1*slots); the
            # fastest warm call per round_batch displaces the compile call
            planner.PLAN_CACHE.observe(
                self._plan_cache_key, self.round_batch,
                int(sum(self.piece_batches)), self.last_rounds,
                time.perf_counter() - t0, n)
        return ss

    # -- telemetry surfacing (repro.obs) --------------------------------------
    def piece_stats_dict(self) -> Dict[str, Dict[str, int]]:
        """Cumulative per-piece round counters keyed by join name
        (PIECE_STAT_FIELDS columns; ``bank_hwm`` is a high-water mark)."""
        return {name: {f: int(self.piece_stats[j, i])
                       for i, f in enumerate(PIECE_STAT_FIELDS)}
                for j, name in enumerate(self.order)}

    def _obs_handles(self):
        """Lazily bound metric children (one registry lookup per engine)."""
        if self._obs_metrics is None:
            reg = obs.get_registry()
            per_piece = [
                reg.counter("repro_engine_piece_draws_total",
                            "candidate draws per cover piece", ("join",)),
                reg.counter("repro_engine_piece_accepts_total",
                            "cover-accepted candidates per piece", ("join",)),
                reg.counter("repro_engine_piece_residual_rejects_total",
                            "§8.2 residual rejections per piece", ("join",)),
                reg.counter("repro_engine_piece_bank_drained_total",
                            "rows served from the surplus bank", ("join",)),
            ]
            self._obs_metrics = {
                "piece": [[c.labels(join=n) for c in per_piece]
                          for n in self.order],
                "hwm": reg.gauge("repro_engine_piece_bank_hwm",
                                 "surplus-bank occupancy high-water mark",
                                 ("join",)),
                "waste": reg.gauge(
                    "repro_round_waste_ratio",
                    "1 - accepted/drawn per cover piece (cumulative)",
                    ("join",)),
                "ema": reg.gauge(
                    "repro_engine_piece_ema",
                    "adaptive-planner acceptance EMA (fraction of budget)",
                    ("join", "component")),
                "rounds": reg.counter("repro_engine_rounds_total",
                                      "fused Algorithm-1 rounds run"),
                "samples": reg.counter("repro_engine_samples_total",
                                       "samples emitted by the fused loop"),
                "dispatch": reg.histogram(
                    "repro_engine_dispatch_seconds",
                    "host wall-clock of sample(n) loop dispatch"),
                "drain": reg.histogram(
                    "repro_engine_drain_seconds",
                    "host wall-clock of result fetch + assembly"),
            }
        return self._obs_metrics

    def _obs_dispatch_hist(self):
        return self._obs_handles()["dispatch"]

    def _obs_drain_hist(self):
        return self._obs_handles()["drain"]

    def _fold_piece_stats(self, p: np.ndarray, rounds: int = 0,
                          samples: int = 0,
                          ema: Optional[np.ndarray] = None) -> None:
        """Fold one call's per-piece counter matrix into the cumulative
        engine state (+ registry publication unless REPRO_OBS=off)."""
        p = np.asarray(p, np.int64)
        self.piece_stats[:, :4] += p[:, :4]
        self.piece_stats[:, 4] = np.maximum(self.piece_stats[:, 4], p[:, 4])
        self.stats.samples_emitted += int(samples)
        if not obs.enabled():
            return
        h = self._obs_handles()
        for j, name in enumerate(self.order):
            children = h["piece"][j]
            for i, child in enumerate(children):
                v = int(p[j, i])
                if v:
                    child.inc(v)
            h["hwm"].labels(join=name).set(int(self.piece_stats[j, 4]))
            draws = int(self.piece_stats[j, 0])
            if draws:
                h["waste"].labels(join=name).set(
                    1.0 - int(self.piece_stats[j, 1]) / draws)
            if ema is not None:
                for i, comp in enumerate(planner.EMA_COMPONENTS):
                    h["ema"].labels(join=name, component=comp).set(
                        float(ema[j, i]) / planner.EMA_ONE)
        if rounds:
            h["rounds"].inc(int(rounds))
        if samples:
            h["samples"].inc(int(samples))

    # -- host twin loop (fused_rounds="host") ---------------------------------
    def _sample_host(self, n: int):
        """Host-driven round loop with numpy twin banks.

        Same round program, same PRNG discipline, same banking semantics as
        the device loop — one device sync per round instead of one per call.
        Kept for parity testing (the device loop is pinned bit-equal to
        this) and as the debugging fallback."""
        from ..union_sampler import SampleSet, empty_sample_set
        if n <= 0:
            return empty_sample_set(list(self.attrs), self.stats)
        self._ensure_device_inputs()
        nj, cap = len(self.order), self.surplus_cap
        if self._h_bank is None:
            self._h_bank = np.zeros((nj, cap, len(self.attrs) + 1),
                                    np.int32)
        bank, head, count = self._h_bank, self._h_head, self._h_count
        dead, streak = self._h_dead, self._h_streak
        bt = int(sum(self.piece_batches))
        adaptive = self.plan == "adaptive"
        if adaptive and self._h_ema is None:
            self._h_ema = self._ema_seed.copy()
        pbatch = np.asarray(self.piece_batches, np.int64)
        # numpy twin of the device loop's per-piece telemetry carry
        pstats = np.zeros((nj, len(PIECE_STAT_FIELDS)), np.int64)
        parts: List[np.ndarray] = []      # (k, A+1) rows + home matrices
        owed = np.zeros(nj, dtype=np.int64)   # per-piece carried shortfall
        total = 0
        rounds = 0
        while total < n:
            rounds += 1
            if rounds > self.max_rounds:
                raise RuntimeError("JaxUnionSampler: top-up budget exhausted")
            extra = max(0, min(n - total - int(owed.sum()),
                               self._slot_width))
            self.key, sub = jax.random.split(self.key)
            if adaptive:
                cols, okc, resc, accc, predc, need, budget, bad = \
                    self._round_jit(
                        self._probs_base, jnp.asarray(dead),
                        jnp.asarray(owed.astype(np.int32)),
                        jnp.int32(extra), sub, jnp.asarray(self._h_ema),
                        jnp.asarray(count.astype(np.int32)))
                budget = np.asarray(budget)
            else:
                budget = None
                cols, okc, resc, accc, predc, need, bad = self._round_jit(
                    self._probs_base, jnp.asarray(dead),
                    jnp.asarray(owed.astype(np.int32)), jnp.int32(extra),
                    sub)
            if bool(np.asarray(bad)):
                raise RuntimeError("all cover pieces unreachable")
            okc = np.asarray(okc).astype(np.int64)
            resc = np.asarray(resc).astype(np.int64)
            accc = np.asarray(accc).astype(np.int64)
            predc = np.asarray(predc).astype(np.int64)
            need = np.asarray(need).astype(np.int64)
            drawn = bt if budget is None else int(budget.sum())
            self.stats.iterations += drawn
            self.stats.candidate_draws += drawn
            # residual (§8.2), predicate (§8.3) and membership rejections are
            # accounted separately (dead walks are none of the three)
            self.stats.residual_rejects += int(resc.sum())
            self.stats.pred_rejects += int(predc.sum())
            self.stats.cover_rejects += int(okc.sum() - resc.sum()
                                            - predc.sum() - accc.sum())
            dt = np.minimum(np.minimum(need, count), self._drain_w)
            ft = np.minimum(need - dt, accc)
            for j in range(nj):
                if dt[j]:
                    idx = (head[j] + np.arange(dt[j])) % cap
                    parts.append(bank[j, idx])
                cj = None
                if ft[j]:
                    cj = np.asarray(cols[j])
                    parts.append(cj[:ft[j]])
                # push surplus accepts at the ring tail (invariant under
                # the take: tail = head + count before both operations)
                push = int(min(accc[j] - ft[j], cap - (count[j] - dt[j])))
                if push > 0:
                    if cj is None:
                        cj = np.asarray(cols[j])
                    pidx = (head[j] + count[j] + np.arange(push)) % cap
                    bank[j, pidx] = cj[ft[j]:ft[j] + push]
                head[j] = (head[j] + dt[j]) % cap
                count[j] = count[j] - dt[j] + push
            total += int((dt + ft).sum())
            # identical accumulation rules to the device carry (post-round
            # bank occupancy for the high-water column)
            pstats[:, 0] += pbatch if budget is None else budget.astype(
                np.int64)
            pstats[:, 1] += accc
            pstats[:, 2] += resc
            pstats[:, 3] += dt
            pstats[:, 4] = np.maximum(pstats[:, 4], count)
            if adaptive:
                # numpy EMA step — planner.ema_update with xp=np runs the
                # same int32 adds/shifts/divides as the device carry
                counts4 = np.stack([accc, okc, resc, predc],
                                   axis=1).astype(np.int32)
                self._h_ema = planner.ema_update(
                    self._h_ema, budget.astype(np.int32), counts4,
                    self._ema_shifts, np)
            shortfall = need - dt - ft
            # dead-piece bookkeeping — identical rules to the device loop
            self.stats.dropped_slots += int(shortfall[dead].sum())
            shortfall[dead] = 0
            trig = (shortfall > 0) & (accc == 0) & (count == 0)
            streak[:] = np.where(dead, streak,
                                 np.where(trig, streak + 1, 0))
            newly = ~dead & (streak >= self.dead_rounds)
            self.stats.dropped_slots += int(shortfall[newly].sum())
            shortfall[newly] = 0
            dead |= newly
            owed = shortfall
        self.last_rounds = rounds
        self._fold_piece_stats(pstats, rounds=rounds, samples=n,
                               ema=self._h_ema if adaptive else None)
        mat = np.concatenate(parts)[:n].astype(np.int64)
        shuffle = self.host_rng.permutation(n)
        mat = mat[shuffle]
        rows = {a: np.ascontiguousarray(mat[:, i])
                for i, a in enumerate(self.attrs)}
        home = np.ascontiguousarray(mat[:, -1])
        from ..relation import fingerprint128
        fp = fingerprint128([rows[a] for a in sorted(self.attrs)])
        return SampleSet(list(self.attrs), rows, home, fp, self.stats)


# ---------------------------------------------------------------------------
# Record-mode membership on device (the lazy orig_join record, Alg 1 l.8-12)
# ---------------------------------------------------------------------------


class JaxRecordUnionSampler(JaxUnionSampler):
    """Algorithm 1 with ``membership="record"`` and the ``orig_join`` record
    as a device-resident sorted-fingerprint multiset.

    The record is four aligned device arrays of capacity ``R``: sorted
    64-bit row fingerprints (two uint32 halves, the same
    :func:`fp32_np`/:func:`fp32_jnp` arithmetic as
    :class:`DeviceJoinMembership` — see DESIGN.md for the collision budget;
    empty slots hold the all-ones sentinel pair and sort last), the tuple's
    current **home** piece, and the count of output rows currently credited
    to the entry.  One round is one jitted program (host-driven: the lazy
    record semantics need the emitted stream back each round, so there is
    exactly one device sync per round) that processes the cover pieces in
    ascending order against the live record:

    * draw ``piece_batches[j]`` candidates (tree walk + §8.2 residual + the
      fused §8.3 predicate mask),
    * probe the record (``searchsorted`` + a static duplicate window): a
      candidate is **rejected** when its record home is an earlier piece
      (Alg 1 line 8), **revises** when its home is a later piece (lines
      10-12: the old entry's credited rows are debited and its home moves
      to ``j``), and is accepted otherwise,
    * take the first ``need_j`` accepted candidates in slot order (the
      remaining accepts are discarded — a truncation of an i.i.d. stream,
      so the emitted prefix stays i.i.d. uniform; there is no surplus
      banking because banked rows could be invalidated by later revisions),
    * fold the taken rows into the record: revision flags scatter onto hit
      entries (credit zeroed, home lowered to ``j``), missed fingerprints
      are deduplicated with run-length credit counts and merged by one
      sorted concatenation.  Pieces later in the same round see the updated
      record, so within-round semantics match the sequential host dict
      exactly (processing pieces in ascending order means within-round hits
      on entries created earlier in the round are always earlier-piece
      rejections, never revisions).

    Revision cannot rewrite rows already handed out, so emission is settled
    at the end: every emitted row is kept iff its emit-time home equals its
    **final** record home (revised copies are exactly the rows whose home
    moved after they were emitted), and the per-round valid total — taken
    rows minus revision-debited credits — tells the driver when ``n`` valid
    rows exist.  The first ``n`` valid rows in emission order, shuffled,
    are the sample.

    The engine is host-driven either way, so ``fused_rounds`` only selects
    where the round program's carry lives (it is donated device state in
    both modes); the equivalence test replays ``debug_capture=True`` round
    captures through a sequential host dict instead.  The sharded engine
    does not support record mode (the multiset is device-global).
    """

    _KWIN = 8          # static fp1 duplicate window (cf. DeviceJoinMembership)
    _SENTINEL = 0xFFFFFFFF

    def __init__(self, backend: JaxBackend, cover, seed: int = 0,
                 round_batch: int = 4096,
                 dead_rounds: int = 8, max_rounds: int = 4096,
                 surplus_cap: Optional[int] = None, stats=None,
                 fused_rounds: str = "device", balance: str = "cover",
                 balance_slack: float = 1.5, predicate=None,
                 record_capacity: Optional[int] = None,
                 debug_capture: bool = False, plan: str = "static"):
        # record mode is take-in-slot-order with in-round record revision;
        # budget masking would interleave with the lazy-record semantics, so
        # the adaptive planner is not offered here
        if plan != "static":
            raise ValueError(
                "membership='record' supports plan='static' only")
        super().__init__(backend, cover, seed=seed, round_batch=round_batch,
                         dead_rounds=dead_rounds, max_rounds=max_rounds,
                         surplus_cap=surplus_cap, stats=stats,
                         fused_rounds=fused_rounds, balance=balance,
                         balance_slack=balance_slack, predicate=predicate)
        self._sorted_attrs = tuple(sorted(self.attrs))
        self.record_capacity = record_capacity
        self.debug_capture = bool(debug_capture)
        self.captured: List[Dict] = []
        self._rec_state = None
        self._rec_jit = jax.jit(self._record_round, donate_argnums=(0,))

    def _ensure_device_inputs(self) -> None:
        """No-op: record mode never probes the replicated membership
        indexes, so the backend's lazy build must not be triggered."""

    # -- record state ---------------------------------------------------------
    def _init_record_state(self, n: int):
        if self.record_capacity is not None:
            r = int(self.record_capacity)
        else:
            r = 1 << max(12, (4 * int(n) - 1).bit_length())
        self.R = r
        return {
            "f1": jnp.full((r,), self._SENTINEL, jnp.uint32),
            "f2": jnp.full((r,), self._SENTINEL, jnp.uint32),
            "home": jnp.full((r,), 0x7FFFFFFF, jnp.int32),
            "emit": jnp.zeros((r,), jnp.int32),
            "count": jnp.int32(0),
            "fail": jnp.bool_(False),
        }

    # -- one round (traced) ---------------------------------------------------
    def _record_round(self, state, need: jnp.ndarray, key: jax.Array):
        nj = len(self.trees)
        R = self.R
        keys = jax.random.split(key, nj)
        cols_out, debug = [], []
        ft_l, okc_l, resc_l, predc_l, rejc_l = [], [], [], [], []
        accc_l, revc_l, inval_l = [], [], []
        for j, tree in enumerate(self.trees):
            bj = self.piece_batches[j]
            rows, acc, walk_ok = tree.draw(keys[j], bj)
            okc_l.append(jnp.sum(walk_ok).astype(jnp.int32))
            resc_l.append((jnp.sum(walk_ok) - jnp.sum(acc))
                          .astype(jnp.int32))
            pf = self._pred_fns[j]
            if pf is None:
                predc_l.append(jnp.int32(0))
            else:
                pok = pf(rows)
                predc_l.append(jnp.sum(acc & ~pok).astype(jnp.int32))
                acc = acc & pok
            if self.debug_capture:
                debug.append((dict(rows), acc))
            f1 = fp32_jnp([rows[a] for a in self._sorted_attrs], salt=1)
            f2 = fp32_jnp([rows[a] for a in self._sorted_attrs], salt=2)
            # record lookup against the start-of-piece state
            lo = jnp.searchsorted(state["f1"], f1, side="left")
            hit = jnp.zeros((bj,), bool)
            epos = jnp.zeros((bj,), jnp.int32)
            for k in range(self._KWIN):
                pos = jnp.minimum(lo + k, R - 1).astype(jnp.int32)
                m = ((lo + k < R) & (state["f1"][pos] == f1)
                     & (state["f2"][pos] == f2))
                epos = jnp.where(m & ~hit, pos, epos)
                hit = hit | m
            home = state["home"][epos]
            rejc_l.append(jnp.sum(acc & hit & (home < j))
                          .astype(jnp.int32))
            accepted = acc & (~hit | (home >= j))
            accc_l.append(jnp.sum(accepted).astype(jnp.int32))
            rank = jnp.cumsum(accepted) - 1
            taken = accepted & (rank < need[j])
            ft_l.append(jnp.minimum(jnp.sum(accepted), need[j])
                        .astype(jnp.int32))
            # emit: taken rows compacted to the front (rank scatter)
            dst = jnp.where(taken, jnp.cumsum(taken) - 1, bj)
            mat = jnp.stack([rows[a].astype(jnp.int32)
                             for a in self.attrs], axis=1)
            cols_out.append(jnp.zeros((bj, mat.shape[1]), jnp.int32)
                            .at[dst].set(mat, mode="drop"))
            # revisions: taken hits whose entry currently lives at a LATER
            # piece — debit the entry's credited rows, move it home to j
            th = taken & hit
            rev = th & (home > j)
            rev_flag = (jnp.zeros((R,), bool)
                        .at[jnp.where(rev, epos, R)].set(True, mode="drop"))
            revc_l.append(jnp.sum(rev_flag).astype(jnp.int32))
            inval_l.append(jnp.sum(jnp.where(rev_flag, state["emit"], 0))
                           .astype(jnp.int32))
            emit2 = jnp.where(rev_flag, 0, state["emit"])
            home2 = jnp.where(rev_flag, jnp.int32(j), state["home"])
            emit2 = emit2.at[jnp.where(th, epos, R)].add(1, mode="drop")
            # insert taken misses: lexicographic (f1, f2) sort → dedup →
            # run-length credit counts → one sorted-concat merge
            tm = taken & ~hit
            cf1 = jnp.where(tm, f1, jnp.uint32(self._SENTINEL))
            cf2 = jnp.where(tm, f2, jnp.uint32(self._SENTINEL))
            o = jnp.argsort(cf2)
            o = o[jnp.argsort(cf1[o])]
            sf1, sf2, stm = cf1[o], cf2[o], tm[o]
            first = jnp.arange(bj) == 0
            dup = (~first & (sf1 == jnp.roll(sf1, 1))
                   & (sf2 == jnp.roll(sf2, 1)))
            is_new = stm & ~dup
            g = jnp.cumsum(is_new) - 1
            counts = (jnp.zeros((bj,), jnp.int32)
                      .at[jnp.where(stm, g, bj)].add(1, mode="drop"))
            n_new = jnp.sum(is_new).astype(jnp.int32)
            new_emit = jnp.where(is_new, counts[jnp.clip(g, 0, bj - 1)], 0)
            nf1 = jnp.where(is_new, sf1, jnp.uint32(self._SENTINEL))
            nf2 = jnp.where(is_new, sf2, jnp.uint32(self._SENTINEL))
            nhome = jnp.where(is_new, jnp.int32(j), jnp.int32(0x7FFFFFFF))
            mf1 = jnp.concatenate([state["f1"], nf1])
            morder = jnp.argsort(mf1)[:R]
            state = {
                "f1": mf1[morder],
                "f2": jnp.concatenate([state["f2"], nf2])[morder],
                "home": jnp.concatenate([home2, nhome])[morder],
                "emit": jnp.concatenate([emit2, new_emit.astype(jnp.int32)]
                                        )[morder],
                "count": state["count"] + n_new,
                "fail": state["fail"] | (state["count"] + n_new > R),
            }
        out = (state, cols_out, jnp.stack(ft_l), jnp.stack(okc_l),
               jnp.stack(resc_l), jnp.stack(predc_l), jnp.stack(rejc_l),
               jnp.stack(accc_l), jnp.stack(revc_l), jnp.stack(inval_l))
        if self.debug_capture:
            return out + (debug,)
        return out

    # -- driver ---------------------------------------------------------------
    def sample_async(self, n: int):
        from ..union_sampler import empty_sample_set
        if n <= 0:
            return _ReadyHandle(empty_sample_set(list(self.attrs),
                                                 self.stats))
        return _ReadyHandle(self._sample_record(n))

    def sample(self, n: int):
        from ..union_sampler import empty_sample_set
        if n <= 0:
            return empty_sample_set(list(self.attrs), self.stats)
        return self._sample_record(n)

    def _host_lookup(self, f1s: np.ndarray, q1: np.ndarray):
        """Positions of (q1, q2) probes: returns the searchsorted lows (the
        window scan happens at the call site, numpy-vectorised)."""
        return np.searchsorted(f1s, q1, side="left")

    def _sample_record(self, n: int):
        from ..union_sampler import SampleSet
        nj, bt = len(self.order), int(sum(self.piece_batches))
        if self._rec_state is None:
            self._rec_state = self._init_record_state(n)
        pbatch = np.asarray(self.piece_batches, np.int64)
        pstats = np.zeros((nj, len(PIECE_STAT_FIELDS)), np.int64)
        dead, streak = self._h_dead, self._h_streak
        base = np.asarray(self._probs_base, np.float64)
        parts: List[Tuple[np.ndarray, int]] = []   # (rows matrix, home) in
        carry = np.zeros(nj, dtype=np.int64)       # emission order
        valid = 0
        rounds = 0
        while valid < n:
            rounds += 1
            if rounds > self.max_rounds:
                raise RuntimeError(
                    "JaxRecordUnionSampler: top-up budget exhausted")
            probs = np.where(dead, 0.0, base)
            s = probs.sum()
            if s <= 0:
                raise RuntimeError("all cover pieces unreachable")
            extra = max(0, min(n - valid - int(carry.sum()),
                               self.round_batch))
            fresh = self.host_rng.multinomial(extra, probs / s)
            need = carry + fresh
            self.key, sub = jax.random.split(self.key)
            res = self._rec_jit(self._rec_state,
                                jnp.asarray(need.astype(np.int32)), sub)
            (self._rec_state, cols, ft, okc, resc, predc, rejc, accc,
             revc, inval) = res[:10]
            if self.debug_capture:
                self.captured.append({
                    "need": need.copy(),
                    "pieces": [({a: np.asarray(c) for a, c in rows.items()},
                                np.asarray(acc))
                               for rows, acc in res[10]],
                })
            ft = np.asarray(ft).astype(np.int64)
            okc = np.asarray(okc).astype(np.int64)
            resc = np.asarray(resc).astype(np.int64)
            predc = np.asarray(predc).astype(np.int64)
            rejc = np.asarray(rejc).astype(np.int64)
            accc = np.asarray(accc).astype(np.int64)
            if bool(np.asarray(self._rec_state["fail"])):
                raise RuntimeError(
                    f"JaxRecordUnionSampler: record capacity R={self.R} "
                    "exhausted; pass record_capacity= to size the multiset "
                    "for the expected distinct-tuple volume")
            for j in range(nj):
                if ft[j]:
                    parts.append((np.asarray(cols[j])[:ft[j]], j))
            valid += int(ft.sum()) - int(np.asarray(inval).sum())
            self.stats.iterations += bt
            self.stats.candidate_draws += bt
            self.stats.residual_rejects += int(resc.sum())
            self.stats.pred_rejects += int(predc.sum())
            self.stats.cover_rejects += int(rejc.sum())
            self.stats.revisions += int(np.asarray(revc).sum())
            self.stats.backtrack_removed += int(np.asarray(inval).sum())
            pstats[:, 0] += pbatch
            pstats[:, 1] += accc
            pstats[:, 2] += resc
            # no surplus banking in record mode: columns 3/4 stay zero
            shortfall = need - ft
            self.stats.dropped_slots += int(shortfall[dead].sum())
            shortfall[dead] = 0
            trig = (shortfall > 0) & (accc == 0)
            streak[:] = np.where(dead, streak,
                                 np.where(trig, streak + 1, 0))
            newly = ~dead & (streak >= self.dead_rounds)
            self.stats.dropped_slots += int(shortfall[newly].sum())
            shortfall[newly] = 0
            dead |= newly
            carry = shortfall
        self.last_rounds = rounds
        self._fold_piece_stats(pstats, rounds=rounds, samples=n)
        # settle emission: keep rows whose emit-time home is still the final
        # record home (revised copies are exactly the ones whose home moved)
        f1s = np.asarray(self._rec_state["f1"])
        f2s = np.asarray(self._rec_state["f2"])
        homes = np.asarray(self._rec_state["home"])
        kept: List[np.ndarray] = []
        for mat, j in parts:
            by_attr = {a: mat[:, i].astype(np.int64)
                       for i, a in enumerate(self.attrs)}
            q1 = fp32_np([by_attr[a] for a in self._sorted_attrs], salt=1)
            q2 = fp32_np([by_attr[a] for a in self._sorted_attrs], salt=2)
            lo = self._host_lookup(f1s, q1)
            fh = np.full(q1.shape[0], -1, np.int64)
            found = np.zeros(q1.shape[0], bool)
            for k in range(self._KWIN):
                pos = np.minimum(lo + k, self.R - 1)
                m = ((lo + k < self.R) & (f1s[pos] == q1)
                     & (f2s[pos] == q2) & ~found)
                fh = np.where(m, homes[pos], fh)
                found |= m
            keep = found & (fh == j)
            if keep.any():
                km = mat[keep].astype(np.int64)
                kept.append(np.concatenate(
                    [km, np.full((km.shape[0], 1), j, np.int64)], axis=1))
        mat = (np.concatenate(kept) if kept
               else np.zeros((0, len(self.attrs) + 1), np.int64))
        if mat.shape[0] < n:
            raise RuntimeError(
                "JaxRecordUnionSampler: settled emission came up short "
                f"({mat.shape[0]} < {n}) — record fingerprint collision")
        mat = mat[:n]
        shuffle = self.host_rng.permutation(n)
        mat = mat[shuffle]
        rows = {a: np.ascontiguousarray(mat[:, i])
                for i, a in enumerate(self.attrs)}
        home = np.ascontiguousarray(mat[:, -1])
        from ..relation import fingerprint128
        fp = fingerprint128([rows[a] for a in sorted(self.attrs)])
        return SampleSet(list(self.attrs), rows, home, fp, self.stats)

    def record_dict(self) -> Dict[int, Tuple[int, int]]:
        """The current record as ``{fp64: (home, credited_rows)}`` (test
        hook: the debug-capture replay compares its host dict to this)."""
        if self._rec_state is None:
            return {}
        f1 = np.asarray(self._rec_state["f1"]).astype(np.uint64)
        f2 = np.asarray(self._rec_state["f2"]).astype(np.uint64)
        home = np.asarray(self._rec_state["home"])
        emit = np.asarray(self._rec_state["emit"])
        real = ~((f1 == self._SENTINEL) & (f2 == self._SENTINEL))
        return {int((f1[i] << np.uint64(32)) | f2[i]):
                (int(home[i]), int(emit[i]))
                for i in np.nonzero(real)[0]}
