"""Device (JAX) backend — the union sampling engine resident on accelerator.

Three layers, bottom-up:

* :class:`DeviceTreeJoin` — generalises the jitted chain sampler to arbitrary
  acyclic (tree) joins **and to cyclic joins via the paper's §8.2
  skeleton+residual scheme**.  Each non-root node keeps its child rows sorted
  by a **composite mixed-radix key** over the node's edge attributes (radices
  are per-attribute domain widths shared across the whole join, so
  parent-side query keys pack identically and probes stay exact), plus
  prefix-summed EW weights; one draw is root inverse-CDF + per-node
  ``searchsorted`` → ranged weighted pick → payload gathers, all ``jax.lax``
  over fixed shapes.  For cyclic joins the EW weights cover the acyclic
  skeleton only; each residual (cycle-closing) edge is then verified inside
  the same traced draw with a batched sorted-key membership probe — uniform
  pick among the ``d`` matches + an accumulated ``Π d/M`` acceptance test —
  mirroring the host :class:`~repro.core.join_sampler.JoinSampler`
  semantics exactly.  On TPU the per-node range probe routes through the
  two-phase Pallas pipeline of :mod:`repro.kernels.searchsorted`
  (``use_pallas``); on CPU it lowers via ``jnp.searchsorted``.
* :class:`DeviceJoinMembership` — batched "is tuple in join J" probes as
  sorted-row-fingerprint lookups resident on device: per base relation, rows
  are indexed by a 32-bit primary fingerprint (sorted) with a 32-bit
  secondary for verification (64 bits total; the host oracle uses 128 — see
  DESIGN.md for the collision budget).  A probe is one ``searchsorted`` per
  relation plus a ``kmax``-wide duplicate window check, AND-reduced.
* :class:`JaxUnionSampler` — fuses one whole Algorithm-1 round into a single
  jitted program: multinomial cover selection (per-slot categorical),
  candidate generation for *all* joins, cover-membership acceptance masks
  with **retry-within-the-selected-join** (the distribution-correct loop —
  see union_sampler's module docstring on the printed-pseudocode pitfall),
  and compaction of accepted slots.  The host only tops up between rounds.

:class:`JaxBackend` packages the per-join pieces behind the
:class:`~repro.core.backends.base.Backend` protocols so
``SetUnionSampler(backend="jax")`` / ``OnlineUnionSampler(backend="jax")``
select the device engine without touching the algorithm layer.

Limits (all checked at build time with clear errors): ``method="ew"``
weights, non-negative dict-encoded values whose packed edge domains fit in
int32 (the device substrate is 32-bit; see DESIGN.md).  Chain, acyclic, and
cyclic (§8.2 skeleton+residual) join shapes all run on device; a union whose
*individual* joins trip a device limit degrades those joins to host
candidate draws with a single warning instead of rejecting the whole union.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..index import Catalog
from ..join_sampler import EmptyJoinError, JoinSampler
from ..joins import JoinSpec
from ..membership import rows_length
from .base import Backend, Rows

_I32_LIM = 1 << 31


# ---------------------------------------------------------------------------
# 32-bit row fingerprints — identical arithmetic on host (build) and device
# (probe): murmur3-style finalizer, FNV-style column combine, uint32 wraps.
# ---------------------------------------------------------------------------


def _mix32_consts(salt: int) -> Tuple[int, int, int]:
    return ((0x9E3779B9 * (salt + 1)) & 0xFFFFFFFF, 0x85EBCA6B, 0xC2B2AE35)


def mix32_np(x: np.ndarray, salt: int = 0) -> np.ndarray:
    add, m1, m2 = _mix32_consts(salt)
    z = (np.asarray(x, np.int64) & 0xFFFFFFFF).astype(np.uint32)
    with np.errstate(over="ignore"):
        z = z + np.uint32(add)
        z = (z ^ (z >> np.uint32(16))) * np.uint32(m1)
        z = (z ^ (z >> np.uint32(13))) * np.uint32(m2)
        z = z ^ (z >> np.uint32(16))
    return z


def mix32_jnp(x: jnp.ndarray, salt: int = 0) -> jnp.ndarray:
    add, m1, m2 = _mix32_consts(salt)
    z = x.astype(jnp.uint32)
    z = z + jnp.uint32(add)
    z = (z ^ (z >> jnp.uint32(16))) * jnp.uint32(m1)
    z = (z ^ (z >> jnp.uint32(13))) * jnp.uint32(m2)
    z = z ^ (z >> jnp.uint32(16))
    return z


_FNV32 = 16777619


def fp32_np(cols: Sequence[np.ndarray], salt: int) -> np.ndarray:
    acc = np.zeros(np.asarray(cols[0]).shape[0], dtype=np.uint32)
    with np.errstate(over="ignore"):
        for i, c in enumerate(cols):
            acc = acc * np.uint32(_FNV32) ^ mix32_np(c, salt=salt * 1000 + i)
    return acc


def fp32_jnp(cols: Sequence[jnp.ndarray], salt: int) -> jnp.ndarray:
    acc = jnp.zeros(cols[0].shape[0], dtype=jnp.uint32)
    for i, c in enumerate(cols):
        acc = acc * jnp.uint32(_FNV32) ^ mix32_jnp(c, salt=salt * 1000 + i)
    return acc


# ---------------------------------------------------------------------------
# Composite-key encoding
# ---------------------------------------------------------------------------


def _attr_widths(spec: JoinSpec) -> Dict[str, int]:
    """Per-attribute mixed-radix width over *all* relations of the join.

    Using the join-wide width (not the per-relation one) makes the packing a
    single injective code over the joint domain, so a parent-side query key
    and a child-side index key for the same tuple of values always coincide.
    """
    widths: Dict[str, int] = {}
    for node in spec.nodes:
        for a, c in node.relation.columns.items():
            lo = int(c.min(initial=0))
            if lo < 0:
                raise ValueError(
                    f"jax backend: attribute {a!r} of {node.relation.name!r} "
                    "has negative values; device engine requires non-negative "
                    "dict-encoded columns")
            hi = int(c.max(initial=0))
            widths[a] = max(widths.get(a, 1), hi + 1)
    return widths


def _pack_np(cols: Sequence[np.ndarray], radices: Sequence[int]) -> np.ndarray:
    key = np.zeros(np.asarray(cols[0]).shape[0], dtype=np.int64)
    for c, w in zip(cols, radices):
        key = key * np.int64(w) + np.asarray(c, np.int64)
    return key


def _pack_jnp(rows: Dict[str, jnp.ndarray], attrs: Sequence[str],
              radices: Sequence[int]) -> jnp.ndarray:
    key = jnp.zeros(rows[attrs[0]].shape[0], dtype=jnp.int32)
    for a, w in zip(attrs, radices):
        key = key * jnp.int32(w) + rows[a]
    return key


def _as_i32(col: np.ndarray, what: str) -> np.ndarray:
    col = np.asarray(col, np.int64)
    if col.size and (int(col.min()) < 0 or int(col.max()) >= _I32_LIM):
        raise ValueError(f"jax backend: {what} outside int32 domain "
                         "(re-encode the dictionary or use backend='numpy')")
    return col.astype(np.int32)


def _inverse_cdf_pick(prefix: jnp.ndarray, lo, hi, u):
    """Weighted pick within [lo, hi) via prefix sums (vectorised)."""
    tot = prefix[hi] - prefix[lo]
    tgt = prefix[lo] + u * jnp.maximum(tot, 1e-30)
    pos = jnp.searchsorted(prefix, tgt, side="right") - 1
    pos = jnp.clip(pos, lo, jnp.maximum(hi - 1, lo))
    return pos, tot > 0


# ---------------------------------------------------------------------------
# Device-resident tree join (generalised EW candidate source)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _NodeCfg:
    alias: str
    edge_attrs: Tuple[str, ...]
    radices: Tuple[int, ...]
    new_attrs: Tuple[str, ...]
    kind: str = "tree"               # "tree" | "residual" (§8.2 cycle closer)
    max_degree: int = 0              # residual only: M of the d/M acceptance


class DeviceTreeJoin:
    """Join prepared for jitted EW sampling (chain ⊂ tree ⊂ skeleton+residual).

    Acyclic (tree) joins draw with zero rejection.  Cyclic joins follow the
    paper's §8.2 scheme, all inside the same traced draw: the EW weights are
    computed over the acyclic *skeleton* only, each residual (cycle-closing)
    node keeps the identical sorted composite-key index as a tree node, and a
    draw resolves every residual edge with the same batched sorted-key range
    probe — a uniform pick among the ``d`` matches plus an accumulated
    ``Π d/M`` acceptance test (``M`` = the residual index's max degree, as in
    the host :class:`~repro.core.join_sampler.JoinSampler`).  Residual
    rejections surface through the third element of ``draw``'s return.
    """

    def __init__(self, cat: Catalog, spec: JoinSpec,
                 use_pallas: Optional[bool] = None):
        if use_pallas is None:
            from ...kernels.ops import on_tpu
            use_pallas = on_tpu()
        self.use_pallas = bool(use_pallas)
        self.name = spec.name
        self.spec = spec
        self.attrs = tuple(spec.output_attrs)

        js = JoinSampler(cat, spec, method="ew")  # reuse host weight computation
        widths = _attr_widths(spec)
        self.node_cfgs: List[_NodeCfg] = []
        self.sorted_keys: List[jnp.ndarray] = []
        self.perm: List[jnp.ndarray] = []
        self.wprefix: List[jnp.ndarray] = []
        self.cols: List[Dict[str, jnp.ndarray]] = []
        self._prepped: List[object] = []

        produced = set(js.root_rel.attrs)
        for n in js.order[1:]:
            rel = js._reduced[n.alias]
            radices = tuple(widths[a] for a in n.edge_attrs)
            dom = 1
            for w in radices:
                dom *= w
            if dom >= _I32_LIM:
                raise ValueError(
                    f"jax backend: packed edge-key domain of node {n.alias!r} "
                    f"({dom}) exceeds int32 (the device substrate is 32-bit; "
                    "use backend='numpy')")
            key = _pack_np([rel.columns[a] for a in n.edge_attrs], radices)
            perm = np.argsort(key, kind="stable")
            skeys = key[perm].astype(np.int32)
            if n.kind == "residual":
                # §8.2: residual picks are uniform among matches via
                # floor(u*d) in _residual_step — no weight prefix needed;
                # the EW weights cover the skeleton only (host parity)
                wp = np.zeros(1, dtype=np.float64)
            else:
                w = js.node_weights[n.alias]
                wp = np.zeros(rel.nrows + 1, dtype=np.float64)
                np.cumsum(w[perm], out=wp[1:])
            new_attrs = tuple(a for a in rel.attrs if a not in produced)
            produced.update(rel.attrs)
            self.node_cfgs.append(_NodeCfg(
                n.alias, tuple(n.edge_attrs), radices, new_attrs,
                kind=n.kind, max_degree=int(js.edges[n.alias].max_degree)))
            self.sorted_keys.append(jnp.asarray(skeys))
            self.perm.append(jnp.asarray(perm.astype(np.int32)))
            self.wprefix.append(jnp.asarray(wp, jnp.float32))
            self.cols.append({a: jnp.asarray(_as_i32(c, f"{rel.name}.{a}"))
                              for a, c in rel.columns.items() if a in new_attrs})
            if self.use_pallas:
                from ...kernels.searchsorted import PreparedKeys
                self._prepped.append(PreparedKeys(key[perm]))
            else:
                self._prepped.append(None)

        self.has_residual = any(c.kind == "residual" for c in self.node_cfgs)
        self.host_root_cols = {a: _as_i32(c, f"root.{a}")
                               for a, c in js.root_rel.columns.items()}
        self.root_cols = {a: jnp.asarray(c)
                          for a, c in self.host_root_cols.items()}
        # float64 host prefix retained: the sharding layer cuts weight-quantile
        # root ranges from it (repro.core.sharding.catalog.ShardedTreeJoin)
        self.host_root_wprefix = np.asarray(js.root_weight_prefix, np.float64)
        self.root_wprefix = jnp.asarray(js.root_weight_prefix, jnp.float32)
        self.total_weight = float(js.root_weight_total)
        self.n_root = js.root_rel.nrows
        self._empty = js.is_empty()

    def is_empty(self) -> bool:
        return self._empty

    # -- range probe: jnp.searchsorted, or the two-phase Pallas pipeline ------
    def _ranges(self, i: int, q: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        if not self.use_pallas:
            sk = self.sorted_keys[i]
            return (jnp.searchsorted(sk, q, side="left").astype(jnp.int32),
                    jnp.searchsorted(sk, q, side="right").astype(jnp.int32))
        from ...kernels.ops import default_interpret
        from ...kernels.searchsorted import QUERY_TILE, _searchsorted_i32
        prep = self._prepped[i]
        b = q.shape[0]
        pad = (-b) % QUERY_TILE
        qp = jnp.pad(q, (0, pad))
        qt = qp.shape[0] // QUERY_TILE
        # keys are non-negative int32, so the 64-bit split is (hi=0, lo=q^MIN)
        q_lo = (qp ^ jnp.int32(-(1 << 31))).reshape(qt, QUERY_TILE)
        q_hi = jnp.zeros_like(q_lo)
        lo, hi = _searchsorted_i32(q_hi, q_lo, prep.f_hi2, prep.f_lo2,
                                   prep.keys2d_hi, prep.keys2d_lo,
                                   n_chunks=prep.n_chunks,
                                   n_fences=prep.n_blocks,
                                   interpret=default_interpret())
        n = jnp.int32(prep.n)
        return (jnp.minimum(lo.reshape(-1)[:b], n),
                jnp.minimum(hi.reshape(-1)[:b], n))

    # -- one batch of EW tree draws (traced; jit at the call site) ------------
    def draw(self, key: jax.Array, batch: int
             ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray, jnp.ndarray]:
        return self.draw_with_root(key, batch, self.root_wprefix,
                                   self.root_cols, self.n_root)

    def _residual_step(self, i: int, cfg: _NodeCfg, rows, ok, acc_ratio, u):
        """One residual edge: sorted-key probe, uniform pick, d/M factor."""
        q = _pack_jnp(rows, cfg.edge_attrs, cfg.radices)
        lo, hi = self._ranges(i, q)
        d = hi - lo
        off = jnp.floor(u * jnp.maximum(d, 1).astype(jnp.float32)
                        ).astype(jnp.int32)
        pos = lo + jnp.minimum(off, jnp.maximum(d - 1, 0))
        ok = ok & (d > 0)
        acc_ratio = acc_ratio * (d.astype(jnp.float32)
                                 / jnp.float32(max(cfg.max_degree, 1)))
        child = self.perm[i][jnp.clip(pos, 0, self.perm[i].shape[0] - 1)]
        for a, c in self.cols[i].items():
            rows[a] = c[child]
        return rows, ok, acc_ratio

    def draw_with_root(self, key: jax.Array, batch: int,
                       root_wprefix: jnp.ndarray,
                       root_cols: Dict[str, jnp.ndarray], n_root
                       ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray,
                                  jnp.ndarray]:
        """Tree draw with a caller-supplied root slice.

        The sharding layer passes each shard's local root range (weight
        prefix, payload columns, row count); the non-root node indexes are
        this tree's replicated device arrays.  ``draw`` is the degenerate
        whole-root call, so both paths share one op sequence (and a 1-shard
        mesh reproduces unsharded draws bit for bit).

        Returns ``(rows, accept, walk_ok)``: ``walk_ok`` marks walks whose
        every edge (tree and residual) had a match; ``accept`` additionally
        applies the §8.2 residual ``Π d/M`` acceptance test, so
        ``walk_ok & ~accept`` are exactly the residual rejections.  On
        acyclic joins the two are the same array.
        """
        keys = jax.random.split(key, len(self.node_cfgs) + 1
                                + (1 if self.has_residual else 0))
        u0 = jax.random.uniform(keys[0], (batch,))
        r_pos, ok = _inverse_cdf_pick(
            root_wprefix, jnp.zeros((batch,), jnp.int32),
            jnp.full((batch,), n_root, jnp.int32), u0)
        rows = {a: c[r_pos] for a, c in root_cols.items()}
        acc_ratio = jnp.ones((batch,), jnp.float32)
        for i, cfg in enumerate(self.node_cfgs):
            u = jax.random.uniform(keys[i + 1], (batch,))
            if cfg.kind == "residual":
                rows, ok, acc_ratio = self._residual_step(
                    i, cfg, rows, ok, acc_ratio, u)
                continue
            q = _pack_jnp(rows, cfg.edge_attrs, cfg.radices)
            lo, hi = self._ranges(i, q)
            pos, alive = _inverse_cdf_pick(self.wprefix[i], lo, hi, u)
            ok = ok & alive & (hi > lo)
            child = self.perm[i][jnp.clip(pos, 0, self.perm[i].shape[0] - 1)]
            for a, c in self.cols[i].items():
                rows[a] = c[child]
        if not self.has_residual:
            return rows, ok, ok
        u_acc = jax.random.uniform(keys[-1], (batch,))
        return rows, ok & (u_acc < acc_ratio), ok


# ---------------------------------------------------------------------------
# Device-resident membership (sorted-row-fingerprint lookups)
# ---------------------------------------------------------------------------


class DeviceJoinMembership:
    """Batched 'is tuple in join J' probes on device.

    Mirrors the host :class:`~repro.core.membership.MembershipProber`
    semantics: a tuple is in the join iff every base relation contains the
    tuple's projection onto that relation's attributes (the shared output
    schema makes connectivity automatic).
    """

    def __init__(self, spec: JoinSpec):
        self.join_name = spec.name
        # (attrs, sorted_fp1, fp2_in_fp1_order, kmax, nrows) per base relation
        self.rels: List[Tuple[Tuple[str, ...], jnp.ndarray, jnp.ndarray,
                              int, int]] = []
        seen = set()
        for node in spec.nodes:
            rel = node.relation
            attrs = tuple(sorted(rel.attrs))
            # dedup on the host Catalog.rowset cache key, so repeated nodes
            # over one relation build one index but distinct relations that
            # merely share a name are still probed (host parity)
            if (rel.name, attrs) in seen:
                continue
            seen.add((rel.name, attrs))
            for a in attrs:
                _as_i32(rel.columns[a], f"{rel.name}.{a}")  # domain check
            fp1 = fp32_np([rel.columns[a] for a in attrs], salt=1)
            fp2 = fp32_np([rel.columns[a] for a in attrs], salt=2)
            order = np.argsort(fp1, kind="stable")
            s1 = fp1[order]
            if s1.shape[0]:
                _, counts = np.unique(s1, return_counts=True)
                kmax = int(counts.max())
            else:
                kmax = 0
            self.rels.append((attrs, jnp.asarray(s1), jnp.asarray(fp2[order]),
                              kmax, int(rel.nrows)))

    def contains(self, rows: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Traced probe: rows are device int32 columns of the output schema."""
        b = rows[next(iter(rows))].shape[0]
        res = jnp.ones((b,), bool)
        for attrs, s1, s2, kmax, n in self.rels:
            if n == 0:
                return jnp.zeros((b,), bool)
            q1 = fp32_jnp([rows[a] for a in attrs], salt=1)
            q2 = fp32_jnp([rows[a] for a in attrs], salt=2)
            lo = jnp.searchsorted(s1, q1, side="left")
            m = jnp.zeros((b,), bool)
            for k in range(kmax):  # duplicate window (kmax is tiny, static)
                pos = jnp.minimum(lo + k, n - 1)
                m = m | ((lo + k < n) & (s1[pos] == q1) & (s2[pos] == q2))
            res = res & m
        return res


# ---------------------------------------------------------------------------
# Backend protocol implementations
# ---------------------------------------------------------------------------


class JaxCandidateSource:
    """CandidateSource over a :class:`DeviceTreeJoin`.

    Carries its own PRNG key; the host ``rng`` argument of ``draw`` is
    ignored (documented deviation — the numpy and jax engines are
    distributionally, not bitwise, equivalent).
    """

    def __init__(self, tree: DeviceTreeJoin, seed: int = 0,
                 device_batch: int = 4096):
        self.join_name = tree.name
        self.tree = tree
        self.attrs = tree.attrs
        self.key = jax.random.PRNGKey(seed)
        self._batch = int(device_batch)
        self._draw_jit = jax.jit(functools.partial(tree.draw,
                                                   batch=self._batch))
        # buffer of accepted-but-unserved rows: device rounds are fixed-width,
        # so small draws (OnlineUnionSampler asks for 1 at a time) are served
        # from the remainder of the last round instead of a fresh round each.
        self._buf: Optional[Rows] = None
        self._buf_pos = 0
        self._res_rej = 0

    def is_empty(self) -> bool:
        return self.tree.is_empty()

    def pop_residual_rejects(self) -> int:
        """Residual (§8.2 cyclic) rejections since the last pop."""
        n, self._res_rej = self._res_rej, 0
        return n

    def _refill(self) -> int:
        """One device round into the buffer; returns rows banked."""
        self.key, sub = jax.random.split(self.key)
        rows, ok, walk_ok = self._draw_jit(sub)
        ok = np.asarray(ok)
        if self.tree.has_residual:
            self._res_rej += int(np.asarray(walk_ok).sum() - ok.sum())
        idx = np.nonzero(ok)[0]
        self._buf = {a: np.asarray(rows[a])[idx].astype(np.int64)
                     for a in self.attrs}
        self._buf_pos = 0
        return int(idx.shape[0])

    def draw(self, rng: np.random.Generator, count: int,
             batch: Optional[int] = None) -> Tuple[Rows, int]:
        if self.is_empty():
            raise EmptyJoinError(f"join {self.join_name!r} is empty")
        got: List[Rows] = []
        draws = 0
        have = 0
        # round budget scales with the request (device rounds are fixed-width;
        # the numpy source instead grows its batch with `count`)
        max_rounds = 1000 + 20 * (count // self._batch + 1)
        for _ in range(max_rounds):
            if self._buf is None or self._buf_pos >= rows_length(self._buf):
                draws += self._batch
                if self._refill() == 0:
                    continue
            lo = self._buf_pos
            hi = min(lo + count - have, rows_length(self._buf))
            got.append({a: c[lo:hi] for a, c in self._buf.items()})
            self._buf_pos = hi
            have += hi - lo
            if have >= count:
                break
        else:
            raise RuntimeError(f"JaxCandidateSource({self.join_name}): "
                               "round budget exhausted")
        return ({a: np.concatenate([g[a] for g in got])
                 for a in self.attrs}, draws)


class JaxMembershipOracle:
    """MembershipOracle facade over per-join device membership indexes.

    Host-facing: accepts numpy rows, pads to power-of-two buckets (bounding
    the number of jit retraces), probes on device, returns numpy booleans.
    """

    def __init__(self, members: Dict[str, DeviceJoinMembership],
                 output_attrs: Sequence[str]):
        self.members = members
        self.output_attrs = list(output_attrs)
        self._fns = {name: jax.jit(m.contains) for name, m in members.items()}

    @staticmethod
    def _bucket(n: int) -> int:
        b = 256
        while b < n:
            b <<= 1
        return b

    def contains(self, join_name: str, rows: Rows) -> np.ndarray:
        n = rows_length(rows)
        if n == 0:
            return np.zeros(0, dtype=bool)
        p = self._bucket(n)
        dev = {a: jnp.asarray(np.pad(_as_i32(np.asarray(rows[a])[:n],
                                             f"probe.{a}"), (0, p - n)))
               for a in self.output_attrs}
        out = self._fns[join_name](dev)
        return np.asarray(out)[:n]

    def membership_matrix(self, rows: Rows,
                          join_names: Optional[Sequence[str]] = None
                          ) -> np.ndarray:
        names = list(join_names) if join_names is not None else list(self.members)
        return np.stack([self.contains(nm, rows) for nm in names], axis=1)


class JaxBackend(Backend):
    """Device-resident engine: tree candidate sources + membership indexes."""

    name = "jax"

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec],
                 join_method: str = "ew", seed: int = 0,
                 device_batch: int = 4096,
                 use_pallas: Optional[bool] = None):
        if join_method != "ew":
            raise ValueError("jax backend: only method='ew' runs on device "
                             "(eo/wj walks stay on the numpy backend)")
        self.cat = cat
        self.joins = list(joins)
        schemas = {tuple(sorted(j.output_attrs)) for j in self.joins}
        if len(schemas) > 1:
            raise ValueError(
                f"joins must share an output schema; got {sorted(schemas)}")
        self.attrs = list(self.joins[0].output_attrs)
        # per-join degrade: a join that trips a device limit (packed edge-key
        # domain over int32, negative dict values) falls back to the host
        # candidate source instead of failing the whole union; fused rounds
        # need every piece on device, so they disable when any join degrades
        self.trees: Dict[str, DeviceTreeJoin] = {}
        self.degraded: Dict[str, str] = {}          # join name -> reason
        for j in self.joins:
            try:
                self.trees[j.name] = DeviceTreeJoin(cat, j,
                                                    use_pallas=use_pallas)
            except ValueError as e:
                self.degraded[j.name] = str(e)
        if self.degraded:
            import warnings
            warnings.warn(
                "jax backend: joins "
                f"{sorted(self.degraded)} fall back to host candidate draws "
                f"({'; '.join(sorted(set(self.degraded.values())))}); fused "
                "device rounds are disabled for this union", stacklevel=2)
        self._sources: Dict[str, object] = {}
        for i, j in enumerate(self.joins):
            if j.name in self.trees:
                self._sources[j.name] = JaxCandidateSource(
                    self.trees[j.name], seed=seed + i,
                    device_batch=device_batch)
            else:
                from .numpy_backend import NumpyCandidateSource
                self._sources[j.name] = NumpyCandidateSource(
                    cat, j, method=join_method)
        # replicated membership indexes are built lazily: the mesh-sharded
        # engine (repro.core.sharding) keeps its own hash-partitioned
        # indexes and must not pay for (or hold) the full replicated ones
        self._members: Optional[Dict[str, DeviceJoinMembership]] = None
        self._oracle = None

    @property
    def members(self) -> Dict[str, DeviceJoinMembership]:
        if self._members is None:
            self._members = {j.name: DeviceJoinMembership(j)
                             for j in self.joins}
        return self._members

    def source(self, join_name: str):
        return self._sources[join_name]

    def oracle(self):
        if self._oracle is None:
            try:
                self._oracle = JaxMembershipOracle(self.members, self.attrs)
            except ValueError as e:
                # same degrade rule as the draw side: out-of-domain values
                # keep membership on the (128-bit, exact) host prober
                import warnings
                warnings.warn(
                    f"jax backend: device membership unavailable ({e}); "
                    "probing through the host oracle", stacklevel=2)
                from ..membership import MembershipProber
                self._oracle = MembershipProber(self.cat, self.joins)
        return self._oracle

    def supports_fused_rounds(self) -> bool:
        return not self.degraded


# ---------------------------------------------------------------------------
# Fused Algorithm-1 round
# ---------------------------------------------------------------------------


class JaxUnionSampler:
    """One whole Algorithm-1 top-up round as a single jitted program.

    Per round (``round_batch`` candidates per join, fixed shapes):

    1. **multinomial cover selection** — per-slot categorical on the piece
       probabilities, histogrammed into per-piece targets (an i.i.d.
       factorisation of the host path's multinomial) and added to the
       shortfall carried from earlier rounds,
    2. **candidate generation for all joins** — one batched EW tree draw per
       join; cyclic pieces verify their residual edges inside the same
       program (sorted-key probes + ``Π d/M`` acceptance, §8.2), so a
       residual rejection simply leaves the slot unaccepted and its target
       flows into the per-piece shortfall carry like any other rejection —
       round shapes stay static and no piece is ever re-selected,
    3. **cover-membership acceptance** — a candidate of piece ``j`` survives
       iff no earlier cover piece contains it (batched device probes),
    4. **compaction** — accepted candidates sorted to the front per join;
       the round emits ``min(target_j, accepted_j)`` of them and returns the
       per-piece shortfall.

    Crucially the shortfall of piece ``j`` stays *assigned to piece j* across
    rounds (it is carried, never re-drawn from the selection distribution):
    re-selecting a piece after a rejection is the printed-pseudocode pitfall
    documented in union_sampler.  Since each round's accepted candidates are
    i.i.d. uniform over their piece, the host also banks the surplus
    (accepted beyond ``target_j``) and serves later targets from it before
    asking the device again — this is what makes the engine a streaming
    source for serving.

    The host loop only tracks the shortfall vector, drains surplus, zeroes
    pieces that repeatedly yield nothing (estimation noise gave a positive
    size to an empty piece) and stops at ``n`` accepted samples.
    """

    def __init__(self, backend: JaxBackend, cover, seed: int = 0,
                 round_batch: int = 4096,
                 dead_rounds: int = 8, max_rounds: int = 4096,
                 surplus_cap: Optional[int] = None, stats=None):
        self.backend = backend
        self.cover = cover
        self.order = list(cover.order)
        self.trees = [backend.trees[n] for n in self.order]
        self.attrs = tuple(backend.attrs)
        self.key = jax.random.PRNGKey(seed)
        self.host_rng = np.random.default_rng(seed)
        self.round_batch = int(round_batch)
        self.dead_rounds = int(dead_rounds)
        self.max_rounds = int(max_rounds)
        self.surplus_cap = (8 * self.round_batch if surplus_cap is None
                            else int(surplus_cap))
        if stats is None:
            from ..union_sampler import SamplerStats
            stats = SamplerStats()
        self.stats = stats
        self._round_jit = jax.jit(self._round_impl)
        # per-piece surplus bank: accepted-but-not-yet-emitted piece samples
        self._bank: List[List[Rows]] = [[] for _ in self.order]
        self._bank_n = np.zeros(len(self.order), dtype=np.int64)
        # dead-piece state persists across sample() calls (the cover is
        # fixed per engine; rediscovering empty pieces per call would cost
        # dead_rounds device rounds on every request)
        self._dead: set = set()
        self._streak = np.zeros(len(self.order), dtype=np.int64)

    # -- the fused program ----------------------------------------------------
    def _round_impl(self, probs_cum: jnp.ndarray, carry_need: jnp.ndarray,
                    extra_target: jnp.ndarray, key: jax.Array):
        batch, nj = self.round_batch, len(self.trees)
        # resolved at trace time (first round): keeps the lazy backend
        # membership unbuilt for subclasses that override the round program
        members = [self.backend.members[n] for n in self.order]
        kpick, *jks = jax.random.split(key, nj + 1)
        # (1) multinomial cover selection: categorical picks → histogram
        u = jax.random.uniform(kpick, (batch,))
        pick = jnp.clip(jnp.searchsorted(probs_cum, u, side="right"
                                         ).astype(jnp.int32), 0, nj - 1)
        valid = (jnp.arange(batch) < extra_target).astype(jnp.int32)
        need = carry_need + jnp.zeros((nj,), jnp.int32).at[pick].add(valid)
        # (2)+(3) per join: batched candidate draw (incl. §8.2 residual-edge
        # verification for cyclic pieces) + earlier-piece rejection
        out_cols = []
        ok_counts = []
        res_counts = []
        acc_counts = []
        for j, tree in enumerate(self.trees):
            rows, acc, walk_ok = tree.draw(jks[j], batch)
            res_counts.append(jnp.sum(walk_ok) - jnp.sum(acc))
            for q in range(j):             # pieces earlier in cover order
                acc = acc & ~members[q].contains(rows)
            # (4) compaction: accepted candidates first, original slot order
            perm = jnp.argsort(~acc)
            out_cols.append(tuple(rows[a][perm] for a in self.attrs))
            ok_counts.append(jnp.sum(walk_ok))
            acc_counts.append(jnp.sum(acc))
        ok_counts = jnp.stack(ok_counts).astype(jnp.int32)
        res_counts = jnp.stack(res_counts).astype(jnp.int32)
        acc_counts = jnp.stack(acc_counts).astype(jnp.int32)
        take = jnp.minimum(need, acc_counts)
        shortfall = need - take
        return out_cols, ok_counts, res_counts, acc_counts, take, shortfall

    # -- host top-up loop -----------------------------------------------------
    def _drain_bank(self, j: int, want: int, parts, homes) -> int:
        """Emit up to ``want`` banked piece-``j`` samples; returns count."""
        got = 0
        while got < want and self._bank[j]:
            rows = self._bank[j][0]
            k = rows_length(rows)
            use = min(k, want - got)
            parts.append({a: rows[a][:use] for a in self.attrs})
            homes.append(np.full(use, j, dtype=np.int64))
            if use == k:
                self._bank[j].pop(0)
            else:
                self._bank[j][0] = {a: rows[a][use:] for a in self.attrs}
            self._bank_n[j] -= use
            got += use
        return got

    def sample(self, n: int):
        from ..union_sampler import SampleSet, empty_sample_set
        if n <= 0:
            return empty_sample_set(list(self.attrs), self.stats)
        nj = len(self.order)
        base = np.maximum(np.asarray(self.cover.selection_probs(), np.float64), 0)
        streak, dead = self._streak, self._dead
        parts: List[Rows] = []
        homes: List[np.ndarray] = []
        owed = np.zeros(nj, dtype=np.int64)   # per-piece carried shortfall
        total = 0
        rounds = 0
        while total < n:
            rounds += 1
            if rounds > self.max_rounds:
                raise RuntimeError("JaxUnionSampler: top-up budget exhausted")
            p = base.copy()
            for j in dead:
                p[j] = 0.0
            s = p.sum()
            if s <= 0:
                raise RuntimeError("all cover pieces unreachable")
            p /= s
            # assign banked surplus to fresh targets (host multinomial — the
            # same selection law; piece counts stay multinomial under p)
            bank_total = int(self._bank_n.sum())
            unassigned = n - total - int(owed.sum())
            if bank_total > 0 and unassigned > 0:
                owed += self.host_rng.multinomial(min(unassigned, bank_total), p)
            # serve carried per-piece targets from the surplus bank first
            for j in range(nj):
                if owed[j] and self._bank_n[j]:
                    got = self._drain_bank(j, int(owed[j]), parts, homes)
                    owed[j] -= got
                    total += got
            if total >= n:
                break
            unassigned = n - total - int(owed.sum())
            extra = max(0, min(unassigned, self.round_batch))
            self.key, sub = jax.random.split(self.key)
            (out_cols, ok_counts, res_counts, acc_counts, take,
             shortfall) = self._round_jit(
                jnp.asarray(np.cumsum(p), jnp.float32),
                jnp.asarray(np.minimum(owed, np.iinfo(np.int32).max),
                            jnp.int32),
                jnp.int32(extra), sub)
            ok_counts = np.asarray(ok_counts)
            res_counts = np.asarray(res_counts)
            acc_counts = np.asarray(acc_counts)
            take = np.asarray(take)
            shortfall = np.asarray(shortfall)
            self.stats.iterations += self.round_batch * nj
            self.stats.candidate_draws += self.round_batch * nj
            # residual (§8.2) and membership rejections are accounted
            # separately (dead walks are neither)
            self.stats.residual_rejects += int(res_counts.sum())
            self.stats.cover_rejects += int(ok_counts.sum() - res_counts.sum()
                                            - acc_counts.sum())
            for j in range(nj):
                t = int(take[j])
                a_j = int(acc_counts[j])
                if t:
                    cols = out_cols[j]
                    parts.append({a: np.asarray(c)[:t].astype(np.int64)
                                  for a, c in zip(self.attrs, cols)})
                    homes.append(np.full(t, j, dtype=np.int64))
                    total += t
                # bank the surplus accepted candidates for later targets
                if a_j > t and self._bank_n[j] < self.surplus_cap:
                    cols = out_cols[j]
                    self._bank[j].append(
                        {a: np.asarray(c)[t:a_j].astype(np.int64)
                         for a, c in zip(self.attrs, cols)})
                    self._bank_n[j] += a_j - t
            owed = shortfall.astype(np.int64)
            # dead-piece detection: a piece that keeps a target but never
            # accepts is empty in reality (estimation noise) — drop it.
            for j in range(nj):
                if j in dead:
                    # float32-cumsum clipping can still assign stray picks to
                    # a dead piece; return them to the unassigned pool
                    if owed[j]:
                        self.stats.dropped_slots += int(owed[j])
                        owed[j] = 0
                    continue
                if owed[j] > 0 and acc_counts[j] == 0 and self._bank_n[j] == 0:
                    streak[j] += 1
                    if streak[j] >= self.dead_rounds:
                        dead.add(j)
                        self.stats.dropped_slots += int(owed[j])
                        owed[j] = 0
                else:
                    streak[j] = 0
        rows = {a: np.concatenate([g[a] for g in parts])[:n] for a in self.attrs}
        home = np.concatenate(homes)[:n]
        shuffle = self.host_rng.permutation(n)
        rows = {a: c[shuffle] for a, c in rows.items()}
        from ..relation import fingerprint128
        fp = fingerprint128([rows[a] for a in sorted(self.attrs)])
        return SampleSet(list(self.attrs), rows, home[shuffle], fp, self.stats)
