"""Host (numpy) backend — the reference engine, extracted from the per-join
machinery the union samplers used to instantiate directly.

* Candidate draws delegate to :class:`repro.core.join_sampler.JoinSampler`
  (EW/EO batched walks).
* Membership probes delegate to
  :class:`repro.core.membership.MembershipProber` (128-bit fingerprint
  row-set indexes), which already satisfies the
  :class:`~repro.core.backends.base.MembershipOracle` protocol.

This backend is behaviour-identical to the pre-backend-layer code path: it
draws from the caller's ``rng`` in the same order with the same batch sizes,
so seeded runs reproduce bit-for-bit.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ..index import Catalog
from ..join_sampler import JoinSampler
from ..joins import JoinSpec
from ..membership import MembershipProber
from .base import Backend, Rows


class NumpyCandidateSource:
    """Uniform candidate draws via the host batched-walk sampler."""

    def __init__(self, cat: Catalog, spec: JoinSpec, method: str = "ew"):
        self.join_name = spec.name
        self.sampler = JoinSampler(cat, spec, method=method)
        self._rej_seen = 0

    def draw(self, rng: np.random.Generator, count: int,
             batch: Optional[int] = None) -> Tuple[Rows, int]:
        if batch is None:
            batch = max(count, 64)
        return self.sampler.sample_uniform(rng, count, batch=batch)

    def pop_residual_rejects(self) -> int:
        """Residual (§8.2 cyclic) rejections since the last pop."""
        cur = self.sampler.residual_rejects
        d, self._rej_seen = cur - self._rej_seen, cur
        return d

    def is_empty(self) -> bool:
        return self.sampler.is_empty()


class NumpyBackend(Backend):
    name = "numpy"

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec],
                 join_method: str = "ew", seed: int = 0):
        self.cat = cat
        self.joins = list(joins)
        self._sources: Dict[str, NumpyCandidateSource] = {
            j.name: NumpyCandidateSource(cat, j, method=join_method)
            for j in self.joins
        }
        self._oracle = MembershipProber(cat, self.joins)

    def source(self, join_name: str) -> NumpyCandidateSource:
        return self._sources[join_name]

    def oracle(self) -> MembershipProber:
        return self._oracle
