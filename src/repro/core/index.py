"""Sorted-key indexes — the TPU-native replacement for per-column hash tables.

An index over ``(relation, key)`` is ``(perm, sorted_vals, fences)``:

* ``perm``        — argsort permutation (row ids in key order),
* ``sorted_vals`` — the key column in sorted order,
* ``fences``      — every ``FENCE_STRIDE``-th sorted key; small enough to live
                    in VMEM so a Pallas probe does a branchless binary search
                    on the fences and then one refinement block DMA.

Every probe (``lo/hi`` range per query), degree lookup, membership test and
wander-join hop in :mod:`repro.core` reduces to ``searchsorted`` over these
arrays.  The host path below uses ``np.searchsorted``; the device path
(`use_kernel=True` consumers) routes through :mod:`repro.kernels.ops`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .relation import Relation, combine_columns, fingerprint128

FENCE_STRIDE = 128


@dataclasses.dataclass
class SortedIndex:
    """Sorted index of one (possibly composite) key column of a relation."""

    relation: str
    key_attrs: Tuple[str, ...]
    perm: np.ndarray          # (n,) int64 row ids in sorted key order
    sorted_vals: np.ndarray   # (n,) int64 sorted keys
    fences: np.ndarray        # (ceil(n/FENCE_STRIDE),) int64

    # -- probes --------------------------------------------------------------
    def ranges(self, queries: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Per-query [lo, hi) positions in the sorted order."""
        q = np.asarray(queries)
        lo = np.searchsorted(self.sorted_vals, q, side="left")
        hi = np.searchsorted(self.sorted_vals, q, side="right")
        return lo, hi

    def degrees(self, queries: np.ndarray) -> np.ndarray:
        lo, hi = self.ranges(queries)
        return hi - lo

    def contains(self, queries: np.ndarray) -> np.ndarray:
        lo, hi = self.ranges(queries)
        return hi > lo

    def row_ids_at(self, pos: np.ndarray) -> np.ndarray:
        """Row ids of sorted positions (for gathering matched rows)."""
        return self.perm[np.asarray(pos)]

    # -- stats ----------------------------------------------------------------
    @property
    def nrows(self) -> int:
        return int(self.sorted_vals.shape[0])

    def value_counts(self) -> Tuple[np.ndarray, np.ndarray]:
        """(unique values, per-value degree) — the exact 'histogram'."""
        vals, counts = np.unique(self.sorted_vals, return_counts=True)
        return vals, counts

    def max_degree(self) -> int:
        if self.nrows == 0:
            return 0
        _, counts = self.value_counts()
        return int(counts.max())

    def avg_degree(self) -> float:
        if self.nrows == 0:
            return 0.0
        vals, counts = self.value_counts()
        return float(counts.mean())


def build_index(rel: Relation, key_attrs: Sequence[str]) -> SortedIndex:
    key = rel.key(list(key_attrs))
    perm = np.argsort(key, kind="stable")
    sv = key[perm]
    fences = sv[::FENCE_STRIDE].copy() if sv.shape[0] else sv[:0]
    return SortedIndex(rel.name, tuple(key_attrs), perm.astype(np.int64), sv, fences)


@dataclasses.dataclass
class RowSetIndex:
    """Membership index over whole rows of a relation (projected sub-tuples).

    Sorted 64-bit primary fingerprints + secondary fingerprints for
    verification: a probe matches iff primary fp is found AND one of the
    candidates' secondary fps matches (128 bits total — exact for all
    practical purposes; tests additionally cross-check against raw values).
    """

    relation: str
    attrs: Tuple[str, ...]
    sorted_fp1: np.ndarray
    fp2_in_fp1_order: np.ndarray

    def contains_rows(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        cols = [np.asarray(rows[a]) for a in self.attrs]
        fp = fingerprint128(cols)
        lo = np.searchsorted(self.sorted_fp1, fp[:, 0], side="left")
        hi = np.searchsorted(self.sorted_fp1, fp[:, 0], side="right")
        out = np.zeros(fp.shape[0], dtype=bool)
        # verify secondaries; ranges are tiny (fp collisions ~ none)
        span = hi - lo
        simple = span <= 1
        pos = np.clip(lo, 0, max(self.sorted_fp1.shape[0] - 1, 0))
        if self.sorted_fp1.shape[0]:
            out[simple] = (span[simple] == 1) & (
                self.fp2_in_fp1_order[pos[simple]] == fp[simple, 1]
            )
        for i in np.nonzero(~simple)[0]:
            out[i] = bool(np.any(self.fp2_in_fp1_order[lo[i]:hi[i]] == fp[i, 1]))
        return out


def build_rowset_index(rel: Relation, attrs: Sequence[str]) -> RowSetIndex:
    attrs = tuple(attrs)
    fp = fingerprint128([rel.columns[a] for a in attrs])
    order = np.argsort(fp[:, 0], kind="stable")
    return RowSetIndex(rel.name, attrs, fp[order, 0], fp[order, 1])


# ---------------------------------------------------------------------------
# Catalog — per-column statistics the HISTOGRAM-BASED estimator consumes.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ColumnStats:
    distinct: int
    max_degree: int
    avg_degree: float
    # exact per-value histogram (what a DBMS histogram approximates)
    hist_values: np.ndarray
    hist_counts: np.ndarray

    def degree_of(self, values: np.ndarray) -> np.ndarray:
        pos = np.searchsorted(self.hist_values, values)
        pos = np.clip(pos, 0, max(self.hist_values.shape[0] - 1, 0))
        ok = (
            (self.hist_values.shape[0] > 0)
            & (self.hist_values[pos] == values)
        )
        return np.where(ok, self.hist_counts[pos], 0)


class Catalog:
    """Caches sorted indexes, row-set indexes, and column statistics."""

    def __init__(self) -> None:
        self._indexes: Dict[Tuple[str, Tuple[str, ...]], SortedIndex] = {}
        self._rowsets: Dict[Tuple[str, Tuple[str, ...]], RowSetIndex] = {}
        self._stats: Dict[Tuple[str, Tuple[str, ...]], ColumnStats] = {}
        self._relations: Dict[str, Relation] = {}

    def register(self, rel: Relation) -> None:
        self._relations[rel.name] = rel

    def relation(self, name: str) -> Relation:
        return self._relations[name]

    def index(self, rel: Relation, key_attrs: Sequence[str]) -> SortedIndex:
        self.register(rel)
        k = (rel.name, tuple(key_attrs))
        if k not in self._indexes:
            self._indexes[k] = build_index(rel, key_attrs)
        return self._indexes[k]

    def rowset(self, rel: Relation, attrs: Sequence[str]) -> RowSetIndex:
        self.register(rel)
        k = (rel.name, tuple(sorted(attrs)))
        if k not in self._rowsets:
            self._rowsets[k] = build_rowset_index(rel, sorted(attrs))
        return self._rowsets[k]

    def stats(self, rel: Relation, key_attrs: Sequence[str]) -> ColumnStats:
        self.register(rel)
        k = (rel.name, tuple(key_attrs))
        if k not in self._stats:
            idx = self.index(rel, key_attrs)
            vals, counts = idx.value_counts()
            self._stats[k] = ColumnStats(
                distinct=int(vals.shape[0]),
                max_degree=int(counts.max()) if counts.shape[0] else 0,
                avg_degree=float(counts.mean()) if counts.shape[0] else 0.0,
                hist_values=vals,
                hist_counts=counts,
            )
        return self._stats[k]
