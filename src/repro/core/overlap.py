"""Overlap-size estimation |O_Δ| for a set Δ of joins.

Three instantiations, mirroring the paper:

* :func:`exact_overlap`       — materialise the joins and intersect distinct
  tuple sets (the FULLJOIN ground truth of §9; exponential-cost baseline).
* :class:`HistogramOverlap`   — §5 / Theorem 4: degree-statistics upper bound
  over template-split chains.  Needs only per-column histograms — the
  *decentralised* (data-market) setting.
* :class:`RandomWalkOverlap`  — §6.2 / Eq. 2: wander-join walks from a pivot
  join, probed for membership in the other joins of Δ.  The estimator is the
  Horvitz–Thompson mean of ``indicator / p(t)`` which is *unbiased* for
  ``|O_Δ|`` (the paper's ``|J_j| · |∩ S'| / |S'_j|`` with the HT size folded
  in), with the delta-method CI the paper derives in Eq. 3.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .estimators.base import OverlapEstimate
from .estimators.numpy_estimator import NumpyEstimator
from .index import Catalog
from .joins import JoinSpec, full_join_matrix
from .splitting import SplitPlan, split_plans

__all__ = [
    "HistogramOverlap", "OverlapEstimate", "RandomWalkOverlap",
    "exact_join_size_distinct", "exact_overlap", "exact_union_size",
]


# ---------------------------------------------------------------------------
# Exact (FULLJOIN baseline)
# ---------------------------------------------------------------------------


def _row_view(mat: np.ndarray) -> np.ndarray:
    """View an (n,k) int64 matrix as an (n,) structured array for set ops."""
    mat = np.ascontiguousarray(mat)
    return mat.view([("", mat.dtype)] * mat.shape[1]).ravel()


def distinct_tuples(mat: np.ndarray) -> np.ndarray:
    return np.unique(_row_view(mat))


def exact_overlap(cat: Catalog, joins: Sequence[JoinSpec],
                  attrs: Optional[Sequence[str]] = None) -> int:
    """|∩_{J in joins} J| over distinct output tuples (expensive baseline)."""
    attrs = list(attrs) if attrs is not None else sorted(joins[0].output_attrs)
    sets = [distinct_tuples(full_join_matrix(cat, j, attrs)) for j in joins]
    cur = sets[0]
    for s in sets[1:]:
        cur = np.intersect1d(cur, s, assume_unique=True)
        if cur.shape[0] == 0:
            break
    return int(cur.shape[0])


def exact_union_size(cat: Catalog, joins: Sequence[JoinSpec],
                     attrs: Optional[Sequence[str]] = None) -> int:
    attrs = list(attrs) if attrs is not None else sorted(joins[0].output_attrs)
    sets = [distinct_tuples(full_join_matrix(cat, j, attrs)) for j in joins]
    cur = sets[0]
    for s in sets[1:]:
        cur = np.union1d(cur, s)
    return int(cur.shape[0])


def exact_join_size_distinct(cat: Catalog, join: JoinSpec,
                             attrs: Optional[Sequence[str]] = None) -> int:
    attrs = list(attrs) if attrs is not None else sorted(join.output_attrs)
    return int(distinct_tuples(full_join_matrix(cat, join, attrs)).shape[0])


# ---------------------------------------------------------------------------
# HISTOGRAM-BASED (Theorem 4 over split chains)
# ---------------------------------------------------------------------------


class HistogramOverlap:
    """Degree-statistics upper bound on |O_Δ| (decentralised setting)."""

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec],
                 template: Optional[Sequence[str]] = None,
                 mode: str = "max", cap_with_join_bound: bool = True):
        if mode not in ("max", "avg"):
            raise ValueError("mode must be 'max' (bound) or 'avg' (refined estimate)")
        self.cat = cat
        self.joins = list(joins)
        self.mode = mode
        self.cap = cap_with_join_bound
        self.plans: Dict[str, SplitPlan] = {
            p.join.name: p for p in split_plans(joins, template)
        }
        self.template = next(iter(self.plans.values())).template
        from .size_estimation import olken_bound
        self._join_bounds = {j.name: olken_bound(cat, j) for j in joins}

    # -- per-join, per-pair statistics ---------------------------------------
    def _pair_degree_hist(self, plan: SplitPlan, i: int, attr: str
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact per-value histogram of ``attr`` in pair i's source relation."""
        pair = plan.pairs[i]
        if pair.source_alias is not None:
            rel = plan.join.node(pair.source_alias).relation
        else:
            # fallback: use the first relation on the path holding the attr
            alias = next(a for a in pair.path_aliases
                         if attr in plan.join.node(a).relation.attrs)
            rel = plan.join.node(alias).relation
        st = self.cat.stats(rel, [attr])
        return st.hist_values, st.hist_counts

    def _pair_multiplier(self, plan: SplitPlan, i: int) -> float:
        """M_{j,i}: multiplier for extending through pair i (Theorem 4)."""
        pair = plan.pairs[i]
        lead = pair.attrs[0]
        if pair.source_alias is not None:
            if pair.fake_edge_to_prev:
                return 1.0  # fake join — row identity continues
            rel = plan.join.node(pair.source_alias).relation
            st = self.cat.stats(rel, [lead])
            return float(st.max_degree if self.mode == "max" else max(st.avg_degree, 1e-12))
        # path fallback: product of per-hop degrees along the connecting path
        m = 1.0
        for alias in pair.path_aliases:
            rel = plan.join.node(alias).relation
            held = [a for a in pair.attrs if a in rel.attrs]
            st = self.cat.stats(rel, [held[0] if held else rel.attrs[0]])
            m *= float(st.max_degree if self.mode == "max" else max(st.avg_degree, 1e-12))
        return m

    def estimate(self, delta: Sequence[JoinSpec]) -> float:
        """Upper bound (mode='max') or refined estimate (mode='avg') of |O_Δ|."""
        delta = list(delta)
        if len(delta) == 1:
            only = delta[0]
            val = self._join_bounds[only.name]
            return float(val)
        plans = [self.plans[j.name] for j in delta]
        k = len(self.template) - 1  # number of pairs

        # K(1): value-level min over joins on the first edge's shared attr.
        # First edge connects pair 0 and pair 1 on template[1].
        first_attr = self.template[1]
        per_join_value_counts: List[Tuple[np.ndarray, np.ndarray]] = []
        for plan in plans:
            v0, c0 = self._pair_degree_hist(plan, 0, first_attr)
            if k >= 2:
                p1 = plan.pairs[1]
                if p1.fake_edge_to_prev:
                    # row identity: pairs with A2=v == d(v) rows
                    per_join_value_counts.append((v0, c0.astype(np.float64)))
                    continue
                v1, c1 = self._pair_degree_hist(plan, 1, first_attr)
                common, i0, i1 = np.intersect1d(v0, v1, assume_unique=True,
                                                return_indices=True)
                per_join_value_counts.append(
                    (common, c0[i0].astype(np.float64) * c1[i1].astype(np.float64)))
            else:
                per_join_value_counts.append((v0, c0.astype(np.float64)))

        # intersect the value domains across joins and take the min count
        vals = per_join_value_counts[0][0]
        for v, _ in per_join_value_counts[1:]:
            vals = np.intersect1d(vals, v, assume_unique=True)
        if vals.shape[0] == 0:
            return 0.0
        kacc = np.full(vals.shape[0], np.inf)
        for v, c in per_join_value_counts:
            pos = np.searchsorted(v, vals)
            kacc = np.minimum(kacc, c[pos])
        k1 = float(kacc.sum())

        # K(i) for the remaining pairs: multiply by min over joins of M_{j,i}
        bound = k1
        for i in range(2, k):
            bound *= min(self._pair_multiplier(plan, i) for plan in plans)
        if self.cap:
            bound = min(bound, min(self._join_bounds[j.name] for j in delta))
        return float(bound)

    def join_size_bound(self, join: JoinSpec) -> float:
        return float(self._join_bounds[join.name])


# ---------------------------------------------------------------------------
# RANDOM-WALK (Eq. 2 + Eq. 3)
# ---------------------------------------------------------------------------
#
# The implementation lives in the estimator subsystem now
# (repro/core/estimators/): NumpyEstimator is the behaviour-identical host
# reference (same class body, same random stream), JaxEstimator runs the
# whole walk+probe+HT pipeline on device.  RandomWalkOverlap stays as the
# historical name of the host engine.


class RandomWalkOverlap(NumpyEstimator):
    """Unbiased overlap estimation from wander-join walks + membership probes."""
