"""Overlap-size estimation |O_Δ| for a set Δ of joins.

Three instantiations, mirroring the paper:

* :func:`exact_overlap`       — materialise the joins and intersect distinct
  tuple sets (the FULLJOIN ground truth of §9; exponential-cost baseline).
* :class:`HistogramOverlap`   — §5 / Theorem 4: degree-statistics upper bound
  over template-split chains.  Needs only per-column histograms — the
  *decentralised* (data-market) setting.
* :class:`RandomWalkOverlap`  — §6.2 / Eq. 2: wander-join walks from a pivot
  join, probed for membership in the other joins of Δ.  The estimator is the
  Horvitz–Thompson mean of ``indicator / p(t)`` which is *unbiased* for
  ``|O_Δ|`` (the paper's ``|J_j| · |∩ S'| / |S'_j|`` with the HT size folded
  in), with the delta-method CI the paper derives in Eq. 3.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from .index import Catalog
from .joins import JoinSpec, full_join_matrix
from .join_sampler import JoinSampler
from .membership import MembershipProber
from .size_estimation import RunningMean, z_value
from .splitting import SplitPlan, split_plans


# ---------------------------------------------------------------------------
# Exact (FULLJOIN baseline)
# ---------------------------------------------------------------------------


def _row_view(mat: np.ndarray) -> np.ndarray:
    """View an (n,k) int64 matrix as an (n,) structured array for set ops."""
    mat = np.ascontiguousarray(mat)
    return mat.view([("", mat.dtype)] * mat.shape[1]).ravel()


def distinct_tuples(mat: np.ndarray) -> np.ndarray:
    return np.unique(_row_view(mat))


def exact_overlap(cat: Catalog, joins: Sequence[JoinSpec],
                  attrs: Optional[Sequence[str]] = None) -> int:
    """|∩_{J in joins} J| over distinct output tuples (expensive baseline)."""
    attrs = list(attrs) if attrs is not None else sorted(joins[0].output_attrs)
    sets = [distinct_tuples(full_join_matrix(cat, j, attrs)) for j in joins]
    cur = sets[0]
    for s in sets[1:]:
        cur = np.intersect1d(cur, s, assume_unique=True)
        if cur.shape[0] == 0:
            break
    return int(cur.shape[0])


def exact_union_size(cat: Catalog, joins: Sequence[JoinSpec],
                     attrs: Optional[Sequence[str]] = None) -> int:
    attrs = list(attrs) if attrs is not None else sorted(joins[0].output_attrs)
    sets = [distinct_tuples(full_join_matrix(cat, j, attrs)) for j in joins]
    cur = sets[0]
    for s in sets[1:]:
        cur = np.union1d(cur, s)
    return int(cur.shape[0])


def exact_join_size_distinct(cat: Catalog, join: JoinSpec,
                             attrs: Optional[Sequence[str]] = None) -> int:
    attrs = list(attrs) if attrs is not None else sorted(join.output_attrs)
    return int(distinct_tuples(full_join_matrix(cat, join, attrs)).shape[0])


# ---------------------------------------------------------------------------
# HISTOGRAM-BASED (Theorem 4 over split chains)
# ---------------------------------------------------------------------------


class HistogramOverlap:
    """Degree-statistics upper bound on |O_Δ| (decentralised setting)."""

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec],
                 template: Optional[Sequence[str]] = None,
                 mode: str = "max", cap_with_join_bound: bool = True):
        if mode not in ("max", "avg"):
            raise ValueError("mode must be 'max' (bound) or 'avg' (refined estimate)")
        self.cat = cat
        self.joins = list(joins)
        self.mode = mode
        self.cap = cap_with_join_bound
        self.plans: Dict[str, SplitPlan] = {
            p.join.name: p for p in split_plans(joins, template)
        }
        self.template = next(iter(self.plans.values())).template
        from .size_estimation import olken_bound
        self._join_bounds = {j.name: olken_bound(cat, j) for j in joins}

    # -- per-join, per-pair statistics ---------------------------------------
    def _pair_degree_hist(self, plan: SplitPlan, i: int, attr: str
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact per-value histogram of ``attr`` in pair i's source relation."""
        pair = plan.pairs[i]
        if pair.source_alias is not None:
            rel = plan.join.node(pair.source_alias).relation
        else:
            # fallback: use the first relation on the path holding the attr
            alias = next(a for a in pair.path_aliases
                         if attr in plan.join.node(a).relation.attrs)
            rel = plan.join.node(alias).relation
        st = self.cat.stats(rel, [attr])
        return st.hist_values, st.hist_counts

    def _pair_multiplier(self, plan: SplitPlan, i: int) -> float:
        """M_{j,i}: multiplier for extending through pair i (Theorem 4)."""
        pair = plan.pairs[i]
        lead = pair.attrs[0]
        if pair.source_alias is not None:
            if pair.fake_edge_to_prev:
                return 1.0  # fake join — row identity continues
            rel = plan.join.node(pair.source_alias).relation
            st = self.cat.stats(rel, [lead])
            return float(st.max_degree if self.mode == "max" else max(st.avg_degree, 1e-12))
        # path fallback: product of per-hop degrees along the connecting path
        m = 1.0
        for alias in pair.path_aliases:
            rel = plan.join.node(alias).relation
            held = [a for a in pair.attrs if a in rel.attrs]
            st = self.cat.stats(rel, [held[0] if held else rel.attrs[0]])
            m *= float(st.max_degree if self.mode == "max" else max(st.avg_degree, 1e-12))
        return m

    def estimate(self, delta: Sequence[JoinSpec]) -> float:
        """Upper bound (mode='max') or refined estimate (mode='avg') of |O_Δ|."""
        delta = list(delta)
        if len(delta) == 1:
            only = delta[0]
            val = self._join_bounds[only.name]
            return float(val)
        plans = [self.plans[j.name] for j in delta]
        k = len(self.template) - 1  # number of pairs

        # K(1): value-level min over joins on the first edge's shared attr.
        # First edge connects pair 0 and pair 1 on template[1].
        first_attr = self.template[1]
        per_join_value_counts: List[Tuple[np.ndarray, np.ndarray]] = []
        for plan in plans:
            v0, c0 = self._pair_degree_hist(plan, 0, first_attr)
            if k >= 2:
                p1 = plan.pairs[1]
                if p1.fake_edge_to_prev:
                    # row identity: pairs with A2=v == d(v) rows
                    per_join_value_counts.append((v0, c0.astype(np.float64)))
                    continue
                v1, c1 = self._pair_degree_hist(plan, 1, first_attr)
                common, i0, i1 = np.intersect1d(v0, v1, assume_unique=True,
                                                return_indices=True)
                per_join_value_counts.append(
                    (common, c0[i0].astype(np.float64) * c1[i1].astype(np.float64)))
            else:
                per_join_value_counts.append((v0, c0.astype(np.float64)))

        # intersect the value domains across joins and take the min count
        vals = per_join_value_counts[0][0]
        for v, _ in per_join_value_counts[1:]:
            vals = np.intersect1d(vals, v, assume_unique=True)
        if vals.shape[0] == 0:
            return 0.0
        kacc = np.full(vals.shape[0], np.inf)
        for v, c in per_join_value_counts:
            pos = np.searchsorted(v, vals)
            kacc = np.minimum(kacc, c[pos])
        k1 = float(kacc.sum())

        # K(i) for the remaining pairs: multiply by min over joins of M_{j,i}
        bound = k1
        for i in range(2, k):
            bound *= min(self._pair_multiplier(plan, i) for plan in plans)
        if self.cap:
            bound = min(bound, min(self._join_bounds[j.name] for j in delta))
        return float(bound)

    def join_size_bound(self, join: JoinSpec) -> float:
        return float(self._join_bounds[join.name])


# ---------------------------------------------------------------------------
# RANDOM-WALK (Eq. 2 + Eq. 3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class OverlapEstimate:
    value: float
    half_width: float
    walks: int


class RandomWalkOverlap:
    """Unbiased overlap estimation from wander-join walks + membership probes."""

    def __init__(self, cat: Catalog, joins: Sequence[JoinSpec], seed: int = 0,
                 batch: int = 512):
        self.cat = cat
        self.joins = list(joins)
        self.by_name = {j.name: j for j in self.joins}
        self.prober = MembershipProber(cat, self.joins)
        self.batch = batch
        self._samplers: Dict[str, JoinSampler] = {}
        self._rng = np.random.default_rng(seed)
        # per-Δ running statistics: HT mean of indicator/p (=|O|) and of 1/p (=|J|)
        self._stats: Dict[FrozenSet[str], RunningMean] = {}
        self._size_stats: Dict[str, RunningMean] = {}
        # reuse pool: walk tuples + probabilities per join (feeds ONLINE-UNION §7)
        self.walk_pool: Dict[str, List[Tuple[Dict[str, np.ndarray], np.ndarray]]] = {}

    def sampler(self, name: str) -> JoinSampler:
        if name not in self._samplers:
            self._samplers[name] = JoinSampler(self.cat, self.by_name[name], method="wj")
        return self._samplers[name]

    def _pivot(self, delta: Sequence[JoinSpec]) -> JoinSpec:
        # pivot = join with the smallest Olken bound (lowest-variance walks)
        from .size_estimation import olken_bound
        return min(delta, key=lambda j: olken_bound(self.cat, j))

    def observe(self, delta: Sequence[JoinSpec], rounds: int = 1) -> OverlapEstimate:
        """Run ``rounds`` batches of walks on the pivot and update estimates."""
        delta = list(delta)
        key = frozenset(j.name for j in delta)
        stat = self._stats.setdefault(key, RunningMean())
        pivot = self._pivot(delta)
        others = [j for j in delta if j.name != pivot.name]
        smp = self.sampler(pivot.name)
        for _ in range(rounds):
            sb = smp.sample_batch(self._rng, self.batch)
            inv = np.where(sb.ok & (sb.prob > 0), 1.0 / np.maximum(sb.prob, 1e-300), 0.0)
            self._size_stats.setdefault(pivot.name, RunningMean()).update_batch(inv)
            ind = sb.ok.copy()
            if others and ind.any():
                member = np.ones(self.batch, dtype=bool)
                for j in others:
                    member &= self.prober.contains(j.name, sb.rows)
                ind &= member
            stat.update_batch(np.where(ind, inv, 0.0))
            self.walk_pool.setdefault(pivot.name, []).append((sb.rows, sb.prob))
        return OverlapEstimate(stat.mean, stat.half_width(0.90), stat.count)

    def estimate(self, delta: Sequence[JoinSpec], confidence: float = 0.90,
                 rel_halfwidth: float = 0.25, max_walks: int = 50_000,
                 min_walks: int = 512) -> OverlapEstimate:
        """Walk until the CI is tight (or budget exhausted); Eq. 2 estimate."""
        delta = list(delta)
        key = frozenset(j.name for j in delta)
        while True:
            est = self.observe(delta, rounds=1)
            stat = self._stats[key]
            if stat.count >= min_walks:
                hw = stat.half_width(confidence)
                if est.value <= 0 and stat.count >= min_walks * 4:
                    break  # looks empty
                if est.value > 0 and hw <= rel_halfwidth * est.value:
                    break
            if stat.count >= max_walks:
                break
        stat = self._stats[key]
        return OverlapEstimate(max(stat.mean, 0.0), stat.half_width(confidence), stat.count)

    def join_size(self, join: JoinSpec, min_walks: int = 512) -> float:
        """HT size of one join (walked as a Δ of size 1)."""
        st = self._size_stats.get(join.name)
        while st is None or st.count < min_walks:
            self.observe([join], rounds=1)
            st = self._size_stats[join.name]
        return max(st.mean, 0.0)
