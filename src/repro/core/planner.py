"""Cost-driven round planning for the fused Algorithm-1 engines.

Two cooperating pieces live here:

* **Fixed-point EMA arithmetic** shared by the host twin (numpy) and the
  device loop (jnp).  Per-piece acceptance rates are carried as ``(nj, 4)``
  int32 arrays in units of ``EMA_ONE == 2**16`` — columns are
  ``(accept, walk_ok, residual, pred)`` fractions of the slots budgeted to
  the piece that round.  Every operation below is an integer add / shift /
  floor-divide, so the numpy host twin and the jitted device carry compute
  **bit-identical** budgets from identical counts.  Budgets depend only on
  carried counts (owed work, bank occupancy, acceptance EMAs) — never on
  sample *values* — which is the same argument that keeps the shortfall
  carry uniform: the accepted candidates inside a round are i.i.d. and
  masking a count-derived prefix of draw slots cannot bias them.

* **A host-side cost model** (:class:`PlanCache`) that autotunes
  ``round_batch`` / ``surplus_cap`` / drain window per (catalog, workload,
  capacity class) from timed calls.  The model is the two-parameter
  ``t_round = c0 + c1 * slots`` fit: per-round fixed overhead (dispatch,
  collectives, scatter) versus per-candidate-slot cost.  Engines feed it
  observations after each timed ``sample()``; ``SetUnionSampler`` consults
  it when built with ``round_batch=None``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .predicates import selectivity_factor

# -- fixed-point constants ----------------------------------------------------

EMA_ONE = 1 << 16          # fixed-point scale: 65536 == acceptance rate 1.0
EMA_ALPHA_SHIFT = 3        # ema += (rate - ema) >> 3   (alpha = 1/8)
EMA_FLOOR = 1 << 10        # ~1.6% assumed minimum acceptance when budgeting
BUDGET_FLOOR = 32          # keep starved pieces probing even when ema says no
NEED_CLAMP = 1 << 14       # clamp need before *EMA_ONE so int32 cannot overflow
EMA_COMPONENTS = ("accept", "walk_ok", "residual", "pred")


def ema_shifts(piece_batches: Sequence[int]) -> np.ndarray:
    """Static per-piece right-shifts so ``count * EMA_ONE`` stays in int32.

    A piece that may draw ``B`` slots per round needs ``B >> s <= 2**14 - 1``
    before the ``* EMA_ONE`` (``2**16``) scale-up.
    """
    return np.asarray(
        [max(0, int(b).bit_length() - 14) for b in piece_batches], np.int32
    )


def seed_rates(cover, specs: Dict[str, object]) -> np.ndarray:
    """(nj, 4) int32 EMA seed so round 1 is not cold.

    Column 0 (accept) seeds from the §5 histogram bounds already folded into
    the cover — ``piece_size / join_size`` is exactly the probability that a
    uniform draw from join *j* lands in piece *j* — scaled by the §8.3
    predicate ``selectivity_factor`` for rejection-mode unions where draws
    come from the unfiltered tree.  Column 3 seeds the complementary
    predicate-reject fraction; walk_ok starts optimistic and residual at 0
    (acyclic default) — both converge within a few EMA steps on cyclic joins.
    """
    rows = []
    for name in cover.order:
        js = max(float(cover.join_sizes.get(name, 0.0)), 1e-9)
        ps = max(float(cover.piece_sizes.get(name, 0.0)), 0.0)
        frac = min(max(ps / js, 1.0 / 64.0), 1.0)
        sf = 1.0
        spec = specs.get(name)
        if spec is not None:
            try:
                sf = float(selectivity_factor(spec))
            except Exception:
                sf = 1.0
        acc = min(max(frac * sf, 1.0 / 64.0), 1.0)
        pred = min(max(1.0 - sf, 0.0), 1.0)
        rows.append(
            [int(round(acc * EMA_ONE)), EMA_ONE, 0, int(round(pred * EMA_ONE))]
        )
    return np.asarray(rows, np.int32)


# adaptive selection-slot expansion: slots per round = round_batch * 9/4.
# On XLA:CPU the fused round has a large width-independent cost (dispatch,
# cover selection, per-piece scatter/gather op overhead) — ~300us against
# ~0.5us per extra slot at round_batch=256 — so an adaptive round amortizes
# it over ~2.25x the emission targets of a static round and wins wall-clock
# even though each round is individually more expensive.  The widths that
# *supply* those slots come from :func:`alloc_batches`, so the extra slots
# are backed by expected accepts, not by padding.
SLOT_EXPANSION = (9, 4)


def adaptive_slot(round_batch: int) -> int:
    num, den = SLOT_EXPANSION
    return max(int(round_batch), (int(round_batch) * num) // den)


def alloc_batches(base_batches: Sequence[int], probs, ema_seed_accept,
                  slot_width: int, *, granule: int = 32,
                  floor: int = 64) -> Tuple[int, ...]:
    """Demand-matched adaptive draw widths (static shapes, fixed at build).

    The cover-balanced schedule sizes piece *j*'s draw batch from its
    selection probability alone; with the seeded acceptance EMAs the
    expected per-round *demand* on piece *j* is ``slot_width * p_j`` and
    the draws needed to supply it ``demand / acc_j``.  Allocating exactly
    that quantity (nearest ``granule``, capped at ``slot_width``, no
    headroom — a round that comes up short just carries the shortfall and
    the surplus banks buffer the over-supplied rounds, so expectation-exact
    widths beat padded ones on wall-clock) removes the draw slots the
    static schedule wastes on high-acceptance or low-mass pieces and adds
    them where the expanded slot actually needs supply — masked draw slots
    still cost full compute under XLA's static shapes, so the wall-clock
    win must come from the array widths, not the runtime budget mask.
    Allocation uses only cover statistics and the EMA *seeds* (counts,
    never sample values), so the i.i.d.-prefix uniformity argument is
    untouched.  ``base_batches`` only fixes the piece count; a seed capped
    at :data:`EMA_FLOOR` keeps a pessimistic piece from claiming more than
    the whole round (carry + the budget floor take over from there).
    """
    p = np.maximum(np.asarray(probs, np.float64), 0)
    s = p.sum()
    if s > 0:
        p = p / s
    acc = np.maximum(np.asarray(ema_seed_accept, np.float64),
                     float(EMA_FLOOR)) / float(EMA_ONE)
    out = []
    for j in range(len(base_batches)):
        want = int(np.ceil(slot_width * p[j] / acc[j]))
        w = max(int(floor), ((want + granule // 2) // granule) * granule)
        out.append(int(min(int(slot_width), w)))
    return tuple(out)


def budget_for(need, bank_count, ema_accept, bmax, drain_w, xp):  # analysis: fixed-point
    """Integer candidate budget per piece — identical under numpy and jnp.

    ``need`` minus usable bank coverage, divided by the accept EMA (ceil),
    plus 12.5% headroom; floored at :data:`BUDGET_FLOOR` while the piece
    still owes work and capped at its static draw width.  All int32.
    """
    cover = xp.minimum(bank_count, drain_w)
    need_eff = xp.clip(need - cover, 0, NEED_CLAMP)
    e = xp.maximum(ema_accept, EMA_FLOOR)
    desired = (need_eff * EMA_ONE + e - 1) // e
    desired = desired + xp.right_shift(desired, 3)
    b = xp.clip(desired, BUDGET_FLOOR, bmax)
    return xp.where(need_eff > 0, b, 0)


def ema_update(ema, drawn, counts, shifts, xp):  # analysis: fixed-point
    """One EMA step from this round's per-piece counts (all int32).

    ``counts`` is ``(nj, 4)`` — (accepted, walk_ok, residual, pred) — and
    ``drawn`` the per-piece budget actually eligible this round.  Pieces
    with ``drawn == 0`` keep their EMA.  ``shifts`` pre-scales both sides of
    the ratio so ``count * EMA_ONE`` cannot overflow int32.
    """
    ds = xp.right_shift(drawn, shifts)
    rate = (xp.right_shift(counts, shifts[:, None]) * EMA_ONE) // xp.maximum(
        ds, 1
    )[:, None]
    upd = ema + xp.right_shift(rate - ema, EMA_ALPHA_SHIFT)
    return xp.where((drawn > 0)[:, None], upd, ema)


# -- host twin for the ONLINE-UNION fresh-draw path ---------------------------


class PiecePlanner:
    """Host-side planner state for :class:`~repro.core.online.OnlineUnionSampler`.

    The same (nj, 4) fixed-point EMAs as the device carry, driving the size
    of the batched fresh-draw each retry makes under ``plan="adaptive"``:
    ``ceil(1/ema_accept)`` candidates (plus headroom) so one retry round
    yields ~1 accepted sample in expectation.  φ-refresh events reseed it.
    """

    def __init__(self, cover, specs: Dict[str, object],
                 max_batch: int = 64) -> None:
        self.max_batch = int(max_batch)
        self.refreshes = 0
        self.reseed(cover, specs)

    def reseed(self, cover, specs: Dict[str, object]) -> None:
        self.ema = seed_rates(cover, specs)
        self.refreshes += 1

    def suggest_batch(self, oidx: int) -> int:
        e = max(int(self.ema[oidx, 0]), EMA_FLOOR)
        k = -(-EMA_ONE // e)          # ceil(1 / ema_accept)
        k = k + (k >> 3)
        return max(1, min(k, self.max_batch))

    def observe(self, oidx: int, drawn: int, accepted: int,
                pred_rejects: int = 0) -> None:
        if drawn <= 0:
            return
        row = self.ema[oidx:oidx + 1]
        counts = np.asarray(
            [[accepted, drawn, 0, pred_rejects]], np.int32
        )
        # walk_ok stays pinned at ``drawn`` here: the host draw path only
        # surfaces completed candidates, so walk failures are invisible.
        sh = np.zeros(1, np.int32)
        self.ema[oidx:oidx + 1] = ema_update(
            row, np.asarray([drawn], np.int32), counts, sh, np
        )


# -- autotuning cost model ----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """One autotuned knob set for a (workload, capacity class)."""

    round_batch: int
    surplus_cap: int
    drain_window: int


@dataclasses.dataclass
class _Obs:
    slots: int          # candidate slots per round at this round_batch
    rounds: int
    seconds: float
    samples: int

    @property
    def t_round(self) -> float:
        return self.seconds / max(self.rounds, 1)

    @property
    def emitted_per_round(self) -> float:
        return self.samples / max(self.rounds, 1)


def plan_key(cat, joins, cover, capacity: int = 0) -> str:
    """Catalog fingerprint + workload signature + capacity class."""
    h = hashlib.sha1()
    rels = getattr(cat, "_relations", {})
    for name in sorted(rels):
        h.update(f"{name}:{rels[name].nrows};".encode())
    for j in joins:
        h.update(f"{getattr(j, 'name', j)},".encode())
    h.update("|".join(cover.order).encode())
    h.update(f"|C{int(capacity)}".encode())
    return h.hexdigest()


_RB_CANDIDATES = (256, 512, 1024, 2048, 4096, 8192)


class PlanCache:
    """Process-global cache of timed-call observations and suggested plans.

    Keeps the fastest (min seconds/sample) observation per (key, round_batch)
    so the compile-polluted first call is displaced as soon as a warm call
    lands.  With one observed round_batch the ``c0``/``c1`` split falls back
    to a fixed 40/60 overhead prior; with two or more it is a least-squares
    fit of ``t_round = c0 + c1 * slots``.
    """

    _OVERHEAD_PRIOR = 0.4

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._obs: Dict[str, Dict[int, _Obs]] = {}

    def reset(self) -> None:
        with self._lock:
            self._obs.clear()

    def observe(self, key: str, round_batch: int, slots: int, rounds: int,
                seconds: float, samples: int) -> None:
        if rounds <= 0 or samples <= 0 or seconds <= 0.0:
            return
        o = _Obs(int(slots), int(rounds), float(seconds), int(samples))
        with self._lock:
            bucket = self._obs.setdefault(key, {})
            prev = bucket.get(int(round_batch))
            if prev is None or o.seconds / o.samples < prev.seconds / prev.samples:
                bucket[int(round_batch)] = o

    def fit(self, key: str) -> Optional[Tuple[float, float]]:
        """(c0, c1) of ``t_round = c0 + c1 * slots``, or None if no data."""
        with self._lock:
            bucket = dict(self._obs.get(key, {}))
        if not bucket:
            return None
        if len(bucket) == 1:
            (o,) = bucket.values()
            c0 = self._OVERHEAD_PRIOR * o.t_round
            return c0, (o.t_round - c0) / max(o.slots, 1)
        xs = np.asarray([o.slots for o in bucket.values()], np.float64)
        ys = np.asarray([o.t_round for o in bucket.values()], np.float64)
        a = np.stack([np.ones_like(xs), xs], axis=1)
        sol, *_ = np.linalg.lstsq(a, ys, rcond=None)
        c0, c1 = float(sol[0]), float(sol[1])
        return max(c0, 0.0), max(c1, 1e-12)

    def suggest(self, key: str) -> Optional[RoundPlan]:
        coeffs = self.fit(key)
        if coeffs is None:
            return None
        c0, c1 = coeffs
        with self._lock:
            bucket = dict(self._obs.get(key, {}))
        # Reference observation: scale slots and emitted/round linearly in rb.
        rb0, o0 = min(bucket.items(), key=lambda kv: kv[1].seconds / kv[1].samples)
        slots_per_rb = o0.slots / max(rb0, 1)
        emit_per_rb = o0.emitted_per_round / max(rb0, 1)
        best_rb, best_rate = None, -1.0
        for rb in _RB_CANDIDATES:
            slots = max(o0.slots, slots_per_rb * rb)
            emitted = max(1.0, emit_per_rb * rb)
            rate = emitted / (c0 + c1 * slots)
            if rate > best_rate:
                best_rb, best_rate = rb, rate
        assert best_rb is not None
        return RoundPlan(
            round_batch=best_rb,
            surplus_cap=8 * best_rb,
            drain_window=min(best_rb, 256),
        )


PLAN_CACHE = PlanCache()
