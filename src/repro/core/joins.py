"""Join specifications: chain, acyclic (tree), and cyclic joins.

A join is an ordered list of :class:`JoinNode`.  Tree nodes reference a parent
node and equi-join it on ``edge_attrs`` (attribute names are standardised
across relations, as the paper assumes).  Cyclic joins are represented the way
the paper (following Zhao et al. [38]) evaluates them: an acyclic *skeleton*
tree plus *residual* nodes whose edge attributes may span several earlier
relations (the residual set is typically materialised into one relation by
:func:`materialize_residual`).

All joins keep their full concatenated output schema (every base attribute
survives; join attributes appear once) — this is what makes batched
membership probes exact (see :mod:`repro.core.membership`).

``full_join`` materialises the result with vectorised sorted-index expansion
(prefix offsets + ``np.repeat`` gathers) — it is the FULLJOIN baseline of the
paper's evaluation, not a subroutine of the samplers.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .catalog_util import as_tuple
from .index import Catalog
from .relation import Relation, combine_columns


@dataclasses.dataclass
class JoinNode:
    alias: str
    relation: Relation
    parent: Optional[str]            # alias of parent (tree nodes); None for root
    edge_attrs: Tuple[str, ...]      # equi-join attributes shared with parent/earlier output
    kind: str = "tree"               # "tree" (incl. root) | "residual"

    def __post_init__(self) -> None:
        self.edge_attrs = as_tuple(self.edge_attrs)


class JoinSpec:
    """An ordered join over base relations (chain / acyclic / cyclic)."""

    # §8.3 predicate provenance — set by the repro.core.predicates helpers
    # (class-level defaults keep hand-built specs clean):
    #  * pushed_preds / pushdown_base: filters already materialised into the
    #    nodes by pushdown(), plus the unfiltered spec they came from — the
    #    device engine rebuilds the filtered join as validity masks over the
    #    base relations from these.
    #  * reject_preds: sampler-side per-join rejection predicates — samplers
    #    reject failing candidates, membership/size estimation apply them, so
    #    the filtered join is the set-union member everywhere.
    pushed_preds: Tuple = ()
    pushdown_base: Optional["JoinSpec"] = None
    reject_preds: Tuple = ()

    def __init__(self, name: str, nodes: Sequence[JoinNode]):
        self.name = name
        self.nodes: List[JoinNode] = list(nodes)
        if not self.nodes:
            raise ValueError("empty join")
        self._by_alias = {n.alias: n for n in self.nodes}
        if len(self._by_alias) != len(self.nodes):
            raise ValueError(f"duplicate aliases in join {name!r}")
        self._validate()

    # -- structure ------------------------------------------------------------
    @property
    def root(self) -> JoinNode:
        roots = [n for n in self.nodes if n.kind == "tree" and n.parent is None]
        if len(roots) != 1:
            raise ValueError(f"join {self.name!r} must have exactly one tree root")
        return roots[0]

    @property
    def tree_nodes(self) -> List[JoinNode]:
        return [n for n in self.nodes if n.kind == "tree"]

    @property
    def residual_nodes(self) -> List[JoinNode]:
        return [n for n in self.nodes if n.kind == "residual"]

    @property
    def is_cyclic(self) -> bool:
        return bool(self.residual_nodes)

    @property
    def is_chain(self) -> bool:
        if self.is_cyclic:
            return False
        kids = self.children_map()
        return all(len(kids.get(n.alias, [])) <= 1 for n in self.tree_nodes)

    def node(self, alias: str) -> JoinNode:
        return self._by_alias[alias]

    def children_map(self) -> Dict[str, List[JoinNode]]:
        out: Dict[str, List[JoinNode]] = {}
        for n in self.tree_nodes:
            if n.parent is not None:
                out.setdefault(n.parent, []).append(n)
        return out

    @property
    def output_attrs(self) -> List[str]:
        seen: List[str] = []
        for n in self.nodes:
            for a in n.relation.attrs:
                if a not in seen:
                    seen.append(a)
        return seen

    def relations(self) -> List[Relation]:
        return [n.relation for n in self.nodes]

    # -- validation -----------------------------------------------------------
    def _validate(self) -> None:
        produced: set = set()
        order = self._expansion_order()
        for i, n in enumerate(order):
            if i == 0:
                if n.parent is not None or n.kind != "tree":
                    raise ValueError("first node in expansion order must be the root")
            else:
                missing = [a for a in n.edge_attrs if a not in produced]
                if missing:
                    raise ValueError(
                        f"join {self.name!r}: node {n.alias!r} edge attrs {missing} "
                        f"not produced by earlier nodes"
                    )
                if not n.edge_attrs:
                    raise ValueError(f"join {self.name!r}: node {n.alias!r} has no edge attrs")
                if n.kind == "tree":
                    parent_attrs = set(self._by_alias[n.parent].relation.attrs)
                    bad = [a for a in n.edge_attrs if a not in parent_attrs]
                    if bad:
                        raise ValueError(
                            f"join {self.name!r}: tree node {n.alias!r} edge attrs {bad} "
                            f"missing from parent {n.parent!r}"
                        )
                missing_child = [a for a in n.edge_attrs if a not in n.relation.attrs]
                if missing_child:
                    raise ValueError(
                        f"join {self.name!r}: node {n.alias!r} lacks its edge attrs {missing_child}"
                    )
            produced.update(n.relation.attrs)

    def _expansion_order(self) -> List[JoinNode]:
        """Root-first order: parents before children, residuals last."""
        order: List[JoinNode] = []
        remaining = {n.alias: n for n in self.tree_nodes}
        roots = [n for n in self.tree_nodes if n.parent is None]
        frontier = list(roots)
        while frontier:
            n = frontier.pop(0)
            order.append(n)
            remaining.pop(n.alias, None)
            frontier.extend([c for c in self.tree_nodes if c.parent == n.alias])
        if remaining:
            raise ValueError(f"join {self.name!r}: disconnected tree nodes {list(remaining)}")
        order.extend(self.residual_nodes)
        return order

    def expansion_order(self) -> List[JoinNode]:
        return self._expansion_order()

    def __repr__(self) -> str:  # pragma: no cover
        parts = [f"{n.alias}({'root' if n.parent is None and n.kind=='tree' else ','.join(n.edge_attrs)})"
                 for n in self.nodes]
        return f"JoinSpec({self.name!r}: {' ⋈ '.join(parts)})"


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------


def chain_join(name: str, relations: Sequence[Relation],
               edge_attrs: Sequence[Sequence[str] | str]) -> JoinSpec:
    """R1 ⋈_{e1} R2 ⋈_{e2} ... ⋈_{e_{m-1}} Rm."""
    if len(edge_attrs) != len(relations) - 1:
        raise ValueError("need len(relations)-1 edge attr sets")
    nodes = [JoinNode(relations[0].name, relations[0], None, ())]
    for i, rel in enumerate(relations[1:]):
        ea = edge_attrs[i]
        ea = (ea,) if isinstance(ea, str) else tuple(ea)
        nodes.append(JoinNode(rel.name, rel, nodes[i].alias, ea))
    return JoinSpec(name, nodes)


def materialize_residual(cat: Catalog, relations: Sequence[Relation],
                         edges: Sequence[Tuple[str, str, Sequence[str]]],
                         name: str) -> Relation:
    """Join the residual set S_R into a single relation (paper §8.2)."""
    by_name = {r.name: r for r in relations}
    first = relations[0]
    inter: Dict[str, np.ndarray] = {a: c for a, c in first.columns.items()}
    done = {first.name}
    pending = list(edges)
    while pending:
        progressed = False
        for e in list(pending):
            a_name, b_name, attrs = e
            nxt = None
            if a_name in done and b_name not in done:
                nxt = by_name[b_name]
            elif b_name in done and a_name not in done:
                nxt = by_name[a_name]
            elif a_name in done and b_name in done:
                pending.remove(e)
                progressed = True
                continue
            if nxt is None:
                continue
            inter = _expand(cat, inter, nxt, tuple(attrs))
            done.add(nxt.name)
            pending.remove(e)
            progressed = True
        if not progressed:
            raise ValueError("residual edges do not connect the residual relations")
    return Relation(name, inter)


# ---------------------------------------------------------------------------
# FULLJOIN baseline
# ---------------------------------------------------------------------------


def _expand(cat: Catalog, inter: Dict[str, np.ndarray], child: Relation,
            edge_attrs: Tuple[str, ...]) -> Dict[str, np.ndarray]:
    """inter ⋈ child on edge_attrs, vectorised via the child's sorted index."""
    idx = cat.index(child, list(edge_attrs))
    n = next(iter(inter.values())).shape[0] if inter else 0
    key = combine_columns([inter[a] for a in edge_attrs])
    lo, hi = idx.ranges(key)
    counts = hi - lo
    total = int(counts.sum())
    rep = np.repeat(np.arange(n), counts)
    starts = np.zeros(n, dtype=np.int64)
    np.cumsum(counts[:-1], out=starts[1:]) if n > 1 else None
    within = np.arange(total, dtype=np.int64) - np.repeat(starts, counts)
    pos = lo[rep] + within
    child_rows = idx.row_ids_at(pos)
    out = {a: c[rep] for a, c in inter.items()}
    for a in child.attrs:
        if a not in out:
            out[a] = child.columns[a][child_rows]
    return out


def full_join(cat: Catalog, spec: JoinSpec) -> Dict[str, np.ndarray]:
    """Materialise the join result (the expensive FULLJOIN baseline).

    ``reject_preds`` (if any) are applied to the output — the filtered join
    is the member of the union, so exact baselines must count it.
    """
    order = spec.expansion_order()
    root = order[0]
    inter: Dict[str, np.ndarray] = {a: c.copy() for a, c in root.relation.columns.items()}
    for n in order[1:]:
        inter = _expand(cat, inter, n.relation, n.edge_attrs)
    if spec.reject_preds:
        n_rows = next(iter(inter.values())).shape[0] if inter else 0
        keep = np.ones(n_rows, dtype=bool)
        for p in spec.reject_preds:
            keep &= p.mask(inter)
        inter = {a: c[keep] for a, c in inter.items()}
    return inter


def full_join_matrix(cat: Catalog, spec: JoinSpec,
                     attrs: Optional[Sequence[str]] = None) -> np.ndarray:
    """(n, k) value matrix of the full join over ``attrs`` (default: output schema)."""
    res = full_join(cat, spec)
    attrs = list(attrs) if attrs is not None else spec.output_attrs
    n = next(iter(res.values())).shape[0] if res else 0
    if n == 0:
        return np.zeros((0, len(attrs)), dtype=np.int64)
    return np.stack([res[a] for a in attrs], axis=1)


def join_size(cat: Catalog, spec: JoinSpec) -> int:
    """|J| without materialising attribute payloads (counts only)."""
    if spec.reject_preds:
        # predicate columns must be materialised to count survivors
        res = full_join(cat, spec)
        return int(next(iter(res.values())).shape[0]) if res else 0
    order = spec.expansion_order()
    root = order[0]
    inter: Dict[str, np.ndarray] = {a: c for a, c in root.relation.columns.items()}
    count_weight = np.ones(root.relation.nrows, dtype=np.int64)
    # expansion keeping only attrs still needed as edge keys downstream
    needed: set = set()
    for n in order[1:]:
        needed.update(n.edge_attrs)
    for i, n in enumerate(order[1:], start=1):
        idx = cat.index(n.relation, list(n.edge_attrs))
        key = combine_columns([inter[a] for a in n.edge_attrs])
        lo, hi = idx.ranges(key)
        counts = hi - lo
        keep = counts > 0
        # degrees multiply; but downstream edges may key on this child's attrs,
        # so we must expand when the child introduces needed attrs.
        later_needed = set()
        for m in order[i + 1:]:
            later_needed.update(m.edge_attrs)
        new_attrs = [a for a in n.relation.attrs if a not in inter]
        if any(a in later_needed for a in new_attrs):
            inter2 = _expand(cat, {a: c for a, c in inter.items()}, n.relation, n.edge_attrs)
            # recompute weight: expansion already multiplies rows
            count_weight = np.repeat(count_weight, counts)
            inter = inter2
        else:
            count_weight = count_weight[keep] * counts[keep]
            inter = {a: c[keep] for a, c in inter.items()}
    return int(count_weight.sum())
