"""Tiled sorted-probe (searchsorted) Pallas TPU kernels.

Every probe / degree / membership / EW-aggregation primitive in the sampler
reduces to ``lo = #keys < q`` / ``hi = #keys <= q`` against a sorted key
column.  TPUs have no efficient per-lane gather, so the paper's hash-probe
becomes a **two-phase dense-compare search** (DESIGN.md §2/§6):

* **Phase A — fence sweep** (`fence_count_kernel`): the fence array
  (every 128th sorted key) is VMEM-resident; each query tile counts
  ``#fences < q`` and ``#fences <= q`` by chunked broadcast-compare on the
  VPU (branchless, gather-free).  This pins each boundary to one 128-key
  block: for ``blk_l = #fences<q - 1``, every key in an earlier block is
  ``<= fences[blk_l] < q`` and every key in a later block is
  ``>= fences[blk_l+1] >= q`` — including runs of equal keys that straddle
  block boundaries.
* **XLA row-gather**: the per-query 128-key refinement rows are gathered by
  XLA (`keys2d[block_id]`) — irregular data movement is XLA's job on TPU;
  dense compute is Pallas's.
* **Phase B — refine** (`refine_kernel`): one dense ``(TQ, 128)`` compare per
  tile finishes the exact position.

int64 keys are carried as (hi32, biased-lo32) pairs with lexicographic
compares (TPU vector ALUs are 32-bit; the split happens host-side in numpy so
the jitted graph is pure int32).  Padding uses +inf sentinels (INT32_MAX
pairs), which never count as ``< q`` or ``<= q`` for real queries.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

KEY_BLOCK = 128          # keys per refinement block (fence stride)
QUERY_TILE = 256         # queries per grid step
FENCE_CHUNK = 128        # fences compared per inner iteration

_I64_MAX = np.iinfo(np.int64).max


def split64_np(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """int64 -> (hi32, biased lo32); lexicographic (hi, lo) preserves order."""
    x = np.asarray(x, dtype=np.int64)
    hi = (x >> 64 - 32).astype(np.int32)
    lo = ((x & 0xFFFFFFFF).astype(np.uint32) ^ np.uint32(0x80000000)).view(np.int32)
    return hi, lo


def _pad_np(x: np.ndarray, m: int, fill: int) -> np.ndarray:
    pad = (-x.shape[0]) % m
    if pad == 0:
        return x
    return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])


def _lt(a_hi, a_lo, b_hi, b_lo):
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo < b_lo))


def _le(a_hi, a_lo, b_hi, b_lo):
    return (a_hi < b_hi) | ((a_hi == b_hi) & (a_lo <= b_lo))


# ---------------------------------------------------------------------------
# Phase A: fence sweep
# ---------------------------------------------------------------------------


def fence_count_kernel(q_hi_ref, q_lo_ref, f_hi_ref, f_lo_ref,
                       blk_l_ref, blk_r_ref, *, n_chunks: int,
                       n_fences: int):
    """Per query: block ids of the lo/hi boundaries (broadcast-compare sweep)."""
    q_hi = q_hi_ref[0, :]                     # (TQ,)
    q_lo = q_lo_ref[0, :]
    tq = q_hi.shape[0]
    acc_l = jnp.zeros((tq,), jnp.int32)
    acc_r = jnp.zeros((tq,), jnp.int32)

    def body(c, carry):
        acc_l, acc_r = carry
        f_hi = f_hi_ref[c, :]                 # (FENCE_CHUNK,)
        f_lo = f_lo_ref[c, :]
        # mask fence padding (chunk grid may overrun n_fences)
        lane = jax.lax.broadcasted_iota(jnp.int32, (1, FENCE_CHUNK), 1)[0]
        valid = (c * FENCE_CHUNK + lane) < n_fences
        lt = _lt(f_hi[None, :], f_lo[None, :], q_hi[:, None], q_lo[:, None]) & valid[None, :]
        le = _le(f_hi[None, :], f_lo[None, :], q_hi[:, None], q_lo[:, None]) & valid[None, :]
        return (acc_l + jnp.sum(lt.astype(jnp.int32), axis=1),
                acc_r + jnp.sum(le.astype(jnp.int32), axis=1))

    acc_l, acc_r = jax.lax.fori_loop(0, n_chunks, body, (acc_l, acc_r))
    blk_l_ref[0, :] = jnp.clip(acc_l - 1, 0, None)
    blk_r_ref[0, :] = jnp.clip(acc_r - 1, 0, None)


# ---------------------------------------------------------------------------
# Phase B: refine within the gathered 128-key rows
# ---------------------------------------------------------------------------


def refine_kernel(q_hi_ref, q_lo_ref, blk_l_ref, blk_r_ref,
                  row_l_hi_ref, row_l_lo_ref, row_r_hi_ref, row_r_lo_ref,
                  lo_ref, hi_ref):
    q_hi = q_hi_ref[0, :][:, None]            # (TQ, 1)
    q_lo = q_lo_ref[0, :][:, None]
    lt = _lt(row_l_hi_ref[0], row_l_lo_ref[0], q_hi, q_lo)
    le = _le(row_r_hi_ref[0], row_r_lo_ref[0], q_hi, q_lo)
    lo_ref[0, :] = blk_l_ref[0, :] * KEY_BLOCK + jnp.sum(lt.astype(jnp.int32), axis=1)
    hi_ref[0, :] = blk_r_ref[0, :] * KEY_BLOCK + jnp.sum(le.astype(jnp.int32), axis=1)


# ---------------------------------------------------------------------------
# Jitted int32 pipeline + host prep
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("n_chunks", "n_fences", "interpret"))
def _searchsorted_i32(q_hi2, q_lo2, f_hi2, f_lo2, keys2d_hi, keys2d_lo,
                      n_chunks: int, n_fences: int, interpret: bool = True):
    qt = q_hi2.shape[0]
    tile_specs = [pl.BlockSpec((1, QUERY_TILE), lambda i: (i, 0))] * 2
    blk_l, blk_r = pl.pallas_call(
        functools.partial(fence_count_kernel, n_chunks=n_chunks,
                          n_fences=n_fences),
        grid=(qt,),
        in_specs=tile_specs + [
            pl.BlockSpec((n_chunks, FENCE_CHUNK), lambda i: (0, 0)),
            pl.BlockSpec((n_chunks, FENCE_CHUNK), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((1, QUERY_TILE), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((qt, QUERY_TILE), jnp.int32)] * 2,
        interpret=interpret,
    )(q_hi2, q_lo2, f_hi2, f_lo2)

    # XLA row-gather of refinement blocks
    bl = blk_l.reshape(-1)
    br = blk_r.reshape(-1)
    row_l_hi = keys2d_hi[bl].reshape(qt, QUERY_TILE, KEY_BLOCK)
    row_l_lo = keys2d_lo[bl].reshape(qt, QUERY_TILE, KEY_BLOCK)
    row_r_hi = keys2d_hi[br].reshape(qt, QUERY_TILE, KEY_BLOCK)
    row_r_lo = keys2d_lo[br].reshape(qt, QUERY_TILE, KEY_BLOCK)

    lo, hi = pl.pallas_call(
        refine_kernel,
        grid=(qt,),
        in_specs=tile_specs * 2 + [
            pl.BlockSpec((1, QUERY_TILE, KEY_BLOCK), lambda i: (i, 0, 0))] * 4,
        out_specs=[pl.BlockSpec((1, QUERY_TILE), lambda i: (i, 0))] * 2,
        out_shape=[jax.ShapeDtypeStruct((qt, QUERY_TILE), jnp.int32)] * 2,
        interpret=interpret,
    )(q_hi2, q_lo2, blk_l, blk_r, row_l_hi, row_l_lo, row_r_hi, row_r_lo)
    return lo, hi


class PreparedKeys:
    """Host-side preparation of a sorted key column for the kernel path."""

    def __init__(self, keys: np.ndarray):
        keys = np.asarray(keys, dtype=np.int64)
        self.n = keys.shape[0]
        kp = _pad_np(keys, KEY_BLOCK, _I64_MAX)
        self.n_blocks = kp.shape[0] // KEY_BLOCK
        k_hi, k_lo = split64_np(kp)
        self.keys2d_hi = jnp.asarray(k_hi.reshape(self.n_blocks, KEY_BLOCK))
        self.keys2d_lo = jnp.asarray(k_lo.reshape(self.n_blocks, KEY_BLOCK))
        fences = _pad_np(kp[::KEY_BLOCK], FENCE_CHUNK, _I64_MAX)
        f_hi, f_lo = split64_np(fences)
        self.n_chunks = f_hi.shape[0] // FENCE_CHUNK
        self.f_hi2 = jnp.asarray(f_hi.reshape(self.n_chunks, FENCE_CHUNK))
        self.f_lo2 = jnp.asarray(f_lo.reshape(self.n_chunks, FENCE_CHUNK))


def searchsorted_pallas(keys, queries, interpret: bool = True
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """(lo, hi) = (#keys < q, #keys <= q) per query. keys must be sorted."""
    prep = keys if isinstance(keys, PreparedKeys) else PreparedKeys(keys)
    q = np.asarray(queries, dtype=np.int64)
    nq = q.shape[0]
    qp = _pad_np(q, QUERY_TILE, 0)
    q_hi, q_lo = split64_np(qp)
    qt = qp.shape[0] // QUERY_TILE
    lo, hi = _searchsorted_i32(
        jnp.asarray(q_hi.reshape(qt, QUERY_TILE)),
        jnp.asarray(q_lo.reshape(qt, QUERY_TILE)),
        prep.f_hi2, prep.f_lo2, prep.keys2d_hi, prep.keys2d_lo,
        n_chunks=prep.n_chunks, n_fences=prep.n_blocks, interpret=interpret)
    lo = np.minimum(np.asarray(lo).reshape(-1)[:nq], prep.n)
    hi = np.minimum(np.asarray(hi).reshape(-1)[:nq], prep.n)
    return lo, hi
