"""Fused GQA decode attention (flash-decoding) Pallas TPU kernel.

Model-side hot spot for the serving cells (beyond the paper's scope, see
DESIGN.md §6): one new query token against a long KV cache, with

* grouped KV heads (G = n_q_heads / n_kv_heads queries share one KV head),
* optional logit soft-capping (gemma-2: ``cap * tanh(logits / cap)``),
* optional sliding-window masking (gemma-2 local layers),
* online-softmax accumulation over KV blocks (scratch carries m/l/acc).

Grid = (batch, kv_head, kv_blocks); the KV-block axis is the sequential inner
axis so the VMEM scratch accumulator is valid across steps.  Tiling:
q tile (G, D) and KV blocks (BS, D) are MXU-shaped (D=head_dim is 128-aligned
for all assigned archs; BS=128 rows).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

KV_BLOCK = 128
NEG_INF = -1e30


def decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *,
                       scale: float, softcap: float, window: int,
                       n_blocks: int):
    sblk = pl.program_id(2)

    @pl.when(sblk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)      # (BS, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)      # (BS, D)
    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)

    length = len_ref[0, 0]
    spos = sblk * k.shape[0] + jax.lax.broadcasted_iota(
        jnp.int32, (1, k.shape[0]), 1)[0]
    mask = spos < length
    if window > 0:
        mask &= spos >= (length - window)
    logits = jnp.where(mask[None, :], logits, NEG_INF)

    m_old = m_ref[...]                              # (G, 1)
    m_new = jnp.maximum(m_old, jnp.max(logits, axis=1, keepdims=True))
    alpha = jnp.exp(m_old - m_new)
    p = jnp.exp(logits - m_new)
    p = jnp.where(mask[None, :], p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(sblk == n_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "window",
                                             "interpret"))
def _decode_attn(q4, k4, v4, lengths, scale: float, softcap: float,
                 window: int, interpret: bool = True):
    B, KVH, G, D = q4.shape
    S = k4.shape[1]
    n_blocks = S // KV_BLOCK
    out = pl.pallas_call(
        functools.partial(decode_attn_kernel, scale=scale, softcap=softcap,
                          window=window, n_blocks=n_blocks),
        grid=(B, KVH, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),                 # lengths
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),     # q
            pl.BlockSpec((1, KV_BLOCK, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, KV_BLOCK, 1, D), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, D), q4.dtype),
        scratch_shapes=[pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, D), jnp.float32)],
        interpret=interpret,
    )(lengths, q4, k4, v4)
    return out


def decode_attention_pallas(q, k, v, lengths, scale: Optional[float] = None,
                            softcap: float = 0.0, window: int = 0,
                            interpret: bool = True):
    """q (B,H,D), k/v (B,S,KVH,D), lengths (B,) -> (B,H,D)."""
    q = jnp.asarray(q)
    k = jnp.asarray(k)
    v = jnp.asarray(v)
    B, H, D = q.shape
    S, KVH = k.shape[1], k.shape[2]
    if S % KV_BLOCK:
        pad = KV_BLOCK - S % KV_BLOCK
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    G = H // KVH
    q4 = q.reshape(B, KVH, G, D)
    lengths2 = jnp.asarray(lengths, jnp.int32).reshape(B, 1)
    if scale is None:
        scale = 1.0 / float(np.sqrt(D))
    out = _decode_attn(q4, k, v, lengths2, scale=float(scale),
                       softcap=float(softcap), window=int(window),
                       interpret=interpret)
    return out.reshape(B, H, D)
