"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; TPU v5e is
the compile target).  On a real TPU backend the same calls lower via Mosaic.

``ranged_weighted_pick`` — the Exact-Weight child-pick primitive — composes
the searchsorted kernel over the *bit-cast* prefix-sum array: non-negative
float32 IEEE bit patterns are order-isomorphic to their int32 views, so the
lexicographic integer compare machinery applies unchanged (hi word = 0).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from .attention import decode_attention_pallas
from .searchsorted import PreparedKeys, searchsorted_pallas
from .segdegree import segdegree_pallas
from .walk import walk_hop_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


def searchsorted(keys, queries) -> Tuple[np.ndarray, np.ndarray]:
    return searchsorted_pallas(keys, queries, interpret=default_interpret())


def walk_hop(keys, queries, u) -> Tuple[np.ndarray, np.ndarray]:
    return walk_hop_pallas(keys, queries, u, interpret=default_interpret())


def segdegree(sorted_keys) -> Tuple[int, int]:
    return segdegree_pallas(sorted_keys, interpret=default_interpret())


def decode_attention(q, k, v, lengths, scale: Optional[float] = None,
                     softcap: float = 0.0, window: int = 0):
    return decode_attention_pallas(q, k, v, lengths, scale=scale,
                                   softcap=softcap, window=window,
                                   interpret=default_interpret())


def ranged_weighted_pick(cs: np.ndarray, lo: np.ndarray, hi: np.ndarray,
                         u: np.ndarray) -> np.ndarray:
    """EW pick: position in [lo,hi) with prob ∝ weight, via prefix sums cs.

    cs must be non-negative float32-representable prefix sums (len n+1).
    """
    cs32 = np.asarray(cs, dtype=np.float32)
    tot = cs32[hi] - cs32[lo]
    tgt = (cs32[lo] + np.asarray(u, np.float32) * np.maximum(tot, 1e-30))
    # order-isomorphic bit-cast: non-negative float32 -> int32
    cs_bits = cs32.view(np.int32).astype(np.int64)
    tgt_bits = np.minimum(tgt, np.nextafter(cs32[-1], -np.inf)).astype(np.float32)
    tgt_bits = tgt_bits.view(np.int32).astype(np.int64)
    _, le_count = searchsorted(cs_bits, tgt_bits)
    pos = le_count - 1
    return np.clip(pos, lo, np.maximum(hi - 1, lo))
