"""Pallas TPU kernels for the sampler's hot spots (+ serving attention).

searchsorted — two-phase tiled sorted probe (fence sweep + refine)
walk         — fused wander-join hop (refine + ranged uniform pick)
segdegree    — single-pass distinct/max-degree over sorted keys
attention    — flash-decoding GQA w/ softcap + sliding window (model-side)
ops          — public jit'd wrappers (interpret=True off-TPU)
ref          — pure jnp/numpy oracles
"""

from . import ops, ref

__all__ = ["ops", "ref"]
