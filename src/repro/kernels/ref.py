"""Pure-jnp / numpy oracles for every Pallas kernel (allclose targets)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np


def searchsorted_ref(keys: np.ndarray, queries: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
    lo = np.searchsorted(keys, queries, side="left")
    hi = np.searchsorted(keys, queries, side="right")
    return lo.astype(np.int64), hi.astype(np.int64)


def walk_hop_ref(keys: np.ndarray, queries: np.ndarray, u: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
    lo, hi = searchsorted_ref(keys, queries)
    d = hi - lo
    off = np.minimum(np.floor(u * np.maximum(d, 1)).astype(np.int64),
                     np.maximum(d - 1, 0))
    return lo + off, d


def segdegree_ref(sorted_keys: np.ndarray) -> Tuple[int, int]:
    if sorted_keys.shape[0] == 0:
        return 0, 0
    _, counts = np.unique(sorted_keys, return_counts=True)
    return int(counts.shape[0]), int(counts.max())


def ranged_weighted_pick_ref(cs: np.ndarray, lo: np.ndarray, hi: np.ndarray,
                             u: np.ndarray) -> np.ndarray:
    """Weighted pick inside [lo, hi) via prefix sums cs (len n+1)."""
    tot = cs[hi] - cs[lo]
    tgt = cs[lo] + u * np.maximum(tot, 1e-300)
    pos = np.searchsorted(cs, tgt, side="right") - 1
    return np.clip(pos, lo, np.maximum(hi - 1, lo))


def decode_attention_ref(q, k, v, lengths, scale: Optional[float] = None,
                         softcap: float = 0.0, window: int = 0) -> jnp.ndarray:
    """q (B,H,D), k/v (B,S,KVH,D), lengths (B,) -> (B,H,D). fp32 math."""
    q = jnp.asarray(q, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    B, H, D = q.shape
    S, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    if scale is None:
        scale = 1.0 / float(np.sqrt(D))
    qg = q.reshape(B, KVH, G, D)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    spos = jnp.arange(S)[None, :]
    lens = jnp.asarray(lengths, jnp.int32)[:, None]
    mask = spos < lens
    if window > 0:
        mask &= spos >= (lens - window)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v)
    return out.reshape(B, H, D)
