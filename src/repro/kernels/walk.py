"""Fused wander-join hop kernel.

One random-walk hop for B walks advances each walk's frontier key through the
next relation's sorted index: ``[lo, hi) = range of matches``, then a ranged
uniform pick ``pos = lo + floor(u * d)``.  This kernel fuses the phase-B
refinement of :mod:`searchsorted` with the pick + probability update, so a hop
is: fence sweep (phase A) → XLA row gather → **fused refine+pick** → XLA
neighbor gather.  Dead walks (``d == 0``) are masked, matching the paper's
"failed random walk, p(t) = 0" semantics.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .searchsorted import (KEY_BLOCK, QUERY_TILE, PreparedKeys, _le, _lt,
                           _pad_np, fence_count_kernel, split64_np)


def hop_refine_pick_kernel(q_hi_ref, q_lo_ref, blk_l_ref, blk_r_ref,
                           row_l_hi_ref, row_l_lo_ref,
                           row_r_hi_ref, row_r_lo_ref,
                           u_ref, pos_ref, deg_ref):
    """Fused: exact [lo,hi) + ranged uniform pick + degree output."""
    q_hi = q_hi_ref[0, :][:, None]
    q_lo = q_lo_ref[0, :][:, None]
    lt = _lt(row_l_hi_ref[0], row_l_lo_ref[0], q_hi, q_lo)
    le = _le(row_r_hi_ref[0], row_r_lo_ref[0], q_hi, q_lo)
    lo = blk_l_ref[0, :] * KEY_BLOCK + jnp.sum(lt.astype(jnp.int32), axis=1)
    hi = blk_r_ref[0, :] * KEY_BLOCK + jnp.sum(le.astype(jnp.int32), axis=1)
    d = hi - lo
    u = u_ref[0, :]
    off = jnp.floor(u * jnp.maximum(d, 1).astype(jnp.float32)).astype(jnp.int32)
    off = jnp.minimum(off, jnp.maximum(d - 1, 0))
    pos_ref[0, :] = lo + off
    deg_ref[0, :] = d


@functools.partial(jax.jit,
                   static_argnames=("n_chunks", "n_fences", "interpret"))
def _hop_i32(q_hi2, q_lo2, u2, f_hi2, f_lo2, keys2d_hi, keys2d_lo,
             n_chunks: int, n_fences: int, interpret: bool = True):
    qt = q_hi2.shape[0]
    tile = pl.BlockSpec((1, QUERY_TILE), lambda i: (i, 0))
    blk_l, blk_r = pl.pallas_call(
        functools.partial(fence_count_kernel, n_chunks=n_chunks,
                          n_fences=n_fences),
        grid=(qt,),
        in_specs=[tile, tile,
                  pl.BlockSpec((n_chunks, 128), lambda i: (0, 0)),
                  pl.BlockSpec((n_chunks, 128), lambda i: (0, 0))],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((qt, QUERY_TILE), jnp.int32)] * 2,
        interpret=interpret,
    )(q_hi2, q_lo2, f_hi2, f_lo2)

    bl, br = blk_l.reshape(-1), blk_r.reshape(-1)
    rl_hi = keys2d_hi[bl].reshape(qt, QUERY_TILE, KEY_BLOCK)
    rl_lo = keys2d_lo[bl].reshape(qt, QUERY_TILE, KEY_BLOCK)
    rr_hi = keys2d_hi[br].reshape(qt, QUERY_TILE, KEY_BLOCK)
    rr_lo = keys2d_lo[br].reshape(qt, QUERY_TILE, KEY_BLOCK)

    row = pl.BlockSpec((1, QUERY_TILE, KEY_BLOCK), lambda i: (i, 0, 0))
    pos, deg = pl.pallas_call(
        hop_refine_pick_kernel,
        grid=(qt,),
        in_specs=[tile, tile, tile, tile, row, row, row, row, tile],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((qt, QUERY_TILE), jnp.int32)] * 2,
        interpret=interpret,
    )(q_hi2, q_lo2, blk_l, blk_r, rl_hi, rl_lo, rr_hi, rr_lo, u2)
    return pos, deg


def walk_hop_pallas(keys, queries, u, interpret: bool = True
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """One hop: (pos, degree) per walk. keys sorted; u uniform [0,1)."""
    prep = keys if isinstance(keys, PreparedKeys) else PreparedKeys(keys)
    q = np.asarray(queries, dtype=np.int64)
    nq = q.shape[0]
    qp = _pad_np(q, QUERY_TILE, 0)
    up = _pad_np(np.asarray(u, dtype=np.float32), QUERY_TILE, 0)
    q_hi, q_lo = split64_np(qp)
    qt = qp.shape[0] // QUERY_TILE
    pos, deg = _hop_i32(
        jnp.asarray(q_hi.reshape(qt, QUERY_TILE)),
        jnp.asarray(q_lo.reshape(qt, QUERY_TILE)),
        jnp.asarray(up.reshape(qt, QUERY_TILE)),
        prep.f_hi2, prep.f_lo2, prep.keys2d_hi, prep.keys2d_lo,
        n_chunks=prep.n_chunks, n_fences=prep.n_blocks, interpret=interpret)
    pos = np.minimum(np.asarray(pos).reshape(-1)[:nq], max(prep.n - 1, 0))
    deg = np.asarray(deg).reshape(-1)[:nq]
    return pos, deg
