"""Segment-degree statistics kernel over a sorted key column.

Computes ``(distinct_count, max_degree)`` in one pass — the statistics the
catalog feeds to Theorem 4 (HISTOGRAM-BASED) and to the extended-Olken
accept/reject ratios.  Grid iterates key blocks sequentially (TPU grids are
sequential per core); run state is carried across blocks in SMEM scratch:

    carry = (last key of previous block, length of its trailing run,
             running max degree, running distinct count)

Within a block, run lengths come from a branchless ``cummax`` over new-run
positions (VPU-dense, no gather).  Padding keys (+inf sentinels) are masked
by the global index.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .searchsorted import KEY_BLOCK, _pad_np, split64_np


def segdegree_kernel(k_hi_ref, k_lo_ref, out_ref, carry_ref, *, n: int):
    b = pl.program_id(0)
    k_hi = k_hi_ref[0, :]
    k_lo = k_lo_ref[0, :]
    width = k_hi.shape[0]
    gidx = b * width + jax.lax.broadcasted_iota(jnp.int32, (1, width), 1)[0]
    valid = gidx < n

    @pl.when(b == 0)
    def _init():
        carry_ref[0] = jnp.int32(0)   # prev key hi (unused at start)
        carry_ref[1] = jnp.int32(0)   # prev key lo
        carry_ref[2] = jnp.int32(0)   # trailing run length
        carry_ref[3] = jnp.int32(0)   # max degree
        carry_ref[4] = jnp.int32(0)   # distinct count
        carry_ref[5] = jnp.int32(0)   # have_prev flag

    prev_hi, prev_lo = carry_ref[0], carry_ref[1]
    run_in, max_in, distinct_in, have_prev = (carry_ref[2], carry_ref[3],
                                              carry_ref[4], carry_ref[5])

    shift_hi = jnp.concatenate([jnp.full((1,), prev_hi, jnp.int32), k_hi[:-1]])
    shift_lo = jnp.concatenate([jnp.full((1,), prev_lo, jnp.int32), k_lo[:-1]])
    same = (k_hi == shift_hi) & (k_lo == shift_lo)
    first_pos = jnp.arange(width, dtype=jnp.int32) == 0
    # position 0 of block 0 always starts a run (no previous key)
    same = jnp.where(first_pos & (have_prev == 0) & (b == 0), False, same)
    new_run = (~same) & valid

    idx = jnp.arange(width, dtype=jnp.int32)
    start = jax.lax.cummax(jnp.where(new_run, idx, -1))
    # run length at position i (runs starting before the block add carry)
    length = jnp.where(start >= 0, idx - start + 1, idx + 1 + run_in)
    length = jnp.where(valid, length, 0)

    n_valid = jnp.sum(valid.astype(jnp.int32))
    block_distinct = jnp.sum(new_run.astype(jnp.int32))
    block_max = jnp.max(length, initial=0)

    # trailing run length = length at last valid position (0 if none valid)
    last_valid = jnp.max(jnp.where(valid, idx, -1), initial=-1)
    trailing = jnp.sum(jnp.where(idx == last_valid, length, 0))
    trailing = jnp.where(n_valid > 0, trailing, run_in)
    new_prev_hi = jnp.sum(jnp.where(idx == last_valid, k_hi, 0))
    new_prev_lo = jnp.sum(jnp.where(idx == last_valid, k_lo, 0))

    carry_ref[0] = jnp.where(n_valid > 0, new_prev_hi, prev_hi)
    carry_ref[1] = jnp.where(n_valid > 0, new_prev_lo, prev_lo)
    carry_ref[2] = trailing
    carry_ref[3] = jnp.maximum(max_in, block_max)
    carry_ref[4] = distinct_in + block_distinct
    carry_ref[5] = jnp.maximum(have_prev, (n_valid > 0).astype(jnp.int32))

    out_ref[0, 0] = carry_ref[4]
    out_ref[0, 1] = carry_ref[3]


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def _segdegree_i32(k_hi2, k_lo2, n: int, interpret: bool = True):
    nb = k_hi2.shape[0]
    out = pl.pallas_call(
        functools.partial(segdegree_kernel, n=n),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, KEY_BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((1, KEY_BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 2), jnp.int32),
        scratch_shapes=[pltpu.SMEM((8,), jnp.int32)],
        interpret=interpret,
    )(k_hi2, k_lo2)
    return out


def segdegree_pallas(sorted_keys, interpret: bool = True) -> Tuple[int, int]:
    """(distinct_count, max_degree) of a sorted key column."""
    keys = np.asarray(sorted_keys, dtype=np.int64)
    n = keys.shape[0]
    if n == 0:
        return 0, 0
    kp = _pad_np(keys, KEY_BLOCK, np.iinfo(np.int64).max)
    k_hi, k_lo = split64_np(kp)
    nb = kp.shape[0] // KEY_BLOCK
    out = _segdegree_i32(jnp.asarray(k_hi.reshape(nb, KEY_BLOCK)),
                         jnp.asarray(k_lo.reshape(nb, KEY_BLOCK)),
                         n=n, interpret=interpret)
    out = np.asarray(out)
    return int(out[0, 0]), int(out[0, 1])
