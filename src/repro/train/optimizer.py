"""Optimizers: AdamW and Adafactor (factored second moment).

AdamW keeps fp32 m/v (optionally bf16 m to cut optimizer HBM).  Adafactor
factorises the second moment of every >=2-D parameter into row/col statistics
(Shazeer & Stern, arXiv:1804.04235) — the default for the giant archs
(arctic-480b, mistral-large-123b) so optimizer state fits 16 GB/chip at 256
chips (DESIGN.md §5 napkin math).

All state is a pytree mirroring the params tree, so the FSDP shardings apply
to optimizer state unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"                 # "adamw" | "adafactor"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    m_dtype: str = "float32"            # "bfloat16" halves first-moment HBM
    min_dim_factored: int = 2           # adafactor: factor dims >= 2


def default_opt_for(model_name: str) -> OptConfig:
    if any(t in model_name for t in ("arctic", "mistral-large")):
        return OptConfig(kind="adafactor")
    return OptConfig()


def opt_state_entries(opt: OptConfig, shapes: Dict[str, Tuple[int, ...]]
                      ) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    """name -> (shape, role) for optimizer slots; role keys sharding reuse."""
    out: Dict[str, Tuple[Tuple[int, ...], str]] = {}
    for k, shp in shapes.items():
        if opt.kind == "adamw":
            out[f"m.{k}"] = (shp, k)
            out[f"v.{k}"] = (shp, k)
        else:
            out[f"m.{k}"] = (shp, k)
            if len(shp) >= opt.min_dim_factored:
                out[f"vr.{k}"] = (shp[:-1], k)          # row stats
                out[f"vc.{k}"] = (shp[:-2] + shp[-1:], k)  # col stats
            else:
                out[f"v.{k}"] = (shp, k)
    return out


def init_opt_state(opt: OptConfig, params: Dict[str, jnp.ndarray]
                   ) -> Dict[str, jnp.ndarray]:
    m_dt = jnp.bfloat16 if opt.m_dtype == "bfloat16" else jnp.float32
    out = {}
    for k, (shp, _) in opt_state_entries(
            opt, {k: tuple(v.shape) for k, v in params.items()}).items():
        out[k] = jnp.zeros(shp, m_dt if k.startswith("m.") else jnp.float32)
    return out


def apply_update(opt: OptConfig, params: Dict[str, jnp.ndarray],
                 grads: Dict[str, jnp.ndarray],
                 state: Dict[str, jnp.ndarray], step: jnp.ndarray,
                 lr: Optional[jnp.ndarray] = None
                 ) -> Tuple[Dict[str, jnp.ndarray], Dict[str, jnp.ndarray]]:
    """One optimizer step.  ``lr`` (traced scalar) overrides ``opt.lr`` —
    Adam-family updates are invariant to gradient scaling, so schedules must
    scale the *update*, never the gradients."""
    eff_lr = opt.lr if lr is None else lr
    new_params, new_state = {}, {}
    t = step.astype(jnp.float32) + 1.0
    for k, p in params.items():
        g = grads[k].astype(jnp.float32)
        m = state[f"m.{k}"].astype(jnp.float32)
        m = opt.b1 * m + (1 - opt.b1) * g
        if opt.kind == "adamw":
            v = state[f"v.{k}"]
            v = opt.b2 * v + (1 - opt.b2) * g * g
            mhat = m / (1 - opt.b1 ** t)
            vhat = v / (1 - opt.b2 ** t)
            upd = mhat / (jnp.sqrt(vhat) + opt.eps)
            new_state[f"v.{k}"] = v
        else:
            if f"vr.{k}" in state:
                vr = state[f"vr.{k}"]
                vc = state[f"vc.{k}"]
                g2 = g * g + 1e-30
                vr = opt.b2 * vr + (1 - opt.b2) * g2.mean(axis=-1)
                vc = opt.b2 * vc + (1 - opt.b2) * g2.mean(axis=-2)
                # factored reconstruction: vr ⊗ vc / mean(vr)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30)
                vhat = (vr[..., :, None] * vc[..., None, :]) / denom[..., None]
                upd = m / (jnp.sqrt(vhat / (1 - opt.b2 ** t)) + opt.eps)
                new_state[f"vr.{k}"] = vr
                new_state[f"vc.{k}"] = vc
            else:
                v = state[f"v.{k}"]
                v = opt.b2 * v + (1 - opt.b2) * g * g
                upd = m / (jnp.sqrt(v / (1 - opt.b2 ** t)) + opt.eps)
                new_state[f"v.{k}"] = v
        if p.ndim >= 2:
            upd = upd + opt.weight_decay * p
        new_params[k] = (p - eff_lr * upd).astype(p.dtype)
        new_state[f"m.{k}"] = m.astype(state[f"m.{k}"].dtype)
    return new_params, new_state


def global_norm(tree: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(v.astype(jnp.float32)))
                        for v in tree.values()))


def clip_by_global_norm(grads: Dict[str, jnp.ndarray], max_norm: float
                        ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return {k: (v * scale).astype(v.dtype) for k, v in grads.items()}, gn
