"""int8 error-feedback gradient compression.

Two pieces:

* :func:`compress_decompress` — value-level quantize→dequantize with an
  error-feedback buffer (Seide et al. 1-bit SGD lineage): the quantisation
  residual is carried into the next step, so compression noise is unbiased
  over time.  This is what the train step applies; XLA still moves fp32 on
  the wire (documented in DESIGN §7 — value-level simulation).
* :func:`compressed_psum` — the *wire-level* building block: a shard_map
  collective that all-gathers int8(+per-shard scale) across an axis and
  de-quantises/sums locally — 4× fewer cross-pod bytes than a bf16
  all-reduce for small axis sizes (the 2-pod case).  Unit-tested standalone;
  wiring it under GSPMD's automatic reduce-scatter requires a custom
  partitioner, which is future work.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def _quant_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    return {k: jnp.zeros(v.shape, jnp.float32) for k, v in params.items()}


def compress_decompress(grads: Dict[str, jnp.ndarray], state: Dict[str, Any]
                        ) -> Tuple[Dict[str, jnp.ndarray], Dict[str, Any]]:
    ef = state.get("ef")
    if ef is None:
        ef = init_error_feedback(grads)
    out, new_ef = {}, {}
    for k, g in grads.items():
        g32 = g.astype(jnp.float32) + ef[k]
        q, s = _quant_int8(g32)
        deq = _dequant(q, s)
        out[k] = deq.astype(g.dtype)
        new_ef[k] = g32 - deq
    new_state = dict(state)
    new_state["ef"] = new_ef
    return out, new_state


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8 all-gather + local dequant-sum along a (small) mesh axis.

    Call inside shard_map.  Sends 1 byte/elem/peer instead of ~4 for a ring
    all-reduce — a win when the axis is small and slow (cross-pod DCN).
    """
    q, s = _quant_int8(x.astype(jnp.float32))
    qg = jax.lax.all_gather(q, axis_name)           # (world, ...)
    sg = jax.lax.all_gather(s, axis_name)           # (world,)
    world = qg.shape[0]
    deq = qg.astype(jnp.float32) * sg.reshape((world,) + (1,) * x.ndim)
    return deq.sum(axis=0)
