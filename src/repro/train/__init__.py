"""train subpackage."""
