"""Training step: loss → grads → clip → optimizer, with μ-batch accumulation.

* fp32 master params, bf16 compute (the model code casts at use sites).
* μ-batched gradient accumulation via ``lax.scan``: XLA's latency-hiding
  scheduler overlaps the reduce-scatter of one μ-batch's grads with the next
  μ-batch's compute (compute/comm overlap, DESIGN §5).
* optional value-level int8 error-feedback gradient compression
  (train/grad_compress.py) before the update.
* LR schedule: linear warmup → cosine.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.transformer import ModelConfig, forward_train
from .optimizer import (OptConfig, apply_update, clip_by_global_norm,
                        init_opt_state, opt_state_entries)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    max_grad_norm: float = 1.0
    n_microbatches: int = 1
    warmup_steps: int = 100
    total_steps: int = 10_000
    compress_grads: bool = False


def lr_at(tc: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    # warmup counts from 1 so the first step takes a real update
    warm = jnp.minimum((s + 1.0) / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip((s - tc.warmup_steps) /
                    jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0, 1)
    return warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def make_train_step(cfg: ModelConfig, tc: TrainConfig
                    ) -> Callable[[Dict[str, Any], Dict[str, jnp.ndarray]],
                                  Tuple[Dict[str, Any], Dict[str, jnp.ndarray]]]:
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"step": i32[], "params": {...}, "opt": {...}}
    batch: tokens/targets (B, S) [+ frontend]; B is the per-call global batch.
    """

    def loss_fn(params, batch):
        return forward_train(params, cfg, batch)

    def grads_of(params, batch):
        # bf16 backward: differentiate wrt bf16 parameter copies so every
        # cross-device gradient reduction (and the activation-gradient
        # traffic of the whole backward) moves bf16, not f32 — §Perf iter 2.
        # The f32 master copy is updated with the (f32-cast) result.
        p16 = {k: v.astype(cfg.compute_dtype) for k, v in params.items()}
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            p16, batch)
        # grads stay bf16 until apply_update's internal f32 cast, so the
        # per-layer reductions inside the scan transpose move bf16
        return loss, metrics, grads

    def train_step(state, batch):
        params = state["params"]
        if tc.n_microbatches > 1:
            n = tc.n_microbatches

            def micro(carry, mb):
                acc, loss_acc = carry
                loss, metrics, grads = grads_of(params, mb)
                acc = {k: acc[k] + grads[k].astype(jnp.float32) for k in acc}
                return (acc, loss_acc + loss), None

            mbs = {k: v.reshape((n, v.shape[0] // n) + v.shape[1:])
                   for k, v in batch.items()}
            zero = {k: jnp.zeros(v.shape, jnp.float32)
                    for k, v in params.items()}
            (gacc, loss_sum), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = {k: v / n for k, v in gacc.items()}
            loss = loss_sum / n
            metrics = {"loss": loss}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if tc.compress_grads:
            from .grad_compress import compress_decompress
            grads, state = compress_decompress(grads, state)

        grads, gnorm = clip_by_global_norm(grads, tc.max_grad_norm)
        lr = lr_at(tc, state["step"]) * tc.opt.lr
        new_params, new_opt = apply_update(tc.opt, params, grads, state["opt"],
                                           state["step"], lr=lr)
        new_state = dict(state)
        new_state.update(step=state["step"] + 1, params=new_params,
                         opt=new_opt)
        metrics = dict(metrics)
        metrics.update(grad_norm=gnorm, lr=lr)
        return new_state, metrics

    return train_step


def init_train_state(cfg: ModelConfig, tc: TrainConfig, seed: int = 0
                     ) -> Dict[str, Any]:
    from ..models.transformer import init_params
    params = init_params(cfg, seed)
    state = {"step": jnp.zeros((), jnp.int32), "params": params,
             "opt": init_opt_state(tc.opt, params)}
    if tc.compress_grads:
        from .grad_compress import init_error_feedback
        state["ef"] = init_error_feedback(params)
    return state


def train_state_specs(cfg: ModelConfig, tc: TrainConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    from ..models.transformer import param_specs
    pspecs = param_specs(cfg)
    m_dt = jnp.bfloat16 if tc.opt.m_dtype == "bfloat16" else jnp.float32
    opt = {k: jax.ShapeDtypeStruct(shp, m_dt if k.startswith("m.") else jnp.float32)
           for k, (shp, _) in opt_state_entries(
               tc.opt, {k: tuple(s.shape) for k, s in pspecs.items()}).items()}
    state = {"step": jax.ShapeDtypeStruct((), jnp.int32), "params": pspecs,
             "opt": opt}
    if tc.compress_grads:
        state["ef"] = {k: jax.ShapeDtypeStruct(s.shape, jnp.float32)
                       for k, s in pspecs.items()}
    return state


def train_state_logical_axes(cfg: ModelConfig, tc: TrainConfig):
    """Logical axes pytree matching train_state_specs (for sharding)."""
    from ..models.transformer import logical_axes, param_specs
    lax_ = logical_axes(cfg)
    pspecs = param_specs(cfg)
    opt_ax = {}
    for k, (shp, role) in opt_state_entries(
            tc.opt, {k: tuple(s.shape) for k, s in pspecs.items()}).items():
        base = lax_[role]
        if len(shp) == len(base):
            opt_ax[k] = base
        else:
            # factored adafactor slots: drop the reduced dim's logical name
            if k.startswith("vr."):
                opt_ax[k] = base[:-1]
            else:  # vc: all but second-to-last
                opt_ax[k] = base[:-2] + base[-1:]
    state = {"step": (), "params": lax_, "opt": opt_ax}
    if tc.compress_grads:
        state["ef"] = lax_
    return state
