"""Engine-wide telemetry (DESIGN.md §10).

Three pieces, importable with zero heavy dependencies (no jax here — the
engines import *us*):

* :mod:`repro.obs.metrics` — labeled counter/gauge/histogram registry with
  cheap thread-safe increments, ``snapshot()``, and Prometheus text
  exposition; global kill switch ``REPRO_OBS=off``.
* :mod:`repro.obs.tracing` — :class:`TraceRing`, the bounded event log
  behind the ONLINE-UNION φ-trajectory tracer.
* :mod:`repro.obs.http` — :class:`MetricsServer`, the background HTTP
  thread serving ``/metrics`` (Prometheus text) and ``/healthz``.

Instrumented layers: the persistent device loop carries per-piece round
counters in its jitted carry (``JaxUnionSampler.piece_stats``), the sharded
loop derives the same counters from its water-filling exchange, ONLINE-UNION
appends φ-refresh/backtrack events to its trace ring, and the serve tier
records request-latency histograms, queue depth, and per-replica merged
``SamplerStats``.  All of it is on by default and disabled end-to-end by
``REPRO_OBS=off`` (sampling output is bit-identical either way — the
switch only gates host-side timers and registry publication).
"""

from .http import MetricsServer, PROMETHEUS_CONTENT_TYPE
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      default_latency_buckets, enabled, get_registry,
                      set_enabled, set_registry, trace_annotations_enabled)
from .tracing import TraceRing

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "MetricsServer",
    "PROMETHEUS_CONTENT_TYPE", "TraceRing", "default_latency_buckets",
    "enabled", "get_registry", "set_enabled", "set_registry",
    "trace_annotations_enabled",
]
