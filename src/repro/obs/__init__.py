"""Engine-wide telemetry (DESIGN.md §10).

Three pieces, importable with zero heavy dependencies (no jax here — the
engines import *us*):

* :mod:`repro.obs.metrics` — labeled counter/gauge/histogram registry with
  cheap thread-safe increments, ``snapshot()``, and Prometheus text
  exposition; global kill switch ``REPRO_OBS=off``.
* :mod:`repro.obs.tracing` — :class:`TraceRing`, the bounded event log
  behind the ONLINE-UNION φ-trajectory tracer.
* :mod:`repro.obs.http` — :class:`MetricsServer`, the background HTTP
  thread serving ``/metrics`` (Prometheus text) and ``/healthz``.

Instrumented layers: the persistent device loop carries per-piece round
counters in its jitted carry (``JaxUnionSampler.piece_stats``), the sharded
loop derives the same counters from its water-filling exchange, ONLINE-UNION
appends φ-refresh/backtrack events to its trace ring, and the serve tier
records request-latency histograms, queue depth, and per-replica merged
``SamplerStats``.  All of it is on by default and disabled end-to-end by
``REPRO_OBS=off`` (sampling output is bit-identical either way — the
switch only gates host-side timers and registry publication).
"""

from .http import MetricsServer, PROMETHEUS_CONTENT_TYPE
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      default_latency_buckets, enabled, get_registry,
                      set_enabled, set_registry, trace_annotations_enabled)
from .tracing import TraceRing

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "MetricsServer",
    "PROMETHEUS_CONTENT_TYPE", "TraceRing", "default_latency_buckets",
    "enabled", "fallback_events", "get_registry", "record_fallback",
    "set_enabled", "set_registry", "trace_annotations_enabled",
]

# ---------------------------------------------------------------------------
# Engine fallback telemetry: every point where a device/fused path degrades
# to the host engine increments repro_engine_fallback_total{reason=...} and
# appends a TraceRing event — warnings are once-only and invisible to
# scrapes; this is the queryable record of "why was this run slow".
# ---------------------------------------------------------------------------

_fallback_trace = TraceRing(capacity=256)

_FALLBACK_HELP = ("Times a fused/device engine path degraded to the host "
                  "engine, by reason")


def record_fallback(reason: str, detail: str = "", join: str = "") -> None:
    """Record one engine degrade-to-host event.

    ``reason`` is the stable low-cardinality label (e.g.
    ``predicate_unsupported``, ``int32_domain``, ``join_method``,
    ``strict_paper_loop``, ``host_oracle``); ``detail``/``join`` carry the
    free-form context into the trace ring only.
    """
    if not enabled():
        return
    get_registry().counter("repro_engine_fallback_total", _FALLBACK_HELP,
                           ("reason",)).labels(reason=reason).inc()
    _fallback_trace.append("engine_fallback", reason=reason, detail=detail,
                           join=join)


def fallback_events():
    """The recent engine-fallback events (newest last)."""
    return _fallback_trace.events()
