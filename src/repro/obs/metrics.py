"""Metrics core: a labeled registry of counters / gauges / histograms.

The engine-wide telemetry substrate (DESIGN.md §10).  Design constraints,
in order:

1. **Cheap increments.**  Instrumentation sits on the serve request path and
   at the engine's once-per-``sample(n)`` host sync — an increment is one
   lock acquire plus a float add.  Anything heavier (rendering, quantile
   estimation, label resolution) happens at scrape/snapshot time.
2. **Thread-safe.**  The serve tier increments from producer threads and
   concurrent ``request()`` callers; every metric child guards its state
   with its own lock, and the registry guards its tables.
3. **Prometheus text exposition.**  :meth:`MetricsRegistry.render` emits the
   text format (version 0.0.4) that ``/metrics`` serves — counters with a
   ``_total`` convention left to the caller, histograms as cumulative
   ``_bucket{le=...}`` series plus ``_sum``/``_count``.

The global kill switch is the ``REPRO_OBS`` environment variable: set it to
``off`` (or ``0``/``false``/``no``) to disable instrumentation everywhere
(sites check :func:`enabled` before doing host-side work; the registry keeps
functioning so late scrapes never crash).  Tests and benchmarks toggle at
runtime with :func:`set_enabled`; ``set_enabled(None)`` re-reads the
environment.  ``REPRO_OBS_TRACE=1`` additionally turns on host-side
``jax.profiler`` trace annotations around engine dispatch (off by default —
they cost a little even without an active profiler trace).
"""

from __future__ import annotations

import bisect
import math
import os
import re
import threading
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "enabled", "set_enabled", "trace_annotations_enabled",
    "default_latency_buckets", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "get_registry", "set_registry",
]

_OFF_VALUES = ("off", "0", "false", "no")

_enabled_override: Optional[bool] = None
_enabled_lock = threading.Lock()


def _env_enabled() -> bool:
    return os.environ.get("REPRO_OBS", "on").strip().lower() not in _OFF_VALUES


def enabled() -> bool:
    """Is instrumentation on?  (``REPRO_OBS=off`` or ``set_enabled(False)``
    turns it off.)"""
    override = _enabled_override
    if override is not None:
        return override
    return _env_enabled()


def set_enabled(on: Optional[bool]) -> None:
    """Runtime override of the ``REPRO_OBS`` switch; ``None`` restores the
    environment-driven default."""
    global _enabled_override
    with _enabled_lock:
        _enabled_override = on


def trace_annotations_enabled() -> bool:
    """Host-side ``jax.profiler`` trace annotations (``REPRO_OBS_TRACE=1``)."""
    return (enabled() and os.environ.get("REPRO_OBS_TRACE", "")
            .strip().lower() in ("1", "on", "true", "yes"))


def default_latency_buckets() -> Tuple[float, ...]:
    """Log-spaced (×2) latency buckets: 10 µs up to ~84 s."""
    return tuple(1e-5 * 2.0 ** k for k in range(24))


_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _escape_label_value(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n").replace('"', r'\"')


def _format_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if isinstance(v, float) and v != v:
        return "NaN"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _labels_suffix(labels: Tuple[Tuple[str, str], ...],
                   extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    items = labels + extra
    if not items:
        return ""
    body = ",".join(f'{k}="{_escape_label_value(str(v))}"' for k, v in items)
    return "{" + body + "}"


class _Child:
    """One labeled series of a metric (the no-label metric is its own
    single child)."""

    __slots__ = ("_lock",)

    def __init__(self):
        self._lock = threading.Lock()


class _CounterChild(_Child):
    __slots__ = ("value",)

    def __init__(self):
        super().__init__()
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        with self._lock:
            self.value += amount


class _GaugeChild(_Child):
    __slots__ = ("value", "fn")

    def __init__(self):
        super().__init__()
        self.value = 0.0
        self.fn: Optional[Callable[[], float]] = None

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    def set_function(self, fn: Callable[[], float]) -> None:
        """Pull-time gauge: ``fn`` is evaluated at snapshot/render (e.g.
        queue depth)."""
        with self._lock:
            self.fn = fn

    def get(self) -> float:
        with self._lock:
            if self.fn is not None:
                try:
                    return float(self.fn())
                except Exception:
                    return float("nan")
            return self.value


class _HistogramChild(_Child):
    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Tuple[float, ...]):
        super().__init__()
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)   # last = +Inf overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        i = bisect.bisect_left(self.bounds, value)
        with self._lock:
            self.counts[i] += 1
            self.sum += value
            self.count += 1

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile estimate (scrape-side convenience;
        Prometheus proper recomputes from the ``_bucket`` series)."""
        with self._lock:
            total = self.count
            counts = list(self.counts)
        if total == 0:
            return 0.0
        target = q * total
        cum = 0.0
        lo = 0.0
        for i, c in enumerate(counts):
            hi = self.bounds[i] if i < len(self.bounds) else lo
            if cum + c >= target and c > 0:
                frac = (target - cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            cum += c
            lo = hi
        return lo


class _Metric:
    """Base labeled metric: a family of children keyed by label values."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._children: Dict[Tuple[str, ...], _Child] = {}
        if not self.labelnames:
            self._children[()] = self._new_child()

    def _new_child(self) -> _Child:
        raise NotImplementedError

    def labels(self, *values, **kv):
        if kv:
            if values:
                raise ValueError("pass label values positionally or by "
                                 "keyword, not both")
            try:
                values = tuple(kv[ln] for ln in self.labelnames)
            except KeyError as e:
                raise ValueError(f"missing label {e} for {self.name}") from e
            if len(kv) != len(self.labelnames):
                raise ValueError(f"unexpected labels for {self.name}: "
                                 f"{sorted(set(kv) - set(self.labelnames))}")
        key = tuple(str(v) for v in values)
        if len(key) != len(self.labelnames):
            raise ValueError(
                f"{self.name} expects labels {self.labelnames}, got {key}")
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._new_child())
        return child

    def _default(self):
        if self.labelnames:
            raise ValueError(f"{self.name} is labeled {self.labelnames}; "
                             "use .labels(...)")
        return self._children[()]

    def _series(self) -> List[Tuple[Tuple[Tuple[str, str], ...], _Child]]:
        with self._lock:
            items = list(self._children.items())
        return [(tuple(zip(self.labelnames, key)), child)
                for key, child in sorted(items)]


class Counter(_Metric):
    kind = "counter"

    def _new_child(self):
        return _CounterChild()

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    def snapshot(self) -> Dict[Tuple[Tuple[str, str], ...], float]:
        return {lk: c.value for lk, c in self._series()}

    def render(self, out: List[str]) -> None:
        for lk, c in self._series():
            out.append(f"{self.name}{_labels_suffix(lk)} "
                       f"{_format_value(c.value)}")


class Gauge(_Metric):
    kind = "gauge"

    def _new_child(self):
        return _GaugeChild()

    def set(self, value: float) -> None:
        self._default().set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._default().dec(amount)

    def set_function(self, fn: Callable[[], float]) -> None:
        self._default().set_function(fn)

    def get(self) -> float:
        return self._default().get()

    def snapshot(self) -> Dict[Tuple[Tuple[str, str], ...], float]:
        return {lk: c.get() for lk, c in self._series()}

    def render(self, out: List[str]) -> None:
        for lk, c in self._series():
            out.append(f"{self.name}{_labels_suffix(lk)} "
                       f"{_format_value(c.get())}")


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Optional[Iterable[float]] = None):
        bounds = tuple(sorted(buckets)) if buckets is not None \
            else default_latency_buckets()
        if not bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.bounds = bounds
        super().__init__(name, help, labelnames)

    def _new_child(self):
        return _HistogramChild(self.bounds)

    def observe(self, value: float) -> None:
        self._default().observe(value)

    def quantile(self, q: float) -> float:
        return self._default().quantile(q)

    def snapshot(self) -> Dict[Tuple[Tuple[str, str], ...], Dict]:
        out = {}
        for lk, c in self._series():
            with c._lock:
                out[lk] = {"buckets": dict(zip(self.bounds, c.counts)),
                           "overflow": c.counts[-1],
                           "sum": c.sum, "count": c.count}
        return out

    def render(self, out: List[str]) -> None:
        for lk, c in self._series():
            with c._lock:
                counts = list(c.counts)
                total, s = c.count, c.sum
            cum = 0
            for bound, n in zip(self.bounds, counts):
                cum += n
                out.append(
                    f"{self.name}_bucket"
                    f"{_labels_suffix(lk, (('le', _format_value(bound)),))}"
                    f" {cum}")
            out.append(f"{self.name}_bucket"
                       f"{_labels_suffix(lk, (('le', '+Inf'),))} {total}")
            out.append(f"{self.name}_sum{_labels_suffix(lk)} "
                       f"{_format_value(s)}")
            out.append(f"{self.name}_count{_labels_suffix(lk)} {total}")


class MetricsRegistry:
    """Get-or-create metric registry with snapshot + Prometheus rendering.

    ``collectors`` are pull-time hooks (e.g. the serve tier refreshing its
    queue-depth and quantile gauges) run at the top of every
    :meth:`snapshot`/:meth:`render`; a collector that raises is dropped from
    the scrape, never propagated into it.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}
        self._collectors: List[Callable[[], None]] = []

    def _get_or_create(self, cls, name: str, help: str,
                       labelnames: Sequence[str], **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, labelnames, **kw)
                self._metrics[name] = m
                return m
        if not isinstance(m, cls) or m.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} already registered as {m.kind} with "
                f"labels {m.labelnames}")
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Optional[Iterable[float]] = None) -> Histogram:
        m = self._get_or_create(Histogram, name, help, labelnames,
                                buckets=buckets)
        return m

    def add_collector(self, fn: Callable[[], None]) -> None:
        with self._lock:
            self._collectors.append(fn)

    def remove_collector(self, fn: Callable[[], None]) -> None:
        with self._lock:
            if fn in self._collectors:
                self._collectors.remove(fn)

    def _run_collectors(self) -> None:
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            try:
                fn()
            except Exception:
                pass

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def snapshot(self) -> Dict[str, Dict]:
        """Point-in-time copy of every series, keyed by metric name."""
        self._run_collectors()
        with self._lock:
            metrics = list(self._metrics.values())
        return {m.name: {"type": m.kind, "help": m.help,
                         "series": m.snapshot()} for m in metrics}

    def render(self) -> str:
        """Prometheus text exposition (format version 0.0.4)."""
        self._run_collectors()
        with self._lock:
            metrics = sorted(self._metrics.values(), key=lambda m: m.name)
        out: List[str] = []
        for m in metrics:
            if m.help:
                out.append(f"# HELP {m.name} {m.help}")
            out.append(f"# TYPE {m.name} {m.kind}")
            m.render(out)
        return "\n".join(out) + "\n"

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()
            self._collectors.clear()


_registry = MetricsRegistry()
_registry_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    """The process-global default registry (what ``/metrics`` serves)."""
    return _registry


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the global registry (tests); returns the previous one."""
    global _registry
    with _registry_lock:
        prev, _registry = _registry, reg
    return prev
