"""Background HTTP thread serving ``/metrics`` and ``/healthz``.

:class:`MetricsServer` wraps a :class:`~http.server.ThreadingHTTPServer` in
a daemon thread: ``/metrics`` serves the registry's Prometheus text
exposition, ``/healthz`` answers ``ok`` while the server is up (and ``503``
once a liveness callback says otherwise).  Port 0 binds an ephemeral port —
read :attr:`port` after :meth:`start`.  Intended for the serve CLI
(``python -m repro.launch.serve --mode samples --metrics-port ...``) and
tests; the server never blocks the sampling path (scrapes render under the
registry locks only).
"""

from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional

from .metrics import MetricsRegistry, get_registry

__all__ = ["MetricsServer", "PROMETHEUS_CONTENT_TYPE"]

PROMETHEUS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-obs/1"

    def _send(self, code: int, body: str,
              ctype: str = "text/plain; charset=utf-8") -> None:
        data = body.encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def do_GET(self):  # noqa: N802 (http.server API)
        path = self.path.split("?", 1)[0]
        if path == "/metrics":
            try:
                body = self.server.registry.render()
            except Exception as e:                  # scrape must not 500 raw
                self._send(500, f"metrics render failed: {e}\n")
                return
            self._send(200, body, PROMETHEUS_CONTENT_TYPE)
        elif path == "/healthz":
            alive = self.server.health_fn()
            self._send(200 if alive else 503, "ok\n" if alive else "down\n")
        else:
            self._send(404, "not found (try /metrics or /healthz)\n")

    def log_message(self, fmt, *args):
        pass                                        # keep scrapes silent


class _Server(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    registry: MetricsRegistry
    health_fn: Callable[[], bool]


class MetricsServer:
    """Daemon-thread HTTP server for ``/metrics`` + ``/healthz``."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 health_fn: Optional[Callable[[], bool]] = None):
        self.registry = registry if registry is not None else get_registry()
        self.host = host
        self.requested_port = int(port)
        self.health_fn = health_fn or (lambda: True)
        self._httpd: Optional[_Server] = None
        self._thread: Optional[threading.Thread] = None

    @property
    def port(self) -> int:
        """Bound port (valid after :meth:`start`)."""
        if self._httpd is None:
            return self.requested_port
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "MetricsServer":
        if self._httpd is not None:
            return self
        httpd = _Server((self.host, self.requested_port), _Handler)
        httpd.registry = self.registry
        httpd.health_fn = self.health_fn
        self._httpd = httpd
        self._thread = threading.Thread(target=httpd.serve_forever,
                                        name="obs-metrics-http", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._httpd is None:
            return
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
        self._httpd = None
        self._thread = None

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
