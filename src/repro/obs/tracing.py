"""Bounded ring-buffer event log — the φ-trajectory tracer.

ONLINE-UNION's whole pitch is refining cheap initial parameter estimates on
the fly; :class:`TraceRing` makes that refinement observable.  The sampler
appends one event dict per notable transition (init, φ-refresh, backtrack)
and the ring keeps the last ``capacity`` of them with a monotone sequence
number, so a long-running service holds bounded memory while the bench CLIs
and tests can dump the recent trajectory.

Events are plain dicts (JSON-friendly); the ring stamps ``seq`` and ``kind``
and never mutates caller payloads.  Appends are thread-safe (the serve tier
may refine φ from a producer thread while a scraper drains the ring).
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional

__all__ = ["TraceRing"]


class TraceRing:
    """Fixed-capacity event log with monotone sequence numbers."""

    def __init__(self, capacity: int = 256):
        if capacity <= 0:
            raise ValueError("TraceRing capacity must be positive")
        self.capacity = int(capacity)
        self._buf: List[Optional[Dict]] = [None] * self.capacity
        self._seq = 0                       # total events ever appended
        self._lock = threading.Lock()

    def append(self, kind: str, **fields) -> Dict:
        """Record one event; returns the stored dict (with ``seq`` set)."""
        ev = {"seq": None, "kind": str(kind), **fields}
        with self._lock:
            ev["seq"] = self._seq
            self._buf[self._seq % self.capacity] = ev
            self._seq += 1
        return ev

    def __len__(self) -> int:
        with self._lock:
            return min(self._seq, self.capacity)

    @property
    def total(self) -> int:
        """Events ever appended (≥ ``len`` once the ring has wrapped)."""
        with self._lock:
            return self._seq

    def events(self, kind: Optional[str] = None) -> List[Dict]:
        """Buffered events, oldest first; optionally filtered by kind."""
        with self._lock:
            n = min(self._seq, self.capacity)
            start = self._seq - n
            out = [dict(self._buf[i % self.capacity])
                   for i in range(start, self._seq)]
        if kind is not None:
            out = [e for e in out if e["kind"] == kind]
        return out

    def last(self, kind: Optional[str] = None) -> Optional[Dict]:
        evs = self.events(kind)
        return evs[-1] if evs else None

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self.capacity
            # seq keeps counting: consumers can detect drops across clears
