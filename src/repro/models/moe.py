"""Mixture-of-Experts FFN: top-k routing with capacity, expert-parallel GEMMs.

TPU-native dispatch (static shapes, no ragged tensors): per expert, the top-C
tokens among those that routed to it are gathered (``top_k`` over the masked
router scores), pushed through the expert's stacked-weight GEMM, and
scatter-added back scaled by the gate.  Tokens beyond capacity are dropped
(standard GShard/Switch semantics); an aux load-balancing loss is returned.

Sharding: expert-stacked weights (E, d, ff) shard E on the "model" axis (EP)
and d on "data" (FSDP); the (E, C, d) dispatch buffer shards E on "model" —
XLA SPMD emits the all-to-all-equivalent collective pattern for the
gather/scatter between token space (batch-sharded) and expert space.

The paper's tie-in (DESIGN §4): the union sampler's i.i.d. guarantee is what
makes the load-balancing statistics unbiased.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _pspec(*parts):
    return jax.sharding.PartitionSpec(*parts)


def _constrain(x: jnp.ndarray, parts) -> jnp.ndarray:
    """Best-effort sharding constraint (no-op without an ambient mesh)."""
    am = _ambient_mesh()
    if am is None:
        return x
    axes = am.axis_names
    fixed = []
    for dim, p in zip(x.shape, parts):
        if p is None:
            fixed.append(None)
            continue
        names = p if isinstance(p, tuple) else (p,)
        names = tuple(n for n in names if n in axes)
        n = int(np.prod([am.shape[a] for a in names])) if names else 1
        if names and n > 1 and dim % n == 0:
            fixed.append(names if len(names) > 1 else names[0])
        else:
            fixed.append(None)
    if all(f is None for f in fixed):
        return x
    return jax.lax.with_sharding_constraint(x, _pspec(*fixed))


@dataclasses.dataclass(frozen=True)
class MoEDims:
    d_model: int
    n_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25


def moe_param_shapes(dims: MoEDims) -> Dict[str, Tuple[int, ...]]:
    return {
        "router": (dims.d_model, dims.n_experts),
        "w_gate": (dims.n_experts, dims.d_model, dims.d_ff),
        "w_up": (dims.n_experts, dims.d_model, dims.d_ff),
        "w_down": (dims.n_experts, dims.d_ff, dims.d_model),
    }


def moe_ffn(params: Dict[str, jnp.ndarray], x: jnp.ndarray, dims: MoEDims,
            capacity: int | None = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,d) -> (out (B,S,d), aux_loss scalar).

    ``capacity=T`` gives dropless routing (the decode path uses this: at
    one-token-per-sequence batches, capacity dropping would be semantic).
    """
    Bsz, S, d = x.shape
    T = Bsz * S
    xt = x.reshape(T, d)
    E, K = dims.n_experts, dims.top_k
    C = capacity if capacity is not None else max(
        int(dims.capacity_factor * K * T / E), 1)
    C = min(C, T)

    xt = _constrain(xt, [("pod", "data"), None])   # tokens stay DP-sharded
    logits = jnp.einsum("td,de->te", xt, params["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)      # (T,E)
    topv, topi = jax.lax.top_k(probs, K)                             # (T,K)
    # normalized combine weights over the chosen experts
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # mask of token->expert assignment, scored by gate for capacity ranking
    assign = jnp.zeros((T, E), jnp.float32)
    assign = assign.at[jnp.arange(T)[:, None], topi].set(topv)       # (T,E)

    # per expert: top-C tokens by gate score (capacity enforcement)
    scores_eT = assign.T                                             # (E,T)
    cap_score, cap_idx = jax.lax.top_k(scores_eT, C)                 # (E,C)
    valid = cap_score > 0.0                                          # (E,C)

    xg = jnp.take(xt, cap_idx.reshape(-1), axis=0).reshape(E, C, d)
    xg = _constrain(xg, ["model", None, None])     # EP: experts on "model"
    xg = xg * valid[..., None].astype(xg.dtype)

    g = jnp.einsum("ecd,edf->ecf", xg, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xg, params["w_up"].astype(x.dtype))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                   params["w_down"].astype(x.dtype))
    y = y * (cap_score[..., None] * valid[..., None]).astype(y.dtype)
    y = _constrain(y, ["model", None, None])

    out = jnp.zeros((T, d), y.dtype).at[cap_idx.reshape(-1)].add(
        y.reshape(E * C, d))
    # combine lands DP-sharded: the cross-expert reduction is then a
    # reduce-scatter over "model" of LOCAL token rows, not a global AR
    out = _constrain(out, [("pod", "data"), None])

    # Switch-style aux loss: E * sum_e (frac tokens to e) * (mean router prob e)
    imp = probs.mean(axis=0)                                         # (E,)
    load = (assign > 0).astype(jnp.float32).mean(axis=0)             # (E,)
    aux = E * jnp.sum(imp * load)
    return out.reshape(Bsz, S, d), aux


# ---------------------------------------------------------------------------
# shard_map expert-parallel MoE (§Perf arctic iteration: explicit collective
# schedule — local dispatch + one bf16 psum over "model", replacing GSPMD's
# gather+f32-all-reduce lowering of jnp.take across shards)
# ---------------------------------------------------------------------------


def _ambient_mesh():
    from ..launch.mesh import ambient_mesh
    return ambient_mesh()


def moe_ffn_dist(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
                 dims: MoEDims) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE under shard_map.

    Per (data, model) shard: route the shard's tokens, build local-expert
    capacity buffers, run the local expert GEMMs, scatter back, and psum the
    partial outputs over "model".  Collectives per layer: the seq all-gather
    at entry (GSPMD reshard) + one psum — vs the gather+f32-AR pattern GSPMD
    derives from cross-shard ``jnp.take`` (≈25x more bytes, measured:
    EXPERIMENTS.md §Perf cell 3).
    """
    am = _ambient_mesh()
    axes = am.axis_names
    P = jax.sharding.PartitionSpec
    da = tuple(a for a in ("pod", "data") if a in axes)
    dd = int(np.prod([am.shape[a] for a in da])) if da else 1
    mo = am.shape["model"]
    E, K = dims.n_experts, dims.top_k
    E_loc = E // mo
    Bsz, S, d = x.shape
    T_loc = (Bsz // dd) * S
    C = min(max(int(dims.capacity_factor * K * T_loc / E), 1), T_loc)
    da_spec = (da if len(da) > 1 else da[0]) if da else None

    def block(xb, wr, wg, wu, wd):
        Tb = xb.shape[0] * xb.shape[1]
        xt = xb.reshape(Tb, d)
        probs = jax.nn.softmax(
            jnp.einsum("td,de->te", xt, wr.astype(xt.dtype)).astype(jnp.float32),
            axis=-1)
        topv, topi = jax.lax.top_k(probs, K)
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        assign = jnp.zeros((Tb, E), jnp.float32)
        assign = assign.at[jnp.arange(Tb)[:, None], topi].set(topv)
        cap_score, cap_idx = jax.lax.top_k(assign.T, C)          # (E, C)
        j = jax.lax.axis_index("model")
        cs = jax.lax.dynamic_slice_in_dim(cap_score, j * E_loc, E_loc, 0)
        ci = jax.lax.dynamic_slice_in_dim(cap_idx, j * E_loc, E_loc, 0)
        valid = cs > 0.0
        xg = jnp.take(xt, ci.reshape(-1), axis=0).reshape(E_loc, C, d)
        xg = xg * valid[..., None].astype(xg.dtype)
        g = jnp.einsum("ecd,edf->ecf", xg, wg.astype(xt.dtype))
        u = jnp.einsum("ecd,edf->ecf", xg, wu.astype(xt.dtype))
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                       wd.astype(xt.dtype))
        y = y * (cs[..., None] * valid[..., None]).astype(y.dtype)
        out = jnp.zeros((Tb, d), y.dtype).at[ci.reshape(-1)].add(
            y.reshape(E_loc * C, d))
        out = jax.lax.psum(out, "model")
        imp = probs.mean(axis=0)
        load = (assign > 0).astype(jnp.float32).mean(axis=0)
        aux = E * jnp.sum(imp * load)
        if da:
            aux = jax.lax.pmean(aux, da)   # model axis is already invariant
        return out.reshape(xb.shape), aux

    from ..launch.mesh import shard_map
    fn = shard_map(
        block, mesh=am,
        in_specs=(P(da_spec, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(da_spec, None, None), P()))
    return fn(x, params["router"], params["w_gate"], params["w_up"],
              params["w_down"])


def moe_ffn_auto(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
                 dims: MoEDims) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """shard_map EP path when the ambient mesh allows it; dense otherwise."""
    am = _ambient_mesh()
    if am is not None and "model" in am.axis_names:
        mo = am.shape["model"]
        da = tuple(a for a in ("pod", "data") if a in am.axis_names)
        dd = int(np.prod([am.shape[a] for a in da])) if da else 1
        if mo > 1 and dims.n_experts % mo == 0 and x.shape[0] % max(dd, 1) == 0:
            return moe_ffn_dist(params, x, dims)
    return moe_ffn(params, x, dims)
