"""Serving: KV/state caches, prefill, and single-token decode per family.

Cache layouts (stacked on the layer axis so decode scans over layers):

* dense/moe/vlm : k,v (L, B, S, KV, hd) — batch on "data", seq on "model"
                  (sequence-parallel decode: XLA SPMD turns the softmax over
                  the seq-sharded cache into partial-max/sum all-reduces —
                  distributed flash-decoding).
* gemma2        : local layers use a **window-capped ring buffer**
                  (L/2, B, W, KV, hd) — the reason gemma2 runs `long_500k`:
                  only the global half of the layers holds full-length KV.
* mamba2        : h (L, B, H, N, P) + conv tail (L, B, k-1, conv_dim) — O(1)
                  in context length.
* zamba2        : per-group mamba states + one KV cache per shared-attention
                  application (G, B, S, KV, hd).
* encdec        : decoder self-KV + precomputed cross-attention K/V.

``decode_step(params, cfg, cache, tokens, lengths)`` appends one token at
position ``lengths`` (per batch row) and returns next-token logits.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import decode_attention, rms_norm, rope, softcap, swiglu
from .moe import moe_ffn
from .ssm import mamba2_decode
from .transformer import ModelConfig, _embed_tokens, _sub

Cache = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Cache construction (shapes only — dry-run uses these as ShapeDtypeStruct)
# ---------------------------------------------------------------------------


def cache_entries(cfg: ModelConfig, batch: int, max_len: int
                  ) -> Dict[str, Tuple[Tuple[int, ...], Tuple[Optional[str], ...]]]:
    """name -> (shape, logical axes)."""
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    dt = ("batch", "kvseq", None, None)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        S = max_len + (cfg.n_frontend_tokens if fam == "vlm" else 0)
        return {"k": ((L, batch, S, KV, hd), ("layer",) + dt),
                "v": ((L, batch, S, KV, hd), ("layer",) + dt)}
    if fam == "gemma2":
        half = L // 2
        W = min(cfg.window, max_len)
        return {
            "k_loc": ((half, batch, W, KV, hd), ("layer",) + dt),
            "v_loc": ((half, batch, W, KV, hd), ("layer",) + dt),
            "k_glob": ((half, batch, max_len, KV, hd), ("layer",) + dt),
            "v_glob": ((half, batch, max_len, KV, hd), ("layer",) + dt),
        }
    if fam == "mamba2":
        d = cfg.ssm_dims
        return {
            "h": ((L, batch, d.n_heads, d.state, d.head_dim),
                  ("layer", "batch", "heads", None, None)),
            "conv": ((L, batch, d.conv_k - 1, d.conv_dim),
                     ("layer", "batch", None, "mlp")),
        }
    if fam == "zamba2":
        d = cfg.ssm_dims
        G, P = cfg.n_zamba_groups, cfg.mamba_per_attn
        ent = {
            "h": ((G, P, batch, d.n_heads, d.state, d.head_dim),
                  ("layer", None, "batch", "heads", None, None)),
            "conv": ((G, P, batch, d.conv_k - 1, d.conv_dim),
                     ("layer", None, "batch", None, "mlp")),
            "k_sh": ((G, batch, max_len, KV, hd), ("layer",) + dt),
            "v_sh": ((G, batch, max_len, KV, hd), ("layer",) + dt),
        }
        if cfg.n_zamba_tail > 0:
            ent["h_tail"] = ((cfg.n_zamba_tail, batch, d.n_heads, d.state,
                              d.head_dim), ("layer", "batch", "heads", None, None))
            ent["conv_tail"] = ((cfg.n_zamba_tail, batch, d.conv_k - 1,
                                 d.conv_dim), ("layer", "batch", None, "mlp"))
        return ent
    if fam == "encdec":
        Tf = cfg.n_frontend_tokens
        return {"k": ((L, batch, max_len, KV, hd), ("layer",) + dt),
                "v": ((L, batch, max_len, KV, hd), ("layer",) + dt),
                "xk": ((L, batch, Tf, KV, hd), ("layer",) + dt),
                "xv": ((L, batch, Tf, KV, hd), ("layer",) + dt)}
    raise ValueError(fam)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    dt = cfg.compute_dtype
    return {k: jax.ShapeDtypeStruct(shp, dt)
            for k, (shp, _) in cache_entries(cfg, batch, max_len).items()}


def cache_logical_axes(cfg: ModelConfig, batch: int, max_len: int):
    return {k: ax for k, (shp, ax) in cache_entries(cfg, batch, max_len).items()}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    return {k: jnp.zeros(s.shape, s.dtype)
            for k, s in cache_specs(cfg, batch, max_len).items()}


# ---------------------------------------------------------------------------
# Decode helpers
# ---------------------------------------------------------------------------


def _project_qkv(p, x, cfg: ModelConfig):
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, p["ln1"])
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"].astype(x.dtype))
    kv = jnp.einsum("bsd,dh->bsh", h, p["wkv"].astype(x.dtype))
    q = q.reshape(B, -1, H, hd)
    kv = kv.reshape(B, -1, 2, KV, hd)
    return h, q, kv[:, :, 0], kv[:, :, 1]


def _attn_decode(p, x, k_cache, v_cache, lengths, cfg: ModelConfig,
                 window: int = 0, ring: bool = False):
    """One-token attention vs cache; returns (attn_out, k_cache', v_cache')."""
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    _, q, k_new, v_new = _project_qkv(p, x, cfg)
    pos = lengths[:, None]                                    # (B,1)
    q = rope(q, pos, cfg.rope_theta)[:, 0]                    # (B,H,hd)
    k_new = rope(k_new, pos, cfg.rope_theta)[:, 0]            # (B,KV,hd)
    v_new = v_new[:, 0]
    W = k_cache.shape[1]
    slot = (lengths % W) if ring else jnp.minimum(lengths, W - 1)
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, slot].set(k_new.astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, slot].set(v_new.astype(v_cache.dtype))
    eff_len = jnp.minimum(lengths + 1, W) if ring else jnp.minimum(lengths + 1, W)
    o = decode_attention(q, k_cache, v_cache, eff_len,
                         window=0 if ring else window, cap=cfg.attn_softcap)
    out = jnp.einsum("bh,hd->bd", o.reshape(B, H * hd),
                     p["wo"].astype(x.dtype))
    return out[:, None, :], k_cache, v_cache


def _mlp_decode(p, x, cfg: ModelConfig):
    return swiglu(rms_norm(x, p["ln2"]), p["w_gate"].astype(x.dtype),
                  p["w_up"].astype(x.dtype), p["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# decode_step per family
# ---------------------------------------------------------------------------


def decode_step(params: Dict[str, jnp.ndarray], cfg: ModelConfig,
                cache: Cache, tokens: jnp.ndarray, lengths: jnp.ndarray
                ) -> Tuple[Cache, jnp.ndarray]:
    """tokens (B,1), lengths (B,) -> (cache', logits (B,vocab))."""
    x = _embed_tokens(params, cfg, tokens)
    fam = cfg.family
    new_cache = dict(cache)

    if fam in ("dense", "moe", "vlm"):
        stack = _sub(params, "blocks.")

        def body(h, xs):
            p, kc, vc = xs
            a, kc, vc = _attn_decode(p, h, kc, vc, lengths, cfg)
            h = h + a
            if fam == "moe":
                # dropless at decode: capacity = token count
                m, _ = moe_ffn(_sub(p, "moe_"), rms_norm(h, p["ln2"]),
                               cfg.moe_dims, capacity=h.shape[0])
                if cfg.dense_residual:
                    hh = rms_norm(h, p["ln2"])
                    m = m + swiglu(hh, p["res_w_gate"].astype(h.dtype),
                                   p["res_w_up"].astype(h.dtype),
                                   p["res_w_down"].astype(h.dtype))
                h = h + m
            else:
                h = h + _mlp_decode(p, h, cfg)
            return h, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(body, x, (stack, cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = k_new, v_new

    elif fam == "gemma2":
        stack = _sub(params, "blocks.")
        even = {k: v[0::2] for k, v in stack.items()}   # local layers
        odd = {k: v[1::2] for k, v in stack.items()}    # global layers

        def pair(h, xs):
            pe, po, klc, vlc, kgc, vgc = xs
            a, klc, vlc = _attn_decode(pe, h, klc, vlc, lengths, cfg, ring=True)
            h = h + rms_norm(a, pe["ln1_post"])
            m = _mlp_decode(pe, h, cfg)
            h = h + rms_norm(m, pe["ln2_post"])
            a, kgc, vgc = _attn_decode(po, h, kgc, vgc, lengths, cfg)
            h = h + rms_norm(a, po["ln1_post"])
            m = _mlp_decode(po, h, cfg)
            h = h + rms_norm(m, po["ln2_post"])
            return h, (klc, vlc, kgc, vgc)

        x, (kl, vl, kg, vg) = jax.lax.scan(
            pair, x, (even, odd, cache["k_loc"], cache["v_loc"],
                      cache["k_glob"], cache["v_glob"]))
        new_cache.update(k_loc=kl, v_loc=vl, k_glob=kg, v_glob=vg)

    elif fam == "mamba2":
        stack = _sub(params, "blocks.")

        def body(h, xs):
            p, hs, cs = xs
            y, st = mamba2_decode(p, h, {"h": hs, "conv": cs}, cfg.ssm_dims)
            return h + y, (st["h"], st["conv"])

        x, (hs, cs) = jax.lax.scan(body, x, (stack, cache["h"], cache["conv"]))
        new_cache["h"], new_cache["conv"] = hs, cs

    elif fam == "zamba2":
        shared = _sub(params, "shared.")
        groups = _sub(params, "blocks.")
        gate = params["gate"]

        def group(h, xs):
            gp, g, hs, cs, ksh, vsh = xs

            def inner(hh, ys):
                p, hsi, csi = ys
                y, st = mamba2_decode(p, hh, {"h": hsi, "conv": csi}, cfg.ssm_dims)
                return hh + y, (st["h"], st["conv"])
            h, (hs, cs) = jax.lax.scan(inner, h, (gp, hs, cs))
            a, ksh, vsh = _attn_decode(shared, h, ksh, vsh, lengths, cfg)
            sh = h + a
            sh = sh + _mlp_decode(shared, sh, cfg)
            h = h + jax.nn.sigmoid(g.astype(jnp.float32)).astype(h.dtype)[None, None, :] * (sh - h)
            return h, (hs, cs, ksh, vsh)

        x, (hs, cs, ksh, vsh) = jax.lax.scan(
            group, x, (groups, gate, cache["h"], cache["conv"],
                       cache["k_sh"], cache["v_sh"]))
        new_cache.update(h=hs, conv=cs, k_sh=ksh, v_sh=vsh)
        if cfg.n_zamba_tail > 0:
            tail = _sub(params, "tail.")
            tail = {k: v[:cfg.n_zamba_tail] for k, v in tail.items()}

            def tbody(h, xs):
                p, hsi, csi = xs
                y, st = mamba2_decode(p, h, {"h": hsi, "conv": csi}, cfg.ssm_dims)
                return h + y, (st["h"], st["conv"])
            x, (ht, ct) = jax.lax.scan(tbody, x, (tail, cache["h_tail"],
                                                  cache["conv_tail"]))
            new_cache["h_tail"], new_cache["conv_tail"] = ht, ct

    elif fam == "encdec":
        stack = _sub(params, "dec.")

        def body(h, xs):
            p, kc, vc, xk, xv = xs
            a, kc, vc = _attn_decode(p, h, kc, vc, lengths, cfg)
            h = h + a
            # cross attention against precomputed encoder K/V
            B = h.shape[0]
            H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            hq = rms_norm(h, p["lnx"])
            q = jnp.einsum("bsd,dh->bsh", hq, p["xq"].astype(h.dtype))
            q = q.reshape(B, H, hd)
            Tf = xk.shape[1]
            o = decode_attention(q, xk, xv,
                                 jnp.full((B,), Tf, jnp.int32))
            h = h + jnp.einsum("bh,hd->bd", o.reshape(B, H * hd),
                               p["xo"].astype(h.dtype))[:, None]
            h = h + _mlp_decode(p, h, cfg)
            return h, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (stack, cache["k"], cache["v"], cache["xk"], cache["xv"]))
        new_cache["k"], new_cache["v"] = k_new, v_new

    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))[:, 0]
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return new_cache, logits


# ---------------------------------------------------------------------------
# Prefill (inference forward producing logits; KV population for encdec cross)
# ---------------------------------------------------------------------------


def prefill_step(params: Dict[str, jnp.ndarray], cfg: ModelConfig,
                 batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Inference prefill: full-sequence forward -> last-token logits (B, V).

    Lowered for the ``prefill_32k`` cells.  The KV-cache write-out (a pure
    store of the per-layer K/V activations) is accounted analytically in the
    roofline notes; XLA fuses it with the projection when caches are threaded
    (decode cells size the caches explicitly).
    """
    from .transformer import forward_hidden
    x, _ = forward_hidden(params, cfg, batch)
    last = x[:, -1, :]
    logits = jnp.einsum("bd,vd->bv", last, params["embed"].astype(x.dtype))
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)
