"""Model assembly: all 10 assigned architectures from one block library.

Families:
* ``dense``   — pre-norm GQA transformer (minitron / granite / mistral-large)
* ``gemma2``  — alternating local(sliding-window)/global attention, logit
                softcaps, pre+post sublayer norms, embedding scaling
* ``moe``     — dense attention + top-k expert FFN (phi3.5-moe / arctic;
                arctic adds a parallel dense-residual FFN)
* ``mamba2``  — attention-free SSD stack
* ``zamba2``  — mamba2 backbone with a single *shared* attention+MLP block
                applied after every ``mamba_per_attn`` SSM layers
* ``encdec``  — whisper-style encoder-decoder (conv/audio frontend stubbed:
                the encoder consumes precomputed frame embeddings)
* ``vlm``     — paligemma: patch-embedding stub prefix (bidirectional prefix
                attention) + gemma-style decoder

Everything that repeats is ``lax.scan``'d over stacked parameters (HLO stays
O(1) in depth — essential for 33-cell × 2-mesh dry-run compile times), with
``jax.checkpoint`` on the block body when ``cfg.remat``.

Params are plain pytrees; ``param_specs`` returns ShapeDtypeStructs (the
dry-run lowers against these — no allocation), ``init_params`` materialises
them, and ``logical_axes`` returns the same-structure sharding names consumed
by :mod:`repro.launch.sharding`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import (decode_attention, fit_chunk, flash_attention,
                     flash_attention_cv, rms_norm, rope, shard_activations,
                     shard_logits, softcap, swiglu)
from .moe import MoEDims, moe_ffn, moe_ffn_auto, moe_param_shapes
from .ssm import (SSMDims, mamba2_block, mamba2_decode, ssm_param_shapes)

PAD_ID = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    rope_theta: float = 10000.0
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    window: int = 0
    n_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0
    moe_capacity_factor: float = 1.25
    dense_residual: bool = False
    ssm_state: int = 0
    ssm_headdim: int = 64
    mamba_per_attn: int = 0
    frontend: str = "none"            # "none" | "audio" | "patch"
    n_frontend_tokens: int = 0
    encdec: bool = False
    n_enc_layers: int = 0
    prefix_len: int = 0
    embed_scale: bool = False
    remat: bool = True
    q_chunk: int = 256
    kv_chunk: int = 512
    ssd_chunk: int = 128
    loss_chunk: int = 512
    dtype: str = "bfloat16"

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("mamba2", "zamba2", "gemma2")

    @property
    def ssm_dims(self) -> SSMDims:
        d_inner = 2 * self.d_model
        return SSMDims(self.d_model, d_inner, d_inner // self.ssm_headdim,
                       self.ssm_headdim, self.ssm_state)

    @property
    def moe_dims(self) -> MoEDims:
        return MoEDims(self.d_model, self.n_experts, self.top_k, self.moe_dff,
                       self.moe_capacity_factor)

    @property
    def n_zamba_groups(self) -> int:
        return self.n_layers // (self.mamba_per_attn + 1)

    @property
    def n_zamba_tail(self) -> int:
        return self.n_layers - self.n_zamba_groups * (self.mamba_per_attn + 1)


# ---------------------------------------------------------------------------
# Parameter shapes / logical sharding axes
# ---------------------------------------------------------------------------



def _ambient_mesh():
    from ..launch.mesh import ambient_mesh
    return ambient_mesh()

def _attn_shapes(cfg: ModelConfig) -> Dict[str, Tuple[Tuple[int, ...], Tuple[str, ...]]]:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "ln1": ((d,), ("embed",)),
        "wq": ((d, H * hd), ("embed", "heads")),
        "wkv": ((d, 2 * KV * hd), ("embed", "heads")),
        "wo": ((H * hd, d), ("heads", "embed")),
    }


def _mlp_shapes(cfg: ModelConfig, ff: Optional[int] = None):
    d = cfg.d_model
    f = ff if ff is not None else cfg.d_ff
    return {
        "ln2": ((d,), ("embed",)),
        "w_gate": ((d, f), ("embed", "mlp")),
        "w_up": ((d, f), ("embed", "mlp")),
        "w_down": ((f, d), ("mlp", "embed")),
    }


def _block_shapes(cfg: ModelConfig) -> Dict[str, Tuple[Tuple[int, ...], Tuple[str, ...]]]:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {**_attn_shapes(cfg), **_mlp_shapes(cfg)}
    if fam == "gemma2":
        out = {**_attn_shapes(cfg), **_mlp_shapes(cfg)}
        out["ln1_post"] = ((cfg.d_model,), ("embed",))
        out["ln2_post"] = ((cfg.d_model,), ("embed",))
        return out
    if fam == "moe":
        out = {**_attn_shapes(cfg)}
        out["ln2"] = ((cfg.d_model,), ("embed",))
        md = cfg.moe_dims
        for k, shp in moe_param_shapes(md).items():
            ax = {"router": ("embed", "experts"),
                  "w_gate": ("experts", "embed", "mlp"),
                  "w_up": ("experts", "embed", "mlp"),
                  "w_down": ("experts", "mlp", "embed")}[k]
            out[f"moe_{k}"] = (shp, ax)
        if cfg.dense_residual:
            for k, (shp, ax) in _mlp_shapes(cfg, cfg.d_ff).items():
                out[f"res_{k}"] = (shp, ax)
        return out
    if fam == "mamba2":
        dims = cfg.ssm_dims
        ax = {"norm": ("embed",), "in_proj": ("embed", "mlp"),
              "conv_w": (None, "mlp"), "conv_b": ("mlp",),
              "A_log": ("heads",), "D": ("heads",), "dt_bias": ("heads",),
              "out_norm": ("mlp",), "out_proj": ("mlp", "embed")}
        return {k: (shp, ax[k]) for k, shp in ssm_param_shapes(dims).items()}
    if fam == "encdec":
        out = {**_attn_shapes(cfg), **_mlp_shapes(cfg)}
        # cross attention (decoder only; encoder stack ignores these)
        out["lnx"] = ((cfg.d_model,), ("embed",))
        out["xq"] = ((cfg.d_model, cfg.n_heads * cfg.head_dim), ("embed", "heads"))
        out["xkv"] = ((cfg.d_model, 2 * cfg.n_kv_heads * cfg.head_dim), ("embed", "heads"))
        out["xo"] = ((cfg.n_heads * cfg.head_dim, cfg.d_model), ("heads", "embed"))
        return out
    raise ValueError(fam)


def _stack(shapes: Dict[str, Tuple[Tuple[int, ...], Tuple[str, ...]]], n: int):
    specs = {k: ((n,) + shp, ("layer",) + tuple(a if a is not None else None
                                                for a in ax))
             for k, (shp, ax) in shapes.items()}
    return specs


def param_entries(cfg: ModelConfig) -> Dict[str, Tuple[Tuple[int, ...], Tuple[Optional[str], ...]]]:
    """name -> (shape, logical axes) for every parameter."""
    d = cfg.d_model
    out: Dict[str, Tuple[Tuple[int, ...], Tuple[Optional[str], ...]]] = {
        "embed": ((cfg.vocab, d), ("vocab", "embed")),
        "final_norm": ((d,), ("embed",)),
    }
    fam = cfg.family
    if fam == "zamba2":
        dims = cfg.ssm_dims
        ssm = {k: (shp, {"norm": ("embed",), "in_proj": ("embed", "mlp"),
                         "conv_w": (None, "mlp"), "conv_b": ("mlp",),
                         "A_log": ("heads",), "D": ("heads",),
                         "dt_bias": ("heads",), "out_norm": ("mlp",),
                         "out_proj": ("mlp", "embed")}[k])
               for k, shp in ssm_param_shapes(dims).items()}
        G, P = cfg.n_zamba_groups, cfg.mamba_per_attn
        for k, (shp, ax) in ssm.items():
            out[f"blocks.{k}"] = ((G, P) + shp, ("layer", None) + ax)
        for k, (shp, ax) in ssm.items():
            out[f"tail.{k}"] = ((max(cfg.n_zamba_tail, 1),) + shp, ("layer",) + ax)
        shared = {**_attn_shapes(cfg), **_mlp_shapes(cfg)}
        for k, (shp, ax) in shared.items():
            out[f"shared.{k}"] = (shp, ax)
        out["gate"] = ((G, d), ("layer", "embed"))
        return out
    if fam == "encdec":
        blk = _block_shapes(cfg)
        for k, (shp, ax) in _stack(blk, cfg.n_layers).items():
            out[f"dec.{k}"] = (shp, ax)
        enc_blk = {**_attn_shapes(cfg), **_mlp_shapes(cfg)}
        for k, (shp, ax) in _stack(enc_blk, cfg.n_enc_layers).items():
            out[f"enc.{k}"] = (shp, ax)
        out["enc_final_norm"] = ((d,), ("embed",))
        return out
    blk = _block_shapes(cfg)
    for k, (shp, ax) in _stack(blk, cfg.n_layers).items():
        out[f"blocks.{k}"] = (shp, ax)
    return out


def param_specs(cfg: ModelConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    return {k: jax.ShapeDtypeStruct(shp, jnp.float32)
            for k, (shp, _) in param_entries(cfg).items()}


def logical_axes(cfg: ModelConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    return {k: ax for k, (shp, ax) in param_entries(cfg).items()}


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    out = {}
    for k, (shp, _) in param_entries(cfg).items():
        if any(t in k for t in ("ln", "norm", "gate")) and len(shp) <= 2 and "w_" not in k:
            out[k] = jnp.zeros(shp, jnp.float32)
        elif k.endswith("A_log"):
            out[k] = jnp.asarray(np.log(rng.uniform(1, 16, shp)), jnp.float32)
        elif k.endswith(("D", "dt_bias", "conv_b")):
            out[k] = jnp.zeros(shp, jnp.float32)
        else:
            fan_in = shp[-2] if len(shp) >= 2 else shp[-1]
            out[k] = jnp.asarray(
                rng.standard_normal(shp) / np.sqrt(max(fan_in, 1)), jnp.float32)
    return out


def _sub(params: Dict[str, jnp.ndarray], prefix: str) -> Dict[str, jnp.ndarray]:
    pl = len(prefix)
    return {k[pl:]: v for k, v in params.items() if k.startswith(prefix)}


# ---------------------------------------------------------------------------
# Blocks (training / prefill forward)
# ---------------------------------------------------------------------------


def _constrain_heads(x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, H, D) attention activations: batch->data, heads->model."""
    am = _ambient_mesh()
    if am is None:
        return x
    axes = am.axis_names
    da = tuple(a for a in ("pod", "data") if a in axes)
    da_n = int(np.prod([am.shape[a] for a in da])) if da else 1
    mo_n = am.shape["model"] if "model" in axes else 1
    parts: list = [None, None, None, None]
    if da and x.shape[0] % da_n == 0 and da_n > 1:
        parts[0] = da if len(da) > 1 else da[0]
    if "model" in axes and x.shape[2] % mo_n == 0 and mo_n > 1:
        parts[2] = "model"
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*parts))


def _attention_sublayer(p, x, cfg: ModelConfig, positions, *, causal=True,
                        window=0, prefix_len=0, context=None):
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, p["ln1" if context is None else "lnx"])
    wq = p["wq" if context is None else "xq"].astype(x.dtype)
    wkv = p["wkv" if context is None else "xkv"].astype(x.dtype)
    wo = p["wo" if context is None else "xo"].astype(x.dtype)
    q = jnp.einsum("bsd,dh->bsh", h, wq).reshape(B, S, H, hd)
    src = h if context is None else context
    kv = jnp.einsum("bsd,dh->bsh", src, wkv).reshape(B, src.shape[1], 2, KV, hd)
    k, v = kv[:, :, 0], kv[:, :, 1]
    if context is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if KV != H:
        # repeat K/V to full head count: a single H-sized head axis shards
        # cleanly over "model" (KV=8 / G=4 both < 16 cannot), removing every
        # cross-model collective inside the attention loops (§Perf iter 3)
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    q = _constrain_heads(q)
    k = _constrain_heads(k)
    v = _constrain_heads(v)
    T = k.shape[1]
    o = flash_attention_cv(q, k, v, bool(causal and context is None),
                           int(window or 0), float(cfg.attn_softcap),
                           fit_chunk(S, cfg.q_chunk),
                           fit_chunk(T, cfg.kv_chunk), int(prefix_len))
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * hd), wo)
    # requesting the row-parallel product in the seq-sharded layout turns the
    # TP all-reduce into a reduce-scatter (Megatron-SP; §Perf iter 4)
    return shard_activations(out.astype(x.dtype))


def _dense_block(p, x, cfg: ModelConfig, positions, window=0, prefix_len=0):
    a = _attention_sublayer(p, x, cfg, positions, window=window,
                            prefix_len=prefix_len)
    if cfg.family == "gemma2":
        a = rms_norm(a, p["ln1_post"])
    x = x + a
    h = rms_norm(x, p["ln2"])
    m = swiglu(h, p["w_gate"].astype(x.dtype), p["w_up"].astype(x.dtype),
               p["w_down"].astype(x.dtype))
    m = shard_activations(m.astype(x.dtype))   # RS for the MLP row-parallel
    if cfg.family == "gemma2":
        m = rms_norm(m, p["ln2_post"])
    return x + m


def _moe_block(p, x, cfg: ModelConfig, positions):
    x = x + _attention_sublayer(p, x, cfg, positions)
    h = rms_norm(x, p["ln2"])
    moe_out, aux = moe_ffn_auto(_sub(p, "moe_"), h, cfg.moe_dims)
    out = moe_out
    if cfg.dense_residual:
        out = out + swiglu(h, p["res_w_gate"].astype(x.dtype),
                           p["res_w_up"].astype(x.dtype),
                           p["res_w_down"].astype(x.dtype))
    return x + out, aux


# ---------------------------------------------------------------------------
# Full forward (training)
# ---------------------------------------------------------------------------


def _embed_tokens(params, cfg: ModelConfig, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.compute_dtype)
    return x


def _maybe_remat(f, cfg: ModelConfig):
    return jax.checkpoint(f) if cfg.remat else f


def _scan_blocks(params_prefix, x, cfg: ModelConfig, positions, body):
    stacked = params_prefix
    fn = _maybe_remat(body, cfg)

    def step(carry, layer_params):
        carry = shard_activations(carry)
        return fn(carry, layer_params), None

    x, _ = jax.lax.scan(step, x, stacked)
    return x


def forward_hidden(params: Dict[str, jnp.ndarray], cfg: ModelConfig,
                   batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Backbone forward: returns (final hidden (B,S,d), moe aux loss)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family == "encdec":
        enc_x = batch["frontend"].astype(cfg.compute_dtype)   # (B,Tf,d)
        enc_pos = jnp.broadcast_to(jnp.arange(enc_x.shape[1])[None], enc_x.shape[:2])
        enc_stack = _sub(params, "enc.")

        def enc_body(h, p):
            return _dense_block(p, h, cfg, enc_pos, window=0)
        enc_x = _scan_blocks(enc_stack, enc_x, cfg, enc_pos, enc_body)
        enc_out = rms_norm(enc_x, params["enc_final_norm"])

        x = _embed_tokens(params, cfg, tokens)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        dec_stack = _sub(params, "dec.")

        def dec_body(h, p):
            h = h + _attention_sublayer(p, h, cfg, pos, causal=True)
            h = h + _attention_sublayer(p, h, cfg, pos, context=enc_out)
            m = swiglu(rms_norm(h, p["ln2"]), p["w_gate"].astype(h.dtype),
                       p["w_up"].astype(h.dtype), p["w_down"].astype(h.dtype))
            return h + m
        x = _scan_blocks(dec_stack, x, cfg, pos, dec_body)

    elif cfg.family == "vlm":
        fe = batch["frontend"].astype(cfg.compute_dtype)      # (B,Np,d)
        text = _embed_tokens(params, cfg, tokens)
        x = jnp.concatenate([fe, text], axis=1)
        S = x.shape[1]
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        stack = _sub(params, "blocks.")

        def body(h, p):
            return _dense_block(p, h, cfg, pos, prefix_len=cfg.prefix_len)
        x = _scan_blocks(stack, x, cfg, pos, body)

    elif cfg.family == "mamba2":
        x = _embed_tokens(params, cfg, tokens)
        stack = _sub(params, "blocks.")

        def body(h, p):
            return h + mamba2_block(p, h, cfg.ssm_dims, chunk=cfg.ssd_chunk)
        x = _scan_blocks(stack, x, cfg, None, body)

    elif cfg.family == "zamba2":
        x = _embed_tokens(params, cfg, tokens)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        shared = _sub(params, "shared.")
        groups = _sub(params, "blocks.")
        gate = params["gate"]

        def group_body(h, gp):
            h = shard_activations(h)
            mamba_p, g = gp

            def inner(hh, p):
                return hh + mamba2_block(p, hh, cfg.ssm_dims, chunk=cfg.ssd_chunk), None
            h, _ = jax.lax.scan(inner, h, mamba_p)
            sh = _dense_block(shared, h, cfg, pos)
            return h + jax.nn.sigmoid(g.astype(jnp.float32)).astype(h.dtype)[None, None, :] * (sh - h)

        fn = _maybe_remat(group_body, cfg)

        def gstep(carry, gp):
            return fn(carry, gp), None
        x, _ = jax.lax.scan(gstep, x, (groups, gate))
        if cfg.n_zamba_tail > 0:
            tail = _sub(params, "tail.")
            tail = {k: v[:cfg.n_zamba_tail] for k, v in tail.items()}

            def tbody(h, p):
                return h + mamba2_block(p, h, cfg.ssm_dims, chunk=cfg.ssd_chunk)
            x = _scan_blocks(tail, x, cfg, None, tbody)

    elif cfg.family == "moe":
        x = _embed_tokens(params, cfg, tokens)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        stack = _sub(params, "blocks.")

        def body(carry, p):
            h, aux = carry
            h = shard_activations(h)
            h, a = _moe_block(p, h, cfg, pos)
            return (h, aux + a)
        fn = _maybe_remat(body, cfg)

        def step(carry, p):
            return fn(carry, p), None
        (x, aux_total), _ = jax.lax.scan(step, (x, aux_total), stack)

    else:  # dense / gemma2
        x = _embed_tokens(params, cfg, tokens)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        stack = _sub(params, "blocks.")
        if cfg.family == "gemma2":
            # pair scan: even layers local (static sliding window), odd global
            even = {k: v[0::2] for k, v in stack.items()}
            odd = {k: v[1::2] for k, v in stack.items()}

            def pair_body(h, pw):
                h = shard_activations(h)
                pe, po = pw
                h = _dense_block(pe, h, cfg, pos, window=cfg.window)
                return _dense_block(po, h, cfg, pos, window=0)
            fn = _maybe_remat(pair_body, cfg)

            def step(carry, pw):
                return fn(carry, pw), None
            x, _ = jax.lax.scan(step, x, (even, odd))
        else:
            def body(h, p):
                return _dense_block(p, h, cfg, pos)
            x = _scan_blocks(stack, x, cfg, pos, body)

    x = rms_norm(x, params["final_norm"])
    return x, aux_total


def _constrain_chunk_stack(xc: jnp.ndarray) -> jnp.ndarray:
    """(nc, B, C, d) loss-chunk stack: pin batch(axis 1)->data so the
    backward's dxc never materialises batch-replicated (§Perf iter 2)."""
    am = _ambient_mesh()
    if am is None:
        return xc
    axes = am.axis_names
    da = tuple(a for a in ("pod", "data") if a in axes)
    da_n = int(np.prod([am.shape[a] for a in da])) if da else 1
    if not da or da_n <= 1 or xc.shape[1] % da_n:
        return xc
    return jax.lax.with_sharding_constraint(
        xc, jax.sharding.PartitionSpec(None, da if len(da) > 1 else da[0]))


def _chunked_xent(x: jnp.ndarray, embed: jnp.ndarray, targets: jnp.ndarray,
                  cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cross-entropy streamed over sequence chunks.

    Never materialises the full (B, S, V) logits — per chunk only
    (B, C, V) exists transiently (and is remat'd in the backward pass).
    With V up to 257k this is the difference between ~60 GiB and ~2 GiB of
    temp per device (see EXPERIMENTS.md §Perf iteration 1).
    """
    B, S, d = x.shape
    from .layers import fit_chunk
    C = fit_chunk(S, cfg.loss_chunk)
    nc = S // C
    x = shard_activations(x)
    xc = x.reshape(B, nc, C, d).transpose(1, 0, 2, 3)
    xc = _constrain_chunk_stack(xc)          # (nc, B, C, d): batch on axis 1
    tc = targets.reshape(B, nc, C).transpose(1, 0, 2)

    def step(carry, xt):
        nll_sum, cnt = carry
        xi, ti = xt
        xi = shard_activations(xi)
        logits = jnp.einsum("bsd,vd->bsv", xi, embed.astype(xi.dtype))
        logits = shard_logits(softcap(logits.astype(jnp.float32),
                                      cfg.final_softcap))
        mask = (ti != PAD_ID).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        nll = (logz - gold) * mask
        return (nll_sum + nll.sum(), cnt + mask.sum()), None

    body = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    (nll_sum, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                            jnp.zeros((), jnp.float32)),
                                     (xc, tc))
    return nll_sum, cnt


def forward_train(params: Dict[str, jnp.ndarray], cfg: ModelConfig,
                  batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Returns (loss, metrics). batch: tokens/targets (+frontend embeds)."""
    x, aux_total = forward_hidden(params, cfg, batch)
    B = x.shape[0]
    targets = batch["targets"]
    if cfg.family == "vlm":
        # frontend positions carry no next-token target
        pad = jnp.zeros((B, cfg.n_frontend_tokens), targets.dtype)
        targets = jnp.concatenate([pad, targets], axis=1)
    nll_sum, cnt = _chunked_xent(x, params["embed"], targets, cfg)
    loss = nll_sum / jnp.maximum(cnt, 1.0)
    metrics = {"loss": loss, "aux_loss": aux_total, "tokens": cnt}
    if cfg.family == "moe":
        loss = loss + 0.01 * aux_total
    return loss, metrics
