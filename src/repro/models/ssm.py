"""Mamba-2 (SSD — state-space duality) block: chunked train scan + O(1) decode.

The SSD form (Dao & Gu, arXiv:2405.21060) splits the sequence into chunks of
length ``Q``: inside a chunk the recurrence is evaluated as a masked
decay-weighted attention-like product (MXU-dense), and a ``lax.scan`` carries
the (H, N, P) state across chunks.  Per-chunk work is materialised one chunk
at a time inside the scan (never the full (S/Q, Q, Q) tensor), so memory is
O(B·H·Q²) transient — the TPU-native tiling of the SSD algorithm.

Decode is the plain recurrence: ``h = a·h + B⊗(dt·x)``, ``y = C·h`` — state is
O(B·H·N·P) regardless of context length, which is why the ``long_500k`` cell
runs for the SSM/hybrid archs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import rms_norm


@dataclasses.dataclass(frozen=True)
class SSMDims:
    d_model: int
    d_inner: int     # 2 * d_model (mamba expand=2)
    n_heads: int     # d_inner // head_dim
    head_dim: int    # P
    state: int       # N
    conv_k: int = 4

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.state  # x, B, C share the conv

    @property
    def in_proj_dim(self) -> int:
        # z, x, B, C, dt
        return 2 * self.d_inner + 2 * self.state + self.n_heads


def ssm_param_shapes(dims: SSMDims) -> Dict[str, Tuple[int, ...]]:
    return {
        "norm": (dims.d_model,),
        "in_proj": (dims.d_model, dims.in_proj_dim),
        "conv_w": (dims.conv_k, dims.conv_dim),
        "conv_b": (dims.conv_dim,),
        "A_log": (dims.n_heads,),
        "D": (dims.n_heads,),
        "dt_bias": (dims.n_heads,),
        "out_norm": (dims.d_inner,),
        "out_proj": (dims.d_inner, dims.d_model),
    }


def _split_proj(dims: SSMDims, zxbcdt: jnp.ndarray):
    di, n, h = dims.d_inner, dims.state, dims.n_heads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    B = zxbcdt[..., 2 * di:2 * di + n]
    C = zxbcdt[..., 2 * di + n:2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]
    return z, x, B, C, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d; returns (out, new_state). xbc (B,S,C)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + xbc.shape[1], :].astype(jnp.float32) * w[i][None, None, :]
    out = jax.nn.silu(out + b[None, None, :])
    new_state = xp[:, xp.shape[1] - (k - 1):, :]
    return out.astype(xbc.dtype), new_state


def ssd_chunked(u: jnp.ndarray, log_a: jnp.ndarray, B: jnp.ndarray,
                C: jnp.ndarray, chunk: int = 128,
                h0: Optional[jnp.ndarray] = None):
    """SSD scan. u (B,S,H,P), log_a (B,S,H), B/C (B,S,N) -> y, h_final."""
    Bsz, S, H, P = u.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    u_c = u.reshape(Bsz, nc, Q, H, P)
    la_c = jnp.cumsum(log_a.reshape(Bsz, nc, Q, H), axis=2)  # (B,nc,Q,H)
    B_c = B.reshape(Bsz, nc, Q, N)
    C_c = C.reshape(Bsz, nc, Q, N)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)

    idx = jnp.arange(Q)
    tri = idx[:, None] >= idx[None, :]          # i >= j

    def chunk_step(h_prev, inp):
        uc, lac, bc, cc = inp                    # (B,Q,H,P) (B,Q,H) (B,Q,N) (B,Q,N)
        lac = lac.astype(jnp.float32)
        # intra-chunk: masked decay-weighted "attention"
        g = jnp.einsum("bin,bjn->bij", cc.astype(jnp.float32),
                       bc.astype(jnp.float32))                     # (B,Q,Q)
        # mask the EXPONENT, not the result: exp of the (positive) upper
        # triangle overflows and poisons the backward pass with inf*0 NaNs
        diff = lac[:, :, None, :] - lac[:, None, :, :]             # (B,Qi,Qj,H)
        diff = jnp.where(tri[None, :, :, None], diff, -1e30)
        dec = jnp.exp(diff)
        y_in = jnp.einsum("bij,bijh,bjhp->bihp", g, dec,
                          uc.astype(jnp.float32))
        # inter-chunk: contribution of the carried state
        y_x = jnp.einsum("bin,bih,bhnp->bihp", cc.astype(jnp.float32),
                         jnp.exp(lac), h_prev)
        # state update
        la_end = lac[:, -1:, :]                                    # (B,1,H)
        w = jnp.exp(la_end - lac)                                  # (B,Q,H)
        s_new = jnp.einsum("bjn,bjh,bjhp->bhnp", bc.astype(jnp.float32), w,
                           uc.astype(jnp.float32))
        h = jnp.exp(la_end[:, 0, :])[:, :, None, None] * h_prev + s_new
        return h, (y_in + y_x)

    step = jax.checkpoint(chunk_step,
                          policy=jax.checkpoint_policies.nothing_saveable)
    h_fin, ys = jax.lax.scan(
        step, h0,
        (u_c.transpose(1, 0, 2, 3, 4), la_c.transpose(1, 0, 2, 3),
         B_c.transpose(1, 0, 2, 3), C_c.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    return y, h_fin


def mamba2_block(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
                 dims: SSMDims, chunk: int = 128) -> jnp.ndarray:
    """Training/prefill forward. x (B,S,d) -> (B,S,d)."""
    Bsz, S, _ = x.shape
    h = rms_norm(x, params["norm"])
    zxbcdt = jnp.einsum("bsd,de->bse", h, params["in_proj"].astype(h.dtype))
    z, xs, Bc, Cc, dt = _split_proj(dims, zxbcdt)
    xbc = jnp.concatenate([xs, Bc, Cc], axis=-1)
    xbc, _ = _causal_conv(xbc, params["conv_w"].astype(jnp.float32),
                          params["conv_b"].astype(jnp.float32))
    xs = xbc[..., :dims.d_inner]
    Bc = xbc[..., dims.d_inner:dims.d_inner + dims.state]
    Cc = xbc[..., dims.d_inner + dims.state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"][None, None, :].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    log_a = dt * A[None, None, :]                                   # (B,S,H)
    xh = xs.reshape(Bsz, S, dims.n_heads, dims.head_dim)
    u = xh.astype(jnp.float32) * dt[..., None]
    y, _ = ssd_chunked(u, log_a, Bc, Cc, chunk=chunk)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, dims.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["out_norm"])
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))


def mamba2_decode(params: Dict[str, jnp.ndarray], x_tok: jnp.ndarray,
                  state: Dict[str, jnp.ndarray], dims: SSMDims
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode. x_tok (B,1,d); state = {"h": (B,H,N,P), "conv": (B,k-1,conv_dim)}."""
    h_in = rms_norm(x_tok, params["norm"])
    zxbcdt = jnp.einsum("bsd,de->bse", h_in, params["in_proj"].astype(x_tok.dtype))
    z, xs, Bc, Cc, dt = _split_proj(dims, zxbcdt)
    xbc = jnp.concatenate([xs, Bc, Cc], axis=-1)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"].astype(jnp.float32),
                                   params["conv_b"].astype(jnp.float32),
                                   state["conv"])
    xs = xbc[..., :dims.d_inner]
    Bc = xbc[..., dims.d_inner:dims.d_inner + dims.state]
    Cc = xbc[..., dims.d_inner + dims.state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"][None, None, :].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A[None, None, :])[:, 0]                        # (B,H)
    xh = xs.reshape(xs.shape[0], 1, dims.n_heads, dims.head_dim)
    u = (xh.astype(jnp.float32) * dt[..., None])[:, 0]              # (B,H,P)
    h = state["h"] * a[:, :, None, None] + jnp.einsum(
        "bn,bhp->bhnp", Bc[:, 0].astype(jnp.float32), u)
    y = jnp.einsum("bn,bhnp->bhp", Cc[:, 0].astype(jnp.float32), h)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh[:, 0].astype(jnp.float32)
    y = y.reshape(y.shape[0], 1, dims.d_inner).astype(x_tok.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["out_norm"])
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x_tok.dtype))
    return out, {"h": h, "conv": conv_state}
