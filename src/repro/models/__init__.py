"""models subpackage."""
