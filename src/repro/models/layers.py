"""Model layer library: norms, RoPE, chunked flash attention, GLU MLP.

Pure-functional: params are pytrees of jnp arrays; every constructor returns
``(init_shapes, apply)``-style helpers via plain functions.  Design points:

* **scan-over-layers** friendly: all block params are stacked on a leading
  layer axis by the callers in :mod:`repro.models.transformer`.
* **chunked flash attention** (`flash_attention`): double ``lax.scan`` over
  query and KV chunks with online softmax; the inner body is ``jax.checkpoint``
  ed so residency is O(S·chunk) not O(S²) — the memory_analysis of the
  dry-run reflects real TPU deployability.  Supports causal, sliding-window,
  logit softcap, and cross-attention (no mask).
* **GQA decode** path is plain jnp over the (sharded) KV cache — XLA SPMD
  turns the softmax reductions over a sequence-sharded cache into the
  flash-decoding collective pattern (partial max/sum all-reduce).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Activation sharding constraints (sequence-parallel residual stream)
# ---------------------------------------------------------------------------



def _ambient_mesh():
    from ..launch.mesh import ambient_mesh
    return ambient_mesh()

def shard_activations(x: jnp.ndarray, seq_axis: int = 1) -> jnp.ndarray:
    """Constrain (B, S, d) activations to batch→(pod,data), seq→model.

    Pinning the *saved residual stream* (the tensors the remat policy keeps
    per layer) to a sequence-parallel layout is what keeps per-device
    activation memory O(S/model): without it GSPMD is free to replicate the
    (L, B, S, d) stacked residuals (observed 128 GiB/device on the dry-run —
    EXPERIMENTS.md §Perf).  No-op when tracing without an ambient mesh
    (smoke tests) or when dims don't divide.
    """
    am = _ambient_mesh()
    if am is None:
        return x
    axes = am.axis_names
    da = tuple(a for a in ("pod", "data") if a in axes)
    da_n = int(np.prod([am.shape[a] for a in da])) if da else 1
    mo = "model" if "model" in axes else None
    mo_n = am.shape["model"] if mo else 1
    parts: list = [None] * x.ndim
    if da and x.shape[0] % da_n == 0 and da_n > 1:
        parts[0] = da if len(da) > 1 else da[0]
    if mo and x.ndim >= 3 and x.shape[seq_axis] % mo_n == 0 and mo_n > 1:
        parts[seq_axis] = mo
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*parts))


def shard_logits(x: jnp.ndarray) -> jnp.ndarray:
    """(T, V) or (B, C, V) logits: batch→(pod,data), vocab→model."""
    am = _ambient_mesh()
    if am is None:
        return x
    axes = am.axis_names
    da = tuple(a for a in ("pod", "data") if a in axes)
    da_n = int(np.prod([am.shape[a] for a in da])) if da else 1
    mo_n = am.shape["model"] if "model" in axes else 1
    parts: list = [None] * x.ndim
    if da and x.shape[0] % da_n == 0 and da_n > 1:
        parts[0] = da if len(da) > 1 else da[0]
    if "model" in axes and x.shape[-1] % mo_n == 0 and mo_n > 1:
        parts[-1] = "model"
    if all(p is None for p in parts):
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*parts))


# ---------------------------------------------------------------------------
# Norms / embeddings / rotary
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x (..., S, H, D), positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq[None, :]  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


# ---------------------------------------------------------------------------
# Chunked flash attention (training / prefill)
# ---------------------------------------------------------------------------


def _attn_chunk(q, k, v, qpos, kpos, *, scale: float, causal: bool,
                window: Optional[int], cap: float):
    """One (q_chunk × kv_chunk) online-softmax tile. fp32 accumulation."""
    # q (B, KV, G, Cq, D), k/v (B, KV, Ck, D)
    logits = jnp.einsum("bkgqd,bkcd->bkgqc", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, cap)
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window and window > 0:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(mask[None, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqc,bkcd->bkgqd", p, v,
                   preferred_element_type=jnp.float32)
    return m, l, o


def fit_chunk(total: int, want: int) -> int:
    """Largest chunk <= want that divides total (whisper's 1500 -> 250)."""
    c = max(min(want, total), 1)
    while total % c:
        c -= 1
    return c


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    cap: float = 0.0, q_chunk: int = 256,
                    kv_chunk: int = 512, q_offset: int = 0) -> jnp.ndarray:
    """q (B,S,H,D), k/v (B,T,KV,D) -> (B,S,H,D). Double-scan online softmax."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / float(np.sqrt(D))
    q_chunk = fit_chunk(S, q_chunk)
    kv_chunk = fit_chunk(T, kv_chunk)
    nq, nk = S // q_chunk, T // kv_chunk

    qr = q.reshape(B, nq, q_chunk, KV, G, D).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(B, nk, kv_chunk, KV, D).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nk, kv_chunk, KV, D).transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        qpos = q_offset + iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki_vi_idx):
            m0, l0, o0 = carry
            ki, vi, ik = ki_vi_idx
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)
            m1, l1, o1 = _attn_chunk(qi, ki, vi, qpos, kpos, scale=scale,
                                     causal=causal, window=window, cap=cap)
            m = jnp.maximum(m0, m1)
            a0 = jnp.exp(m0 - m)
            a1 = jnp.exp(m1 - m)
            return (m, l0 * a0 + l1 * a1,
                    o0 * a0[..., None] + o1 * a1[..., None]), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, KV, G, q_chunk, D), jnp.float32)
        body = jax.checkpoint(kv_step, policy=jax.checkpoint_policies.nothing_saveable)
        (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0),
                                    (kr, vr, jnp.arange(nk)))
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qr, jnp.arange(nq)))
    # outs (nq, B, KV, G, q_chunk, D) -> (B, S, H, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, D)
    return out


# ---------------------------------------------------------------------------
# custom-VJP flash attention (recompute backward; saves only o + lse)
#
# Differentiating the double-scan forward saves every inner-step (m, l, acc)
# carry in f32 — ~16 GiB per layer at train_4k (§Perf iteration 1).  The
# canonical fix is the FlashAttention backward: save (q, k, v, o, lse) only
# and recompute logits per tile, giving dq/dk/dv with O(S·chunk) residency.
# ---------------------------------------------------------------------------


def _mask_for(qpos, kpos, causal: bool, window: Optional[int],
              prefix_len: Optional[int]):
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), dtype=bool)
    if causal:
        c = qpos[:, None] >= kpos[None, :]
        if prefix_len:
            c |= kpos[None, :] < prefix_len
        mask &= c
    if window and window > 0:
        w = kpos[None, :] > (qpos[:, None] - window)
        if prefix_len:
            w |= kpos[None, :] < prefix_len
        mask &= w
    return mask


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention_cv(q, k, v, causal, window, cap, q_chunk, kv_chunk,
                       prefix_len):
    o, _ = _flash_fwd_impl(q, k, v, causal, window, cap, q_chunk, kv_chunk,
                           prefix_len)
    return o


def _flash_fwd_impl(q, k, v, causal, window, cap, q_chunk, kv_chunk,
                    prefix_len):
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / float(np.sqrt(D))
    qr = q.reshape(B, S // q_chunk, q_chunk, KV, G, D).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(B, T // kv_chunk, kv_chunk, KV, D).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, T // kv_chunk, kv_chunk, KV, D).transpose(1, 0, 3, 2, 4)
    nk = T // kv_chunk

    def q_step(_, qi_idx):
        qi, iq = qi_idx
        qpos = iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kv_idx):
            m0, l0, o0 = carry
            ki, vi, ik = kv_idx
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)
            logits = jnp.einsum("bkgqd,bkcd->bkgqc", qi, ki,
                                preferred_element_type=jnp.float32) * scale
            logits = softcap(logits, cap)
            mask = _mask_for(qpos, kpos, causal, window, prefix_len)
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m1 = jnp.max(logits, axis=-1)
            p = jnp.exp(logits - m1[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            l1 = p.sum(-1)
            o1 = jnp.einsum("bkgqc,bkcd->bkgqd", p, vi,
                            preferred_element_type=jnp.float32)
            m = jnp.maximum(m0, m1)
            a0, a1 = jnp.exp(m0 - m), jnp.exp(m1 - m)
            return (m, l0 * a0 + l1 * a1,
                    o0 * a0[..., None] + o1 * a1[..., None]), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, KV, G, q_chunk, D), jnp.float32)
        (m, l, o), _ = jax.lax.scan(kv_step, (m0, l0, o0),
                                    (kr, vr, jnp.arange(nk)))
        out = (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (qr, jnp.arange(S // q_chunk)))
    o = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, D)
    lse = lses.transpose(1, 0, 4, 2, 3).reshape(B, S, H)  # (nq,B,KV,G,qc)->(B,S,H)
    return o, lse


def _flash_fwd_rule(q, k, v, causal, window, cap, q_chunk, kv_chunk,
                    prefix_len):
    o, lse = _flash_fwd_impl(q, k, v, causal, window, cap, q_chunk, kv_chunk,
                             prefix_len)
    return o, (q, k, v, o, lse)


def _flash_bwd_rule(causal, window, cap, q_chunk, kv_chunk, prefix_len,
                    res, do):
    q, k, v, o, lse = res
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / float(np.sqrt(D))
    nq, nk = S // q_chunk, T // kv_chunk

    qr = q.reshape(B, nq, q_chunk, KV, G, D).transpose(1, 0, 3, 4, 2, 5)
    do_r = do.reshape(B, nq, q_chunk, KV, G, D).transpose(1, 0, 3, 4, 2, 5)
    o_r = o.reshape(B, nq, q_chunk, KV, G, D).transpose(1, 0, 3, 4, 2, 5)
    lse_r = lse.reshape(B, nq, q_chunk, KV, G).transpose(1, 0, 3, 4, 2)
    kr = k.reshape(B, nk, kv_chunk, KV, D).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nk, kv_chunk, KV, D).transpose(1, 0, 3, 2, 4)

    # delta = rowsum(do * o) (B,KV,G,qc) per q chunk
    delta_r = jnp.einsum("nbkgqd,nbkgqd->nbkgq", do_r.astype(jnp.float32),
                         o_r.astype(jnp.float32))

    def kv_step(dq_acc, kv_idx):
        ki, vi, ik = kv_idx
        kpos = ik * kv_chunk + jnp.arange(kv_chunk)

        def q_step(carry, q_idx):
            dk0, dv0 = carry
            qi, doi, lsei, deltai, iq = q_idx
            qpos = iq * q_chunk + jnp.arange(q_chunk)
            z = jnp.einsum("bkgqd,bkcd->bkgqc", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            if cap and cap > 0:
                t = jnp.tanh(z / cap)
                logits = cap * t
                dz_fac = (1.0 - t * t)          # d logits / d z
            else:
                logits = z
                dz_fac = None
            mask = _mask_for(qpos, kpos, causal, window, prefix_len)
            p = jnp.exp(jnp.where(mask[None, None, None], logits, NEG_INF)
                        - lsei[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            dv = jnp.einsum("bkgqc,bkgqd->bkcd", p, doi.astype(jnp.float32))
            dp = jnp.einsum("bkgqd,bkcd->bkgqc", doi.astype(jnp.float32), vi)
            ds = p * (dp - deltai[..., None])
            if dz_fac is not None:
                ds = ds * dz_fac
            dq_i = jnp.einsum("bkgqc,bkcd->bkgqd", ds, ki) * scale
            dk = jnp.einsum("bkgqc,bkgqd->bkcd", ds, qi) * scale
            return (dk0 + dk, dv0 + dv), dq_i.astype(q.dtype)

        zero_k = jnp.zeros((B, KV, kv_chunk, D), jnp.float32)
        (dk, dv), dq_parts = jax.lax.scan(
            q_step, (zero_k, zero_k),
            (qr, do_r, lse_r, delta_r, jnp.arange(nq)))
        # dq accumulates as a carry (never nk stacked dq-sized tensors)
        return dq_acc + dq_parts.astype(jnp.float32), (dk, dv)

    dq0 = jnp.zeros((nq, B, KV, G, q_chunk, D), jnp.float32)
    dq_acc, (dks, dvs) = jax.lax.scan(kv_step, dq0,
                                      (kr, vr, jnp.arange(nk)))
    dq = dq_acc.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, D)
    dk = dks.transpose(1, 0, 3, 2, 4).reshape(B, T, KV, D)
    dv = dvs.transpose(1, 0, 3, 2, 4).reshape(B, T, KV, D)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_cv.defvjp(_flash_fwd_rule, _flash_bwd_rule)


# ---------------------------------------------------------------------------
# Decode attention (single new token vs cache)
# ---------------------------------------------------------------------------


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     length: jnp.ndarray, *, window: Optional[int] = None,
                     cap: float = 0.0) -> jnp.ndarray:
    """q (B,H,D), cache (B,T,KV,D), length (B,) -> (B,H,D).

    Plain jnp: under pjit with a sequence-sharded cache, XLA SPMD emits the
    distributed flash-decoding pattern (all-reduce of partial max/sum).
    """
    B, H, D = q.shape
    T, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / float(np.sqrt(D))
    qg = q.reshape(B, KV, G, D)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    logits = softcap(logits, cap)
    pos = jnp.arange(T)[None, :]
    mask = pos < length[:, None]
    if window and window > 0:
        mask &= pos >= (length[:, None] - window)
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    out = out / jnp.maximum(p.sum(-1)[..., None], 1e-30)
    return out.reshape(B, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(
        jnp.einsum("...d,df->...f", x, w_up)), w_down)
