"""Static invariant analysis for the union-sampling engine.

Three layers guard the invariants the runtime tests pin:

* **Layer 1 — AST lint** (:mod:`repro.analysis.lint`,
  :mod:`repro.analysis.rules`): stdlib-only rules over the ``src/repro``
  tree — jit-boundary hazards (Python control flow on tracers, host
  escapes), fixed-point discipline in the planner, nondeterminism in
  traced code, int32 packed-key overflow guards, SamplerStats width
  agreement across the host/device/sharded carries, and host-degrade
  branches that forget ``record_fallback``.
* **Layer 2 — jaxpr audit** (:mod:`repro.analysis.jaxpr_audit`,
  :mod:`repro.analysis.recompile`): traces the real fused round programs
  with abstract/cheap inputs and checks structural invariants without
  sampling — device-vs-host-twin primitive inventories (RNG parity, no
  stray collectives), shard_map collective count consistency, donated
  carry aliasing, and one-trace-per-capacity-class compile behaviour.
* **Layer 3 — concurrency lint** (:mod:`repro.analysis.rules.locks`):
  lock discipline for the serve tier and the obs registry.

Layers 1 and 3 import only the standard library so the CI gate can run
them without jax installed; layer 2 imports jax lazily.
"""

from .findings import Baseline, Finding  # noqa: F401
from .lint import run_lint  # noqa: F401
