"""Layer 2: jaxpr structural audit of the fused round programs.

The AST lint (layer 1) reasons about *source*; this layer reasons about
the *traced programs*.  It builds real ``JaxUnionSampler`` /
``ShardedUnionSampler`` engines on small workloads, traces their fused
device loop and host-twin round program with abstract inputs (no
execution, no XLA compile beyond ``lower``), and checks structural
invariants that source-level lint cannot see:

* **RNG parity** — the device loop and its host twin must draw from the
  same family of RNG primitives.  A threefry primitive on one side only
  means the two paths would consume randomness differently and the
  host/device equivalence tests are comparing different streams.
* **Collective discipline** — the unsharded engine's programs must
  contain *zero* collectives; the world=1 sharded device loop must
  contain exactly the host round program's collective sequence plus the
  single trailing banking ``all_gather`` (the "one tiny exchange" the
  sharded round body documents).
* **Donated-buffer aliasing** — the device loop is jitted with
  ``donate_argnums`` on the carry; the lowered program must actually
  alias those inputs to outputs (``tf.aliasing_output`` /
  ``jax.buffer_donor`` in the StableHLO), otherwise every round copies
  the bank.
* **Loop fusion** — the device program must contain a ``while``
  primitive (the rounds are fused on device, not unrolled by the host).

Everything returns :class:`~repro.analysis.findings.Finding` objects so
the gate script can merge them with the AST layer's output.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Sequence, Tuple

from .findings import Finding

# exchange / mesh primitives (jax.lax collectives, by primitive name)
COLLECTIVE_PRIMITIVES = frozenset({
    "all_gather", "all_to_all", "psum", "psum_scatter", "reduce_scatter",
    "ppermute", "pmax", "pmin", "pgather", "axis_index", "pdot",
})

# substrings identifying RNG primitives (threefry2x32 on CPU paths,
# random_bits / random_seed / random_wrap under new-style keys)
_RNG_MARKERS = ("threefry", "random", "rng")

# StableHLO markers for donated/aliased buffers across jax versions
_DONATION_TOKENS = ("tf.aliasing_output", "jax.buffer_donor")


# -- primitive inventory ------------------------------------------------------

def _sub_jaxprs(val: Any) -> Iterable[Any]:
    """Duck-typed walk into eqn params that hold nested jaxprs.

    ``pjit`` carries a ClosedJaxpr, ``while``/``cond``/``scan`` carry
    (lists of) ClosedJaxprs; shard_map wraps another jaxpr again.  We
    recognise them structurally so this keeps working across jax
    versions: anything with ``.eqns`` is a Jaxpr, anything with
    ``.jaxpr`` is a ClosedJaxpr.
    """
    if hasattr(val, "eqns"):
        yield val
    elif hasattr(val, "jaxpr"):
        yield from _sub_jaxprs(val.jaxpr)
    elif isinstance(val, (tuple, list)):
        for item in val:
            yield from _sub_jaxprs(item)
    elif isinstance(val, dict):
        for item in val.values():
            yield from _sub_jaxprs(item)


def collect_primitives(jaxpr: Any) -> List[str]:
    """Depth-first primitive names of ``jaxpr`` including all sub-jaxprs.

    Depth-first at the equation site preserves program order for the
    collective-sequence check (a ``while`` body's collectives appear
    once, where the loop sits).
    """
    if hasattr(jaxpr, "jaxpr"):            # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    names: List[str] = []
    for eqn in jaxpr.eqns:
        names.append(eqn.primitive.name)
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                names.extend(collect_primitives(sub))
    return names


def rng_kinds(prims: Sequence[str]) -> frozenset:
    return frozenset(p for p in prims
                     if any(m in p for m in _RNG_MARKERS))


def collective_sequence(prims: Sequence[str]) -> List[str]:
    return [p for p in prims if p in COLLECTIVE_PRIMITIVES]


def _donated(lowered_text: str) -> bool:
    return any(tok in lowered_text for tok in _DONATION_TOKENS)


def _finding(label: str, message: str, detail: str) -> Finding:
    return Finding(rule="jaxpr-audit", path=f"<audit:{label}>", line=0,
                   scope=label, message=message, detail=detail)


# -- engine builders ----------------------------------------------------------

def build_engine(workload: str = "uq1", plan: str = "static",
                 world: int = 0, round_batch: int = 256):
    """Build the real engine a tier-1 run would use, on a small workload.

    ``world=0`` returns an unsharded ``JaxUnionSampler``; ``world>=1``
    builds the mesh path (``ShardedUnionSampler``) with that many
    shards.
    """
    from repro.core.framework import estimate_union, warmup
    from repro.core.union_sampler import SetUnionSampler
    from repro.data import workloads

    if workload == "uq1":
        wl = workloads.uq1(scale=0.02, overlap=0.4, seed=0, n_joins=2)
    elif workload == "uq4":
        wl = workloads.uq4(scale=0.04, seed=0)
    else:
        raise ValueError(f"unknown audit workload {workload!r}")
    cover = estimate_union(warmup(wl.cat, wl.joins, method="exact")
                           .oracle).cover
    kwargs: Dict[str, Any] = {}
    if world:
        from repro.core.sharding import make_sampler_mesh
        kwargs["mesh"] = make_sampler_mesh(world=world)
    sampler = SetUnionSampler(wl.cat, wl.joins, cover, seed=11,
                              backend="jax", round_batch=round_batch,
                              fused_rounds="device", plan=plan, **kwargs)
    return sampler._engine


# -- audits -------------------------------------------------------------------

def _device_trace_args(eng, C: int) -> Tuple:
    import jax.numpy as jnp

    eng._ensure_device_inputs()
    return (eng._init_state(), eng._out_buffer(C), jnp.int32(8),
            eng._probs_base)


def _host_twin_args(eng) -> Tuple:
    import jax
    import jax.numpy as jnp

    nj = len(eng.order)
    args = (eng._probs_base, jnp.zeros(nj, dtype=bool),
            jnp.zeros(nj, jnp.int32), jnp.int32(4), jax.random.PRNGKey(0))
    if eng.plan == "adaptive":
        args = args + (jnp.asarray(eng._ema_seed),
                       jnp.zeros(nj, jnp.int32))
    return args


def audit_unsharded(eng, label: str, C: int = 1024
                    ) -> Tuple[List[Finding], Dict[str, Any]]:
    """Structural checks on one ``JaxUnionSampler``'s traced programs."""
    import jax

    dev_args = _device_trace_args(eng, C)
    loop = eng._loop_for(C)
    dev_prims = collect_primitives(jax.make_jaxpr(loop)(*dev_args))
    host_prims = collect_primitives(
        jax.make_jaxpr(eng._round_impl)(*_host_twin_args(eng)))

    findings: List[Finding] = []
    dev_rng, host_rng = rng_kinds(dev_prims), rng_kinds(host_prims)
    if dev_rng != host_rng:
        findings.append(_finding(
            label, "RNG primitive families differ between the device loop "
            "and its host twin",
            f"device={sorted(dev_rng)} host={sorted(host_rng)}"))
    if not dev_rng:
        findings.append(_finding(
            label, "device loop draws no RNG primitives", "rng:none"))
    for side, prims in (("device", dev_prims), ("host", host_prims)):
        cols = collective_sequence(prims)
        if cols:
            findings.append(_finding(
                label, f"unsharded {side} program contains collectives",
                f"{side}:{cols}"))
    if "while" not in dev_prims:
        findings.append(_finding(
            label, "device program has no fused while loop — rounds would "
            "be host-unrolled", "no-while"))
    if not _donated(loop.lower(*dev_args).as_text()):
        findings.append(_finding(
            label, "device loop carry is not donated — every call copies "
            "the bank buffers", "no-donation"))
    report = {
        "label": label, "kind": "unsharded", "plan": eng.plan,
        "device_primitives": len(dev_prims),
        "host_primitives": len(host_prims),
        "rng": sorted(dev_rng), "collectives": [],
        "donated": True, "findings": len(findings),
    }
    return findings, report


def audit_sharded(eng, label: str, C: int = 1024
                  ) -> Tuple[List[Finding], Dict[str, Any]]:
    """World=1 mesh invariants on one ``ShardedUnionSampler``.

    The device loop must run the host round program's collective
    sequence plus exactly one trailing banking ``all_gather`` per round
    body — the single exchange the shard-major water filling needs.
    """
    import jax
    import jax.numpy as jnp

    eng._ensure_device_inputs()
    run = eng._loop_for(C)
    prog = getattr(run, "_prog", None)
    findings: List[Finding] = []
    if prog is None:
        return [_finding(label, "sharded loop does not expose its jitted "
                         "program (run._prog)", "no-prog")], {
            "label": label, "kind": "sharded", "findings": 1}
    state = eng._init_state()
    shr = {k: state[k] for k in ("bank", "bank_head", "bank_count")}
    rep = {k: state[k] for k in run._rep_keys}
    dev_args = (shr, rep, eng._out_buffer(C), jnp.int32(8),
                eng._probs_base, run._st_global)
    dev_prims = collect_primitives(jax.make_jaxpr(prog)(*dev_args))
    # mesh round program: (probs, dead, carry, extra, key, st[, ema, gcount])
    twin = _host_twin_args(eng)
    host_args = twin[:5] + (run._st_global,) + twin[5:]
    host_prims = collect_primitives(
        jax.make_jaxpr(eng._round_prog)(*host_args))

    dev_cols = collective_sequence(dev_prims)
    host_cols = collective_sequence(host_prims)
    if dev_cols != host_cols + ["all_gather"]:
        findings.append(_finding(
            label, "sharded device loop collective sequence is not the "
            "host round sequence plus one banking all_gather",
            f"device={dev_cols} host={host_cols}"))
    dev_rng, host_rng = rng_kinds(dev_prims), rng_kinds(host_prims)
    if dev_rng != host_rng:
        findings.append(_finding(
            label, "RNG primitive families differ between the sharded "
            "device loop and the mesh round program",
            f"device={sorted(dev_rng)} host={sorted(host_rng)}"))
    if "while" not in dev_prims:
        findings.append(_finding(
            label, "sharded device program has no fused while loop",
            "no-while"))
    if not _donated(prog.lower(*dev_args).as_text()):
        findings.append(_finding(
            label, "sharded loop carry (bank shards + output) is not "
            "donated", "no-donation"))
    report = {
        "label": label, "kind": "sharded", "plan": eng.plan,
        "device_primitives": len(dev_prims),
        "host_primitives": len(host_prims),
        "rng": sorted(dev_rng), "collectives": dev_cols,
        "donated": True, "findings": len(findings),
    }
    return findings, report


# default audit matrix: both plan regimes on the acyclic 2-join union,
# the cyclic union, and the world=1 mesh path
DEFAULT_AUDITS: Tuple[Tuple[str, Dict[str, Any]], ...] = (
    ("uq1-static", dict(workload="uq1", plan="static")),
    ("uq1-adaptive", dict(workload="uq1", plan="adaptive")),
    ("uq4-static", dict(workload="uq4", plan="static")),
    ("uq1-sharded-w1", dict(workload="uq1", plan="static", world=1)),
)


def run_jaxpr_audit(audits: Sequence[Tuple[str, Dict[str, Any]]] = None
                    ) -> Tuple[List[Finding], List[Dict[str, Any]]]:
    """Run the audit matrix; returns (findings, per-audit reports)."""
    findings: List[Finding] = []
    reports: List[Dict[str, Any]] = []
    for label, spec in (audits if audits is not None else DEFAULT_AUDITS):
        eng = build_engine(**spec)
        if spec.get("world"):
            f, r = audit_sharded(eng, label)
        else:
            f, r = audit_unsharded(eng, label)
        findings.extend(f)
        reports.append(r)
    return findings, reports
