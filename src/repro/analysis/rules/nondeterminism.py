"""Rule: wall-clock / host-RNG nondeterminism inside traced functions.

The engine's parity story (device loop vs host twin, record-mode replay)
requires traced programs to be pure functions of their inputs and the
threaded PRNG keys.  A ``time.time()`` / ``datetime.now()`` /
``np.random`` / ``random`` / ``uuid`` call inside a traced function is
baked in at *trace* time — the program replays one frozen sample of it,
differs across retraces, and silently breaks bitwise pins.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from ..findings import Finding
from ..lint import Rule, SourceModule, attr_chain

_BANNED_CHAINS = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "os.urandom", "uuid.uuid1", "uuid.uuid4",
    "secrets.token_bytes", "secrets.randbits",
}
_BANNED_PREFIXES = ("np.random.", "numpy.random.", "random.",
                    "datetime.now", "datetime.utcnow", "datetime.today",
                    "datetime.datetime.now", "datetime.datetime.utcnow",
                    "datetime.date.today")


class NondeterminismRule(Rule):
    name = "nondeterminism"
    description = ("wall-clock / host-RNG / uuid calls inside traced "
                   "functions (frozen at trace time)")

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        out: List[Finding] = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = mod.in_traced(node)
            if fn is None:
                continue
            chain = attr_chain(node.func)
            if not chain:
                continue
            hit = chain in _BANNED_CHAINS or any(
                chain == p.rstrip(".") or chain.startswith(p)
                for p in _BANNED_PREFIXES)
            if hit:
                out.append(Finding(
                    rule=self.name, path=mod.rel, line=node.lineno,
                    scope=mod.qualname(fn),
                    message=(f"nondeterministic call `{chain}()` inside "
                             "traced function is frozen at trace time"),
                    detail=chain))
        return out
