"""Rule: SamplerStats counter-vector widths must agree everywhere.

The device loop carries a ``len(_STAT_FIELDS)``-wide int32 stats vector
and a ``(n_pieces, len(PIECE_STAT_FIELDS))`` telemetry matrix; the host
twin, the sharded engine and the telemetry fold all assume those widths.
A field added to one stack literal but not the constants (or vice versa)
shears the fold silently — counters land in the wrong buckets.

Project-wide checks:

1. every ``_STAT_FIELDS`` name is a real ``SamplerStats`` dataclass
   field (renames break the snapshot fold);
2. no module *re-defines* ``_STAT_FIELDS`` / ``PIECE_STAT_FIELDS`` —
   the sharded engine and estimators must import the canonical tuples;
3. in modules using the constants, stack literals assigned to
   ``stats*`` / ``pstats*`` names must have exactly
   ``len(_STAT_FIELDS)`` / ``len(PIECE_STAT_FIELDS)`` elements.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from ..findings import Finding
from ..lint import Rule, SourceModule, attr_chain

_CANON_SUFFIX = "backends/jax_backend.py"
_STATS_NAME = re.compile(r"^stats\d*$")
_PSTATS_NAME = re.compile(r"^pstats\d*$")


def _module_tuple(mod: SourceModule, name: str
                  ) -> Optional[Tuple[ast.Assign, List[str]]]:
    for node in mod.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == name \
                and isinstance(node.value, (ast.Tuple, ast.List)):
            vals = [e.value for e in node.value.elts
                    if isinstance(e, ast.Constant)]
            return node, [v for v in vals if isinstance(v, str)]
    return None


def _dataclass_fields(mod: SourceModule, cls_name: str) -> List[str]:
    for cls in mod.classes:
        if cls.name != cls_name:
            continue
        return [stmt.target.id for stmt in cls.body
                if isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)]
    return []


def _stack_width(value: ast.AST) -> Optional[Tuple[int, int]]:
    """(n_elements, lineno) of a jnp/np.stack([...]) inside ``value``."""
    for sub in ast.walk(value):
        if not isinstance(sub, ast.Call):
            continue
        chain = attr_chain(sub.func)
        if chain.rsplit(".", 1)[-1] != "stack":
            continue
        if sub.args and isinstance(sub.args[0], (ast.List, ast.Tuple)):
            return len(sub.args[0].elts), sub.lineno
    return None


class StatsWidthRule(Rule):
    name = "stats-width"
    description = ("SamplerStats / _STAT_FIELDS / PIECE_STAT_FIELDS width "
                   "and provenance agreement across engines")

    def check_project(self, mods: Sequence[SourceModule]
                      ) -> Iterable[Finding]:
        canon = next((m for m in mods if m.rel.endswith(_CANON_SUFFIX)),
                     None)
        stats_holder = next(
            (m for m in mods if m.rel.endswith("core/union_sampler.py")),
            None)
        if canon is None:
            return ()               # not analyzing the engine tree
        out: List[Finding] = []
        widths: Dict[str, int] = {}
        for const in ("_STAT_FIELDS", "PIECE_STAT_FIELDS"):
            found = _module_tuple(canon, const)
            if found is None:
                out.append(Finding(
                    rule=self.name, path=canon.rel, line=1,
                    scope="<module>",
                    message=f"canonical `{const}` tuple not found",
                    detail=f"missing:{const}"))
                continue
            node, names = found
            widths[const] = len(names)
            # (1) _STAT_FIELDS names must be SamplerStats dataclass fields
            if const == "_STAT_FIELDS" and stats_holder is not None:
                fields = set(_dataclass_fields(stats_holder, "SamplerStats"))
                for n in names:
                    if fields and n not in fields:
                        out.append(Finding(
                            rule=self.name, path=canon.rel,
                            line=node.lineno, scope="<module>",
                            message=(f"`_STAT_FIELDS` entry {n!r} is not a "
                                     "SamplerStats dataclass field"),
                            detail=f"field:{n}"))
        # (2) shadow re-definitions elsewhere
        for mod in mods:
            if mod is canon:
                continue
            for const in ("_STAT_FIELDS", "PIECE_STAT_FIELDS"):
                found = _module_tuple(mod, const)
                if found is not None:
                    out.append(Finding(
                        rule=self.name, path=mod.rel,
                        line=found[0].lineno, scope="<module>",
                        message=(f"`{const}` re-defined here; import the "
                                 "canonical tuple from jax_backend"),
                        detail=f"shadow:{const}"))
        # (3) stack-literal widths in modules that use the constants
        for mod in mods:
            if "_STAT_FIELDS" not in mod.text:
                continue
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Assign) \
                        or len(node.targets) != 1 \
                        or not isinstance(node.targets[0], ast.Name):
                    continue
                tname = node.targets[0].id
                want = None
                const = None
                if _STATS_NAME.match(tname):
                    const, want = "_STAT_FIELDS", widths.get("_STAT_FIELDS")
                elif _PSTATS_NAME.match(tname):
                    const = "PIECE_STAT_FIELDS"
                    want = widths.get("PIECE_STAT_FIELDS")
                if want is None:
                    continue
                got = _stack_width(node.value)
                if got is None:
                    continue
                n, line = got
                if n != want:
                    out.append(Finding(
                        rule=self.name, path=mod.rel, line=line,
                        scope=mod.scope_of(node),
                        message=(f"stack literal assigned to `{tname}` has "
                                 f"{n} elements but `{const}` has {want}"),
                        detail=f"width:{tname}:{n}"))
        return out
