"""Rule: float contamination in fixed-point planner arithmetic.

Functions marked ``# analysis: fixed-point`` (the planner's
``budget_for`` / ``ema_update`` and any future device-carried integer
arithmetic) must stay bit-identical between the numpy host twin and the
jnp device program.  That holds only while every operation is integer:
a float literal, a true division, or an f64-promoting cast silently
drifts the two sides apart (numpy promotes to float64, jax to float32).

Flags, inside marked functions: float/complex literals, ``/`` (true
division), ``float()`` / ``np.float64`` / ``jnp.float64`` /
``np.float32`` / ``jnp.float32`` conversion calls, ``.astype(...)`` to a
float dtype, and ``**`` with a float operand.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from ..findings import Finding
from ..lint import Rule, SourceModule, attr_chain

_FLOAT_CASTS = {"float", "float16", "float32", "float64", "double"}


def _is_float_const(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and isinstance(
        node.value, (float, complex))


class FixedPointRule(Rule):
    name = "f64-in-planner"
    description = ("float literals / true division / float casts inside "
                   "`# analysis: fixed-point` functions")

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        out: List[Finding] = []
        for fn in mod.defs:
            if not mod.has_marker(fn, "fixed-point"):
                continue
            scope = mod.qualname(fn)
            for node in ast.walk(fn):
                if _is_float_const(node):
                    out.append(Finding(
                        rule=self.name, path=mod.rel, line=node.lineno,
                        scope=scope,
                        message=f"float literal {node.value!r} in "
                                "fixed-point function",
                        detail=f"literal:{node.value!r}"))
                elif isinstance(node, ast.BinOp) and isinstance(
                        node.op, ast.Div):
                    out.append(Finding(
                        rule=self.name, path=mod.rel, line=node.lineno,
                        scope=scope,
                        message="true division `/` in fixed-point function "
                                "(use `//` or shifts)",
                        detail="div"))
                elif isinstance(node, ast.BinOp) and isinstance(
                        node.op, ast.Pow) and (
                        _is_float_const(node.left)
                        or _is_float_const(node.right)):
                    out.append(Finding(
                        rule=self.name, path=mod.rel, line=node.lineno,
                        scope=scope,
                        message="float power in fixed-point function",
                        detail="pow"))
                elif isinstance(node, ast.Call):
                    chain = attr_chain(node.func)
                    tail = chain.rsplit(".", 1)[-1]
                    if tail in _FLOAT_CASTS:
                        out.append(Finding(
                            rule=self.name, path=mod.rel, line=node.lineno,
                            scope=scope,
                            message=f"float cast `{chain}()` in "
                                    "fixed-point function",
                            detail=f"cast:{chain}"))
                    elif tail == "astype" and node.args and any(
                            (isinstance(a, ast.Attribute)
                             and a.attr in _FLOAT_CASTS)
                            or (isinstance(a, ast.Name)
                                and a.id in _FLOAT_CASTS)
                            for a in node.args):
                        out.append(Finding(
                            rule=self.name, path=mod.rel, line=node.lineno,
                            scope=scope,
                            message="`.astype(float...)` in fixed-point "
                                    "function",
                            detail="astype"))
        return out
