"""Rule registry for the layer-1/3 AST lint engine (stdlib-only)."""

from typing import List

from ..lint import Rule
from .fallbacks import MissingFallbackRule
from .fixed_point import FixedPointRule
from .host_escape import EstimatorPullRule, HostEscapeRule
from .int32_packing import Int32PackingRule
from .locks import LockDisciplineRule
from .nondeterminism import NondeterminismRule
from .stats_width import StatsWidthRule
from .tracer_flow import TracerFlowRule


def all_rules() -> List[Rule]:
    return [
        TracerFlowRule(),
        HostEscapeRule(),
        EstimatorPullRule(),
        FixedPointRule(),
        NondeterminismRule(),
        Int32PackingRule(),
        StatsWidthRule(),
        MissingFallbackRule(),
        LockDisciplineRule(),
    ]


def rule_catalog() -> List[dict]:
    return [{"name": r.name, "description": r.description}
            for r in all_rules()]
