"""Layer-3 rule: lock discipline in the serve tier and obs registry.

Two hazards, both scoped per class:

* **Unlocked writes to guarded attributes.**  If a method writes
  ``self.x`` inside a ``with self._lock:`` block, ``x`` is part of that
  lock's protected state; any *other* write to ``self.x`` outside a lock
  block (``__init__`` excepted — no concurrent access before the object
  escapes the constructor) is a data race with the guarded readers.

* **Blocking queue/thread operations while holding a lock.**  A
  ``q.get()`` / ``q.put(item)`` without a ``timeout`` (or
  ``block=False``), or a zero-argument ``.join()``, executed inside a
  ``with self._lock:`` block can deadlock against a producer/drain
  thread that needs the same lock to make progress — the exact shape of
  the ``sample_async`` drain in ``serve/service.py``.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set, Tuple

from ..findings import Finding
from ..lint import Rule, SourceModule, attr_chain

_LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore",
               "BoundedSemaphore"}


def _self_attr(node: ast.AST) -> str:
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return ""


def _lock_attrs(cls: ast.ClassDef) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            tail = attr_chain(node.value.func).rsplit(".", 1)[-1]
            if tail in _LOCK_CTORS:
                for tgt in node.targets:
                    attr = _self_attr(tgt)
                    if attr:
                        names.add(attr)
    return names


def _with_lock_blocks(meth: ast.AST, locks: Set[str]
                      ) -> List[Tuple[str, ast.With]]:
    out: List[Tuple[str, ast.With]] = []
    for node in ast.walk(meth):
        if not isinstance(node, (ast.With, ast.AsyncWith)):
            continue
        for item in node.items:
            expr = item.context_expr
            if isinstance(expr, ast.Call):
                expr = expr.func            # self._lock.acquire-style
            attr = _self_attr(expr)
            if attr in locks:
                out.append((attr, node))
    return out


class LockDisciplineRule(Rule):
    name = "lock-discipline"
    description = ("guarded attributes written outside the lock; blocking "
                   "queue/join calls while holding a lock")

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        out: List[Finding] = []
        for cls in mod.classes:
            locks = _lock_attrs(cls)
            if not locks:
                continue
            methods = [n for n in cls.body
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))]
            guarded: Dict[str, int] = {}      # attr -> first guarded line
            locked_nodes: Set[int] = set()    # ids of nodes under a lock
            for meth in methods:
                for _lname, blk in _with_lock_blocks(meth, locks):
                    for sub in ast.walk(blk):
                        locked_nodes.add(id(sub))
                        if isinstance(sub, (ast.Assign, ast.AugAssign)):
                            tgts = (sub.targets
                                    if isinstance(sub, ast.Assign)
                                    else [sub.target])
                            for tgt in tgts:
                                attr = _self_attr(tgt)
                                if attr and attr not in locks:
                                    guarded.setdefault(attr, sub.lineno)
            # unlocked writes to guarded attrs (outside __init__)
            for meth in methods:
                if meth.name in ("__init__", "__new__"):
                    continue
                for sub in ast.walk(meth):
                    if id(sub) in locked_nodes:
                        continue
                    if not isinstance(sub, (ast.Assign, ast.AugAssign)):
                        continue
                    tgts = (sub.targets if isinstance(sub, ast.Assign)
                            else [sub.target])
                    for tgt in tgts:
                        attr = _self_attr(tgt)
                        if attr and attr in guarded:
                            out.append(Finding(
                                rule=self.name, path=mod.rel,
                                line=sub.lineno,
                                scope=mod.qualname(meth),
                                message=(f"`self.{attr}` is written under "
                                         "the lock elsewhere (line "
                                         f"{guarded[attr]}) but written "
                                         "here without it"),
                                detail=f"unlocked:{attr}"))
            # blocking queue/thread ops while holding a lock
            for meth in methods:
                for _lname, blk in _with_lock_blocks(meth, locks):
                    for sub in ast.walk(blk):
                        if not isinstance(sub, ast.Call) \
                                or not isinstance(sub.func, ast.Attribute):
                            continue
                        tail = sub.func.attr
                        kwargs = {kw.arg for kw in sub.keywords}
                        if "timeout" in kwargs or "block" in kwargs:
                            continue
                        recv = attr_chain(sub.func.value)
                        hazard = ""
                        if tail == "put" and sub.args:
                            hazard = "blocking put()"
                        elif tail == "get" and not sub.args:
                            hazard = "blocking get()"
                        elif tail == "join" and not sub.args:
                            hazard = "join()"
                        if not hazard or recv.endswith(tuple(locks)):
                            continue
                        out.append(Finding(
                            rule=self.name, path=mod.rel, line=sub.lineno,
                            scope=mod.qualname(meth),
                            message=(f"{hazard} on `{recv}` without a "
                                     "timeout while holding "
                                     f"`self.{_lname}` can deadlock the "
                                     "drain thread"),
                            detail=f"blocking:{recv}.{tail}"))
        return out
