"""Rule: host-degrade branches must emit ``repro_engine_fallback_total``.

Every place the engine silently degrades — backend substitution,
unsupported-mode rerouting, host-oracle fallback — warns the user.  The
observability contract (DESIGN.md §10) says each such branch *also*
calls :func:`repro.obs.record_fallback` so operators see degrades in
metrics, not just in stderr scrollback.

The rule anchors on the warning: any ``warnings.warn(...)`` (or bare
``warn(...)``) whose message text reads like a degrade ("fall back",
"fallback", "falls back", "degrad…") inside a function that never calls
``record_fallback`` is a silent-degrade branch.
"""

from __future__ import annotations

import ast
import re
from typing import Iterable, List

from ..findings import Finding
from ..lint import Rule, SourceModule, attr_chain

_DEGRADE_RE = re.compile(r"fall\w*[\s-]*back|fallback|degrad", re.I)


def _literal_text(node: ast.AST) -> str:
    """Concatenated string-constant content of a warn() argument."""
    parts: List[str] = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            parts.append(sub.value)
    return " ".join(parts)


class MissingFallbackRule(Rule):
    name = "missing-fallback"
    description = ("degrade-path warnings.warn without a record_fallback "
                   "call in the same function")

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        out: List[Finding] = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = attr_chain(node.func)
            if chain not in ("warn", "warnings.warn"):
                continue
            if not node.args:
                continue
            text = _literal_text(node.args[0])
            if not _DEGRADE_RE.search(text):
                continue
            fn = mod.enclosing_function(node)
            haystack = fn if fn is not None else mod.tree
            has_record = any(
                isinstance(c, ast.Call)
                and attr_chain(c.func).rsplit(".", 1)[-1] == "record_fallback"
                for c in ast.walk(haystack))
            if has_record:
                continue
            core = re.sub(r"\s+", " ", text)[:60]
            out.append(Finding(
                rule=self.name, path=mod.rel, line=node.lineno,
                scope=mod.scope_of(node),
                message=("degrade warning without obs.record_fallback in "
                         f"the same function: \"{core}...\""),
                detail=core[:40]))
        return out
