"""Rule: Python control flow on tracers inside traced functions.

``if``/``while``/ternary tests that depend on a traced function's array
arguments (or on the result of a ``jnp``/``jax`` call) execute *host*
Python during tracing: at best they bake one branch into the program, at
worst they raise ``TracerBoolConversionError`` at runtime.  Structural
``is None`` / ``is not None`` dispatch on optional arguments is the one
sanctioned pattern (it is static at trace time) and is excluded.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from ..findings import Finding
from ..lint import Rule, SourceModule, attr_chain


def _is_none_check(test: ast.AST) -> bool:
    while isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        test = test.operand
    if isinstance(test, ast.BoolOp):
        return all(_is_none_check(v) for v in test.values)
    return (isinstance(test, ast.Compare)
            and all(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops))


_SCALAR_ANNOTATIONS = {"bool", "int", "float", "str", "None"}
_STATIC_ATTRS = {"shape", "ndim", "dtype"}


def _is_static_annotation(ann: ast.AST) -> bool:
    """Python-scalar annotations declare static (non-tracer) config:
    ``bool`` / ``int`` / ``float`` / ``str``, ``Optional[...]`` and
    ``... | None`` unions of those."""
    if isinstance(ann, ast.Constant):
        return ann.value is None
    if isinstance(ann, ast.Name):
        return ann.id in _SCALAR_ANNOTATIONS
    if isinstance(ann, ast.Subscript):
        base = ann.value
        if isinstance(base, ast.Name) and base.id in ("Optional", "Union"):
            inner = ann.slice
            elts = inner.elts if isinstance(inner, ast.Tuple) else [inner]
            return all(_is_static_annotation(e) for e in elts)
        return False
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        return (_is_static_annotation(ann.left)
                and _is_static_annotation(ann.right))
    return False


def _tracer_params(mod: SourceModule, fn: ast.FunctionDef) -> Set[str]:
    """Parameters of ``fn`` and of its traced ancestors (closure tracers).
    Parameters annotated with Python scalar types are static config, not
    tracers, and are excluded."""
    names: Set[str] = set()
    traced = mod.traced_functions()
    cur = fn
    while cur is not None:
        if id(cur) in traced:
            args = cur.args
            for a in (args.posonlyargs + args.args + args.kwonlyargs):
                if a.arg in ("self", "cls"):
                    continue
                if a.annotation is not None \
                        and _is_static_annotation(a.annotation):
                    continue
                names.add(a.arg)
        cur = mod.enclosing_function(cur)
    return names


def _offender(test: ast.AST, params: Set[str],
              mod: Optional[SourceModule] = None) -> str:
    """Stable token for what makes the test tracer-dependent ('' = clean)."""
    for node in ast.walk(test):
        if isinstance(node, ast.Call):
            chain = attr_chain(node.func)
            root = chain.split(".", 1)[0]
            if root in ("jnp", "jax", "lax"):
                return chain
    for node in ast.walk(test):
        if isinstance(node, ast.Name) and node.id in params:
            # x.shape / x.ndim / x.dtype are static at trace time
            parent = mod.parent(node) if mod is not None else None
            if isinstance(parent, ast.Attribute) \
                    and parent.attr in _STATIC_ATTRS \
                    and parent.value is node:
                continue
            return node.id
    return ""


class TracerFlowRule(Rule):
    name = "tracer-branch"
    description = ("Python if/while/ternary on traced-function arguments or "
                   "jnp results inside a traced function")

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        out: List[Finding] = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.If, ast.While, ast.IfExp)):
                continue
            fn = mod.in_traced(node)
            if fn is None:
                continue
            test = node.test
            if _is_none_check(test):
                continue
            tok = _offender(test, _tracer_params(mod, fn), mod)
            if not tok:
                continue
            kind = {"If": "if", "While": "while",
                    "IfExp": "ternary"}[type(node).__name__]
            out.append(Finding(
                rule=self.name, path=mod.rel, line=test.lineno,
                scope=mod.qualname(fn),
                message=(f"host `{kind}` on tracer-dependent value "
                         f"`{tok}` inside traced function"),
                detail=f"{kind}:{tok}"))
        return out
