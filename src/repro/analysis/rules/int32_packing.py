"""Rule: int32 overflow guards around packed composite keys.

The engine packs multi-attribute join keys into int32 by mixed-radix
accumulation (``key = key * width + col``).  The product of radices must
be checked against ``2**31`` *before* packing — otherwise the packed key
silently wraps and the sorted-index probes return wrong rows.  The
canonical guards are ``_I32_LIM`` comparisons and
``(dom).bit_length()``-style error messages (``_as_i32`` carries its own
check).

This rule finds mixed-radix accumulation loops — a ``for`` loop whose
body folds ``x = x * w + c`` (or ``x *= w`` / ``x += c``) — in modules
that do int32 key work, and flags them when the module carries none of
the guard idioms (``_I32_LIM``, ``bit_length``, ``_as_i32``, a literal
``1 << 31`` / ``2147483648``).
"""

from __future__ import annotations

import ast
import re
from typing import Iterable, List

from ..findings import Finding
from ..lint import Rule, SourceModule

_GUARD_TOKENS = ("_I32_LIM", "bit_length", "_as_i32", "2147483648",
                 "2 ** 31", "2**31")
# any `1 << NN` bound with NN >= 31 counts as a domain guard (the int64
# fingerprint pack in relation.py guards against 1 << 62)
_GUARD_SHIFT_RE = re.compile(r"1\s*<<\s*(3[1-9]|[4-9]\d)")


def _mul_add_fold(stmt: ast.stmt) -> str:
    """Name folded by ``x = x * w + c`` / ``x *= w`` inside a loop body."""
    if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
            and isinstance(stmt.targets[0], ast.Name):
        name = stmt.targets[0].id
        v = stmt.value
        if isinstance(v, ast.BinOp) and isinstance(v.op, ast.Add):
            left = v.left
            if isinstance(left, ast.BinOp) and isinstance(left.op, ast.Mult):
                for sub in ast.walk(left):
                    if isinstance(sub, ast.Name) and sub.id == name:
                        return name
    if isinstance(stmt, ast.AugAssign) and isinstance(stmt.op, ast.Mult) \
            and isinstance(stmt.target, ast.Name):
        return stmt.target.id
    return ""


class Int32PackingRule(Rule):
    name = "int32-overflow"
    description = ("mixed-radix key packing without an int32 domain guard "
                   "(_I32_LIM / bit_length / _as_i32)")

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        if "/core/" not in f"/{mod.rel}":
            return ()               # key packing lives in the core engine
        if "int32" not in mod.text:
            return ()               # module does no int32 key work
        if any(tok in mod.text for tok in _GUARD_TOKENS) \
                or _GUARD_SHIFT_RE.search(mod.text):
            return ()               # guard idiom present somewhere in module
        out: List[Finding] = []
        for loop in ast.walk(mod.tree):
            if not isinstance(loop, ast.For):
                continue
            for stmt in ast.walk(loop):
                if not isinstance(stmt, (ast.Assign, ast.AugAssign)):
                    continue
                name = _mul_add_fold(stmt)
                if not name:
                    continue
                out.append(Finding(
                    rule=self.name, path=mod.rel, line=stmt.lineno,
                    scope=mod.scope_of(stmt),
                    message=(f"mixed-radix accumulation on `{name}` in an "
                             "int32 module without a 2**31 domain guard"),
                    detail=f"fold:{name}"))
                break               # one finding per loop is enough
        return out
