"""Rules: host escapes out of traced code, and stale device-scalar pulls.

``host-escape`` — inside traced functions:

* ``x.item()`` — concretizes a tracer (errors under jit);
* ``float(x)`` / ``int(x)`` / ``bool(x)`` on an argument or a jnp/jax
  result — same concretization, often hidden in format strings;
* any ``np.*()`` / ``numpy.*()`` *call* — numpy ops on tracers either
  fail or silently fall back to host round-trips.  Bare dtype references
  (``np.int32`` as an argument) are fine and not flagged.

``estimator-pull`` — in sampler classes that read the estimation
subsystem's device-backed running stats (``size_stats`` /
``overlap_stats``): the ``.mean`` / ``.count`` / ``.variance`` /
``.half_width`` properties each pull a device scalar to host.  Reading
them from sampling-hot-path methods re-syncs unchanged state once per
candidate; those reads belong in the refresh path (method names starting
with ``_refresh``, ``observe`` or ``__init__``) with the host floats
memoised for the hot path.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Set

from ..findings import Finding
from ..lint import Rule, SourceModule, attr_chain
from .tracer_flow import _tracer_params

_PULL_PROPS = {"mean", "count", "variance", "m2", "half_width"}
_STATS_TAILS = {"size_stats", "overlap_stats"}
_EXEMPT_PREFIXES = ("_refresh", "__init__", "observe", "warm")


def _mentions_tracer(node: ast.AST, params: Set[str]) -> str:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            chain = attr_chain(sub.func)
            if chain.split(".", 1)[0] in ("jnp", "jax", "lax"):
                return chain
        if isinstance(sub, ast.Name) and sub.id in params:
            return sub.id
    return ""


class HostEscapeRule(Rule):
    name = "host-escape"
    description = (".item()/float()/int()/bool()/np.* host escapes inside "
                   "traced functions")

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        out: List[Finding] = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = mod.in_traced(node)
            if fn is None:
                continue
            scope = mod.qualname(fn)
            # x.item()
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                out.append(Finding(
                    rule=self.name, path=mod.rel, line=node.lineno,
                    scope=scope,
                    message="`.item()` concretizes a tracer in traced code",
                    detail="item"))
                continue
            # float()/int()/bool() on tracer-ish values
            if isinstance(node.func, ast.Name) \
                    and node.func.id in ("float", "int", "bool") \
                    and node.args:
                tok = _mentions_tracer(node.args[0],
                                       _tracer_params(mod, fn))
                if tok:
                    out.append(Finding(
                        rule=self.name, path=mod.rel, line=node.lineno,
                        scope=scope,
                        message=(f"`{node.func.id}({tok}...)` pulls a "
                                 "tracer to host in traced code"),
                        detail=f"{node.func.id}:{tok}"))
                continue
            # np.*() calls
            chain = attr_chain(node.func)
            root = chain.split(".", 1)[0]
            if root in ("np", "numpy") and "." in chain:
                out.append(Finding(
                    rule=self.name, path=mod.rel, line=node.lineno,
                    scope=scope,
                    message=f"numpy call `{chain}()` inside traced code "
                            "runs on host",
                    detail=chain))
        return out


class EstimatorPullRule(Rule):
    name = "estimator-pull"
    description = ("device-backed running-stat properties read outside the "
                   "refresh path (per-candidate device→host syncs)")

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        out: List[Finding] = []
        for cls in mod.classes:
            methods = [n for n in cls.body
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))]
            if not any(m.name == "sample" for m in methods):
                continue            # only sampler front-ends have a hot path
            for meth in methods:
                if meth.name.startswith(_EXEMPT_PREFIXES):
                    continue
                stat_vars = self._stat_vars(meth)
                if not stat_vars:
                    continue
                for node in ast.walk(meth):
                    read = None
                    if (isinstance(node, ast.Attribute)
                            and node.attr in _PULL_PROPS
                            and isinstance(node.value, ast.Name)
                            and node.value.id in stat_vars):
                        read = f"{node.value.id}.{node.attr}"
                    if read is None:
                        continue
                    out.append(Finding(
                        rule=self.name, path=mod.rel, line=node.lineno,
                        scope=mod.qualname(meth),
                        message=(f"`{read}` pulls a device stat scalar in "
                                 f"`{meth.name}` (hot path); memoise it in "
                                 "the refresh path instead"),
                        detail=f"{meth.name}:{read}"))
        return out

    @staticmethod
    def _stat_vars(meth: ast.AST) -> Set[str]:
        """Local names bound from ``*.size_stats`` / ``*.overlap_stats``."""
        names: Set[str] = set()
        for node in ast.walk(meth):
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            tgt = node.targets[0]
            if not isinstance(tgt, ast.Name):
                continue
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Attribute) \
                        and sub.attr in _STATS_TAILS:
                    names.add(tgt.id)
                    break
        return names
