"""Layer-1 lint engine: module loading, traced-context index, rule runner.

Everything here is stdlib-only (``ast`` + friends) so the CI gate can run
the AST layers on a bare interpreter, before jax is installed.

Traced-context detection
------------------------
A function is considered *traced* (its body runs under a jax trace, so
host-side Python semantics are hazards) when any of these hold:

* it is decorated with ``jit`` / ``jax.jit`` / ``pjit`` / ``partial(jit)``;
* it is passed (as a ``Name`` or ``self.method`` reference) into a trace
  entry point: ``jax.jit``, ``lax.while_loop`` / ``scan`` / ``cond`` /
  ``fori_loop``, ``shard_map``, ``vmap`` / ``pmap``, ``grad``,
  ``make_jaxpr``, ``checkpoint``;
* its ``def`` line (or the line above) carries an ``# analysis: traced``
  marker — the annotation hook for functions whose traced-ness is only
  visible across modules (e.g. tree-draw methods jitted by callers);
* it is defined inside, or called from, a traced function (transitive
  closure over same-module calls: bare ``f(...)`` to a sibling def, or
  ``self.m(...)`` to a method of the enclosing class).

Inline suppression: a line carrying ``# analysis: allow(rule-name)`` (or
``allow(*)``) suppresses findings of that rule anchored to that line.
"""

from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

from .findings import Finding

TRACE_ENTRY_TAILS = {
    "jit", "pjit", "while_loop", "scan", "cond", "fori_loop", "shard_map",
    "vmap", "pmap", "grad", "value_and_grad", "make_jaxpr", "checkpoint",
    "custom_jvp", "custom_vjp",
}
JIT_DECORATOR_TAILS = {"jit", "pjit"}

_ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\(([^)]*)\)")
_MARK_RE = re.compile(r"#\s*analysis:\s*(traced|fixed-point)\b")


def attr_tail(node: ast.AST) -> Optional[str]:
    """Last segment of a Name / dotted-attribute expression, else None."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def attr_chain(node: ast.AST) -> str:
    """Render ``a.b.c`` chains (best effort) for messages."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


class SourceModule:
    """One parsed file plus navigation helpers shared by all rules."""

    def __init__(self, path: str, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        self._parents: Dict[int, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self._parents[id(child)] = node
        self.defs: List[ast.FunctionDef] = [
            n for n in ast.walk(self.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        self.classes: List[ast.ClassDef] = [
            n for n in ast.walk(self.tree) if isinstance(n, ast.ClassDef)]
        self._traced: Optional[Set[int]] = None

    # -- navigation -----------------------------------------------------------
    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(id(node))

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self.parent(node)
        while cur is not None:
            yield cur
            cur = self.parent(cur)

    def enclosing_function(self, node: ast.AST
                           ) -> Optional[ast.FunctionDef]:
        for a in self.ancestors(node):
            if isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return a
        return None

    def enclosing_class(self, node: ast.AST) -> Optional[ast.ClassDef]:
        for a in self.ancestors(node):
            if isinstance(a, ast.ClassDef):
                return a
        return None

    def qualname(self, node: ast.AST) -> str:
        parts: List[str] = []
        cur: Optional[ast.AST] = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                parts.append(cur.name)
            cur = self.parent(cur)
        return ".".join(reversed(parts)) or "<module>"

    def scope_of(self, node: ast.AST) -> str:
        fn = self.enclosing_function(node)
        if fn is not None:
            return self.qualname(fn)
        cls = self.enclosing_class(node)
        if cls is not None:
            return self.qualname(cls)
        return "<module>"

    # -- source markers -------------------------------------------------------
    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def has_marker(self, node: ast.AST, marker: str) -> bool:
        """``# analysis: <marker>`` on the node's line or the line above."""
        for ln in (node.lineno, node.lineno - 1):
            m = _MARK_RE.search(self.line_text(ln))
            if m and m.group(1) == marker:
                return True
        return False

    def allowed_rules(self, lineno: int) -> Set[str]:
        m = _ALLOW_RE.search(self.line_text(lineno))
        if not m:
            return set()
        return {tok.strip() for tok in m.group(1).split(",") if tok.strip()}

    # -- traced-context index -------------------------------------------------
    def traced_functions(self) -> Set[int]:
        """ids of FunctionDef nodes whose bodies run under a jax trace."""
        if self._traced is not None:
            return self._traced
        traced: Set[int] = set()

        def mark(fn: Optional[ast.AST]) -> None:
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                traced.add(id(fn))

        # (1) decorators + explicit markers
        for fn in self.defs:
            if self.has_marker(fn, "traced"):
                mark(fn)
                continue
            for dec in fn.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                tail = attr_tail(target)
                if tail in JIT_DECORATOR_TAILS:
                    mark(fn)
                elif tail == "partial" and isinstance(dec, ast.Call):
                    if dec.args and attr_tail(dec.args[0]) in JIT_DECORATOR_TAILS:
                        mark(fn)

        # (2) function references passed into trace entry points
        for call in ast.walk(self.tree):
            if not isinstance(call, ast.Call):
                continue
            if attr_tail(call.func) not in TRACE_ENTRY_TAILS:
                continue
            for arg in list(call.args) + [kw.value for kw in call.keywords]:
                self._mark_fn_ref(arg, call, mark)

        # (3) transitive closure: nested defs + same-module calls
        changed = True
        while changed:
            changed = False
            before = len(traced)
            for fn in self.defs:
                if id(fn) not in traced:
                    continue
                # nested defs trace with their parent
                for node in ast.walk(fn):
                    if node is not fn and isinstance(
                            node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        mark(node)
                # calls out of traced code
                for node in ast.walk(fn):
                    if not isinstance(node, ast.Call):
                        continue
                    callee = self._resolve_callee(node, fn)
                    if callee is not None:
                        mark(callee)
            changed = len(traced) != before

        self._traced = traced
        return traced

    def _mark_fn_ref(self, arg: ast.AST, call: ast.Call, mark) -> None:
        """Resolve a trace-entry argument to a local def / self-method."""
        if isinstance(arg, ast.Call):
            # functools.partial(fn, ...) — look at the wrapped callable
            if attr_tail(arg.func) == "partial" and arg.args:
                self._mark_fn_ref(arg.args[0], call, mark)
            return
        if isinstance(arg, ast.Name):
            mark(self._lookup_def(arg.id, call))
        elif (isinstance(arg, ast.Attribute)
              and isinstance(arg.value, ast.Name)
              and arg.value.id == "self"):
            mark(self._lookup_method(arg.attr, call))

    def _resolve_callee(self, call: ast.Call, site_fn: ast.AST
                        ) -> Optional[ast.FunctionDef]:
        f = call.func
        if isinstance(f, ast.Name):
            return self._lookup_def(f.id, call)
        if (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name)
                and f.value.id == "self"):
            return self._lookup_method(f.attr, call)
        return None

    def _lookup_def(self, name: str, site: ast.AST
                    ) -> Optional[ast.FunctionDef]:
        """Nearest def named ``name`` in the site's enclosing scope chain."""
        scopes: List[ast.AST] = []
        fn = self.enclosing_function(site)
        while fn is not None:
            scopes.append(fn)
            fn = self.enclosing_function(fn)
        scopes.append(self.tree)
        for scope in scopes:
            body = scope.body if hasattr(scope, "body") else []
            for stmt in body:
                if (isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and stmt.name == name):
                    return stmt
        return None

    def _lookup_method(self, name: str, site: ast.AST
                       ) -> Optional[ast.FunctionDef]:
        cls = self.enclosing_class(site)
        if cls is None:
            return None
        for stmt in cls.body:
            if (isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and stmt.name == name):
                return stmt
        return None

    def in_traced(self, node: ast.AST) -> Optional[ast.FunctionDef]:
        """The innermost traced function enclosing ``node``, if any."""
        traced = self.traced_functions()
        cur: Optional[ast.AST] = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and id(cur) in traced:
                return cur
            cur = self.parent(cur)
        return None


class Rule:
    """Base class: subclasses set ``name`` and override one of the hooks."""

    name = "rule"
    description = ""

    def check_module(self, mod: SourceModule) -> Iterable[Finding]:
        return ()

    def check_project(self, mods: Sequence[SourceModule]
                      ) -> Iterable[Finding]:
        return ()


def load_tree(root: str, rel_prefix: str = "") -> List[SourceModule]:
    """Parse every ``*.py`` under ``root`` (sorted, skipping caches)."""
    mods: List[SourceModule] = []
    root = os.path.abspath(root)
    if os.path.isfile(root):
        with open(root, encoding="utf-8") as fh:
            text = fh.read()
        rel = os.path.join(rel_prefix, os.path.basename(root))
        return [SourceModule(root, rel.replace(os.sep, "/"), text)]
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for fname in sorted(filenames):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.join(rel_prefix, os.path.relpath(path, root))
            with open(path, encoding="utf-8") as fh:
                text = fh.read()
            mods.append(SourceModule(path, rel.replace(os.sep, "/"), text))
    return mods


def run_lint(paths: Sequence[str], rules: Optional[Sequence[Rule]] = None,
             rel_prefixes: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run all (or the given) rules over the files/trees in ``paths``."""
    if rules is None:
        from .rules import all_rules
        rules = all_rules()
    mods: List[SourceModule] = []
    for i, p in enumerate(paths):
        if rel_prefixes:
            prefix = rel_prefixes[i]
        elif os.path.isfile(p):
            prefix = ""              # a file already names itself
        else:
            prefix = os.path.basename(os.path.abspath(p))
        mods.extend(load_tree(p, rel_prefix=prefix))
    findings: List[Finding] = []
    for rule in rules:
        for mod in mods:
            findings.extend(rule.check_module(mod))
        findings.extend(rule.check_project(mods))
    # inline `# analysis: allow(rule)` suppression at the finding's line
    by_rel = {m.rel: m for m in mods}
    kept: List[Finding] = []
    for f in findings:
        mod = by_rel.get(f.path)
        if mod is not None:
            allowed = mod.allowed_rules(f.line)
            if f.rule in allowed or "*" in allowed:
                continue
        kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return kept
