"""Layer 2b: compile-cache audit — one trace per capacity class.

The device loop is compiled per output-capacity class ``C`` (the
power-of-two padding of the request size, floored at 1024) and cached
under ``(C, plan, fused_rounds)``.  Every extra trace is a multi-second
XLA compile stall on the serving path, so the invariant worth gating on
is: across any mix of request sizes, the engine traces its loop exactly
once per distinct capacity class, and never again for repeated sizes.

The engines append ``("loop", C, plan)`` to ``_trace_events`` inside the
pre-jit loop body — i.e. exactly once per *trace*, not per call — which
is what this audit counts.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Sequence, Tuple

from .findings import Finding


def capacity_class(n: int) -> int:
    """Output-capacity class for a request of ``n`` rows (mirrors the
    engine: next power of two, floored at 1024)."""
    return 1 << max(10, (int(n) - 1).bit_length())


def _finding(label: str, message: str, detail: str) -> Finding:
    return Finding(rule="recompile", path=f"<audit:{label}>", line=0,
                   scope=label, message=message, detail=detail)


def audit_recompile_engine(eng, label: str,
                           sizes: Sequence[int] = (200, 300, 1400, 1500, 300)
                           ) -> Tuple[List[Finding], Dict[str, Any]]:
    """Drive one engine through a mix of request sizes and count traces.

    ``sizes`` deliberately repeats a capacity class (200/300 → C=1024,
    1400/1500 → C=2048, then 300 again) so a cache keyed on anything
    finer than the capacity class shows up as a duplicate trace event.
    """
    eng._trace_events.clear()
    for n in sizes:
        eng.sample(n)
    events = list(eng._trace_events)
    expected = sorted({("loop", capacity_class(n), eng.plan)
                       for n in sizes})
    findings: List[Finding] = []
    if sorted(events) != expected:
        findings.append(_finding(
            label, "loop trace count differs from one-per-capacity-class",
            f"traced={sorted(events)} expected={expected}"))
    cache_keys = sorted(eng._loop_cache.keys())
    want_keys = sorted({(capacity_class(n), eng.plan, "device")
                        for n in sizes})
    if cache_keys != want_keys:
        findings.append(_finding(
            label, "loop cache keys are not (capacity class, plan, mode)",
            f"keys={cache_keys} expected={want_keys}"))
    report = {
        "label": label, "plan": eng.plan, "sizes": list(sizes),
        "traces": len(events),
        "capacity_classes": sorted({c for _, c, _ in events}),
        "findings": len(findings),
    }
    return findings, report


# plan regimes get distinct cache entries, so each is audited on a fresh
# engine rather than by flipping ``plan`` on a live one (the device
# carry's layout is plan-dependent)
DEFAULT_RECOMPILE_AUDITS: Tuple[Tuple[str, Dict[str, Any]], ...] = (
    ("uq1-static", dict(workload="uq1", plan="static")),
    ("uq1-adaptive", dict(workload="uq1", plan="adaptive")),
    ("uq4-static", dict(workload="uq4", plan="static")),
)


def run_recompile_audit(audits: Sequence[Tuple[str, Dict[str, Any]]] = None
                        ) -> Tuple[List[Finding], List[Dict[str, Any]]]:
    from .jaxpr_audit import build_engine

    findings: List[Finding] = []
    reports: List[Dict[str, Any]] = []
    for label, spec in (audits if audits is not None
                        else DEFAULT_RECOMPILE_AUDITS):
        eng = build_engine(**spec)
        f, r = audit_recompile_engine(eng, label)
        findings.extend(f)
        reports.append(r)
    return findings, reports
