"""Finding records, stable fingerprints, and the suppression baseline.

A finding's *fingerprint* deliberately excludes line numbers: it hashes
``rule | path | scope | detail`` so that unrelated edits to a file don't
churn the baseline.  ``detail`` is the rule's stable token for the
offending construct (a symbol name, an attribute, a message core) rather
than the rendered message.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, Iterable, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str            # rule identifier, e.g. "host-escape"
    path: str            # repo-relative posix path
    line: int            # 1-based line of the offending node
    scope: str           # dotted qualname of the enclosing def/class
    message: str         # human-readable description
    detail: str = ""     # stable token used for the fingerprint

    @property
    def fingerprint(self) -> str:
        core = f"{self.rule}|{self.path}|{self.scope}|{self.detail or self.message}"
        return hashlib.sha1(core.encode()).hexdigest()[:16]

    def to_dict(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "scope": self.scope,
            "message": self.message,
            "fingerprint": self.fingerprint,
        }

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] {self.message} "
                f"(in {self.scope}) [{self.fingerprint}]")


class Baseline:
    """Grandfathered findings: ``{fingerprint: reason}`` with a policy that
    every entry carries a one-line justification (enforced on load)."""

    def __init__(self, entries: Optional[Dict[str, Dict[str, str]]] = None):
        self.entries: Dict[str, Dict[str, str]] = dict(entries or {})

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path, encoding="utf-8") as fh:
            raw = json.load(fh)
        entries: Dict[str, Dict[str, str]] = {}
        for item in raw.get("findings", []):
            fp = item.get("fingerprint", "")
            reason = (item.get("reason") or "").strip()
            if not fp:
                raise ValueError(f"baseline entry missing fingerprint: {item}")
            if not reason:
                raise ValueError(
                    f"baseline entry {fp} has no justification reason")
            entries[fp] = item
        return cls(entries)

    def suppresses(self, finding: Finding) -> bool:
        return finding.fingerprint in self.entries

    def split(self, findings: Sequence[Finding]
              ) -> tuple[List[Finding], List[Finding]]:
        """(active, suppressed) partition of ``findings``."""
        active = [f for f in findings if not self.suppresses(f)]
        suppressed = [f for f in findings if self.suppresses(f)]
        return active, suppressed

    def stale(self, findings: Iterable[Finding]) -> List[str]:
        """Baseline fingerprints no longer matched by any current finding —
        candidates for deletion so the baseline shrinks over time."""
        seen = {f.fingerprint for f in findings}
        return sorted(fp for fp in self.entries if fp not in seen)
