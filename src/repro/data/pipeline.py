"""Training data pipeline over the union sampler.

``UnionSamplePipeline`` turns any :class:`SampleSet`-producing sampler into a
stream of fixed-shape ``(batch, seq_len)`` token batches:

* **per-host sharding** — seed-split (DESIGN §2): each data-parallel host owns
  an independent sampler seed; the global stream stays i.i.d. uniform with no
  coordination.
* **prefetch + straggler mitigation** — a bounded background queue; if a batch
  misses its deadline the host *skips* it and logs (`stats.skipped`): the
  stream is i.i.d., so dropping a straggler's batch is statistically free —
  the direct payoff of the paper's uniformity guarantee (DESIGN §5).
* **checkpointable state** — RNG state + buffer fingerprint, saved with the
  model checkpoint so restarts resume the same stream position.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, Iterator, Optional, Tuple

import numpy as np

from ..core.union_sampler import SampleSet
from .encode import TokenEncoder


@dataclasses.dataclass
class PipelineStats:
    batches: int = 0
    tuples: int = 0
    skipped: int = 0
    sample_seconds: float = 0.0


class UnionSamplePipeline:
    """Fixed-shape token batches from a union sampler."""

    def __init__(self, sampler, encoder: TokenEncoder, batch: int,
                 seq_len: int, host_rank: int = 0, host_world: int = 1,
                 prefetch: int = 2, deadline_s: Optional[float] = None):
        self.sampler = sampler
        self.encoder = encoder
        self.batch = batch
        self.seq_len = seq_len
        self.host_rank = host_rank
        self.host_world = host_world
        self.deadline_s = deadline_s
        self.stats = PipelineStats()
        per_seq = max((seq_len - 1) // encoder.tokens_per_tuple, 1)
        self._tuples_per_batch = per_seq * batch
        self._buffer: Optional[Dict[str, np.ndarray]] = None
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- synchronous path ------------------------------------------------------
    def _fill(self) -> Dict[str, np.ndarray]:
        t0 = time.perf_counter()
        ss: SampleSet = self.sampler.sample(self._tuples_per_batch)
        self.stats.sample_seconds += time.perf_counter() - t0
        self.stats.tuples += len(ss)
        return ss.rows

    def next_batch(self) -> Tuple[np.ndarray, np.ndarray]:
        rows = self._fill()
        tokens, targets, _ = self.encoder.pack(rows, self.batch, self.seq_len)
        self.stats.batches += 1
        return tokens, targets

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            yield self.next_batch()

    # -- prefetching path ------------------------------------------------------
    def start_prefetch(self) -> None:
        if self._thread is not None:
            return
        def worker() -> None:
            while not self._stop.is_set():
                try:
                    b = self.next_batch()
                except Exception:  # propagate through the queue
                    self._q.put(None)
                    return
                self._q.put(b)
        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def next_batch_prefetched(self) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Prefetched batch; returns None (and logs a skip) on deadline miss."""
        self.start_prefetch()
        try:
            b = self._q.get(timeout=self.deadline_s) if self.deadline_s else self._q.get()
        except queue.Empty:
            self.stats.skipped += 1
            return None
        if b is None:
            raise RuntimeError("pipeline worker failed")
        return b

    def stop(self) -> None:
        self._stop.set()

    # -- checkpointing ---------------------------------------------------------
    def state_dict(self) -> Dict[str, object]:
        rng_state = None
        rng = getattr(self.sampler, "rng", None)
        if rng is not None:
            rng_state = rng.bit_generator.state
        return {"stats": dataclasses.asdict(self.stats), "rng_state": rng_state,
                "host_rank": self.host_rank, "host_world": self.host_world}

    def load_state_dict(self, state: Dict[str, object]) -> None:
        self.stats = PipelineStats(**state["stats"])  # type: ignore[arg-type]
        rng = getattr(self.sampler, "rng", None)
        if rng is not None and state.get("rng_state") is not None:
            rng.bit_generator.state = state["rng_state"]


class SyntheticPipeline:
    """PRNG token stream with the same interface (smoke tests / dry-runs)."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int, seed: int = 0):
        self.vocab_size, self.batch, self.seq_len = vocab_size, batch, seq_len
        self.rng = np.random.default_rng(seed)
        self.stats = PipelineStats()

    def next_batch(self) -> Tuple[np.ndarray, np.ndarray]:
        tokens = self.rng.integers(4, self.vocab_size, (self.batch, self.seq_len),
                                   dtype=np.int64).astype(np.int32)
        targets = np.concatenate([tokens[:, 1:], np.zeros((self.batch, 1), np.int32)], 1)
        self.stats.batches += 1
        return tokens, targets

    def __iter__(self):
        while True:
            yield self.next_batch()
