"""TPC-H-lite generator (DBGen analogue) with scale / skew / overlap knobs.

Produces dict-encoded integer relations mirroring the TPC-H schema subset the
paper's workloads touch (§9): region, nation, supplier, customer, orders,
lineitem, partsupp, part.  Two generator features reproduce the paper's
experimental axes:

* ``scale``          — row counts scale linearly (TPC-H-proportioned bases).
* ``overlap``        — :func:`make_variants` derives per-join variant copies
  of a relation that share exactly the first ``overlap`` fraction of rows (the
  "overlap scale P%" of §9) plus independent 50% subsets of the remainder
  (whose higher-order coincidental overlap is negligible).
* ``skew``           — optional Zipf exponent on FK assignments (orders per
  customer, lineitems per order), exercising the bias the paper notes for
  Theorem 4 under skew.

Every relation includes its primary key, so rows — and therefore join output
tuples — are duplicate-free (the paper's §3 no-duplicates assumption).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..core.relation import Relation

BASES = dict(region=5, nation=25, supplier=100, part=2000, partsupp=8000,
             customer=1500, orders=15_000, lineitem=60_000)


def _zipf_choice(rng: np.random.Generator, n_values: int, size: int,
                 skew: float) -> np.ndarray:
    if skew <= 0:
        return rng.integers(0, n_values, size=size)
    w = 1.0 / np.power(np.arange(1, n_values + 1, dtype=np.float64), skew)
    w /= w.sum()
    return rng.choice(n_values, size=size, p=w)


@dataclasses.dataclass
class TpchLite:
    relations: Dict[str, Relation]
    scale: float
    skew: float

    def __getitem__(self, name: str) -> Relation:
        return self.relations[name]


def generate(scale: float = 0.02, seed: int = 0, skew: float = 0.0) -> TpchLite:
    rng = np.random.default_rng(seed)
    n = {k: max(int(v * scale), 3) for k, v in BASES.items()}
    n["region"], n["nation"] = 5, 25

    region = Relation("region", {"rk": np.arange(n["region"])})
    nation = Relation("nation", {
        "nk": np.arange(n["nation"]),
        "rk": rng.integers(0, n["region"], n["nation"]),
    })
    supplier = Relation("supplier", {
        "sk": np.arange(n["supplier"]),
        "s_nk": rng.integers(0, n["nation"], n["supplier"]),
        "sbal": rng.integers(0, 1000, n["supplier"]),
    })
    part = Relation("part", {
        "pk": np.arange(n["part"]),
        "psize": rng.integers(1, 51, n["part"]),
        "ptype": rng.integers(0, 150, n["part"]),
    })
    ps_pairs = rng.choice(n["part"] * n["supplier"],
                          size=min(n["partsupp"], n["part"] * n["supplier"]),
                          replace=False)
    partsupp = Relation("partsupp", {
        "pk": ps_pairs // n["supplier"],
        "sk": ps_pairs % n["supplier"],
        "ps_cost": rng.integers(0, 1000, ps_pairs.shape[0]),
    })
    customer = Relation("customer", {
        "ck": np.arange(n["customer"]),
        "nk": rng.integers(0, n["nation"], n["customer"]),
        "cbal": rng.integers(0, 1000, n["customer"]),
        "mkt": rng.integers(0, 5, n["customer"]),
    })
    orders = Relation("orders", {
        "ok": np.arange(n["orders"]),
        "ck": _zipf_choice(rng, n["customer"], n["orders"], skew),
        "odate": rng.integers(0, 2556, n["orders"]),
        "oprio": rng.integers(0, 5, n["orders"]),
    })
    lineitem = Relation("lineitem", {
        "ok": _zipf_choice(rng, n["orders"], n["lineitem"], skew),
        "ln": np.zeros(n["lineitem"], dtype=np.int64),  # fixed below (unique per ok)
        "pk": rng.integers(0, n["part"], n["lineitem"]),
        "l_sk": rng.integers(0, n["supplier"], n["lineitem"]),
        "qty": rng.integers(1, 51, n["lineitem"]),
    })
    # line numbers unique within an order (=> duplicate-free rows)
    ok_col = lineitem.columns["ok"]
    order_sort = np.argsort(ok_col, kind="stable")
    ln = np.zeros_like(ok_col)
    sorted_ok = ok_col[order_sort]
    new_run = np.concatenate([[True], sorted_ok[1:] != sorted_ok[:-1]])
    run_ids = np.cumsum(new_run) - 1
    run_starts = np.nonzero(new_run)[0]
    ln[order_sort] = np.arange(sorted_ok.shape[0]) - run_starts[run_ids]
    lineitem = lineitem.with_column("ln", ln)

    return TpchLite({r.name: r for r in
                     (region, nation, supplier, part, partsupp, customer,
                      orders, lineitem)}, scale, skew)


def make_variants(rel: Relation, n_variants: int, overlap: float,
                  seed: int = 0, keep_rest: float = 0.5) -> List[Relation]:
    """Variant copies sharing exactly the first ``overlap`` fraction of rows."""
    rng = np.random.default_rng(seed)
    n = rel.nrows
    core = int(round(n * overlap))
    out = []
    for v in range(n_variants):
        keep = np.zeros(n, dtype=bool)
        keep[:core] = True
        keep[core:] = rng.random(n - core) < keep_rest
        out.append(rel.filter(keep, name=f"{rel.name}@v{v}"))
    return out


def vertical_split(rel: Relation, groups: List[List[str]],
                   key_attrs: List[str]) -> List[Relation]:
    """Lossless vertical split: every part keeps the key attributes."""
    return [rel.project(list(dict.fromkeys(key_attrs + g)),
                        name=f"{rel.name}|{'_'.join(g) or i}")
            for i, g in enumerate(groups)]


def horizontal_split(rel: Relation, fraction: float, seed: int = 0,
                     name: Optional[str] = None) -> Relation:
    rng = np.random.default_rng(seed)
    keep = rng.random(rel.nrows) < fraction
    return rel.filter(keep, name=name or f"{rel.name}~h")
