"""The paper's evaluation workloads (§9): UQ1, UQ2, UQ3 (+ cyclic UQ4).

* **UQ1** — five chain joins, five relations each
  (nation ⋈ supplier ⋈ customer ⋈ orders ⋈ lineitem), one variant database
  per join sharing ``overlap`` of the base rows.
* **UQ2** — three chain joins over the *same* data
  (region ⋈ nation ⋈ supplier ⋈ partsupp ⋈ part) distinguished only by
  overlapping selection predicates (the high-overlap workload), following the
  Q2^N ∪ Q2^P ∪ Q2^S construction the paper cites from Carmeli et al. [8].
* **UQ3** — one acyclic (branching-tree) join + two chain joins derived from
  supplier/customer/orders via vertical + horizontal splits — different
  relation schemas, same output schema: exercises the §5.2 splitting method.
* **UQ4** (beyond paper — §9 skipped cyclic evaluation) — union of a cyclic
  join (supplier ⋈ partsupp ⋈ part + a cycle-closing preferred-supplier
  relation as the §8.2 residual) with an equivalent denormalised chain.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from ..core.index import Catalog
from ..core.joins import JoinNode, JoinSpec, chain_join
from ..core.predicates import Pred, pushdown, rejection
from ..core.relation import Relation
from .tpch import TpchLite, generate, horizontal_split, make_variants, vertical_split


@dataclasses.dataclass
class Workload:
    name: str
    joins: List[JoinSpec]
    cat: Catalog
    db: TpchLite


def uq1(scale: float = 0.02, overlap: float = 0.2, seed: int = 0,
        n_joins: int = 5, skew: float = 0.0) -> Workload:
    db = generate(scale, seed=seed, skew=skew)
    cat = Catalog()
    # standardise the supplier FK name before building chains (paper §2:
    # join attributes are standardised to the same names)
    base = {
        "nation": db["nation"],
        # supplier joins nation on nk but also joins customer on nk in the
        # chain; rename s_nk -> nk up front
        "supplier": db["supplier"].rename({"s_nk": "nk"}),
        "customer": db["customer"].project(["ck", "nk", "cbal"]),
        "orders": db["orders"],
        "lineitem": db["lineitem"],
    }
    variants = {nm: make_variants(rel, n_joins, overlap, seed=seed + 17 + i)
                for i, (nm, rel) in enumerate(base.items())}
    joins = []
    for v in range(n_joins):
        joins.append(chain_join(
            f"UQ1_J{v}",
            [variants["nation"][v], variants["supplier"][v],
             variants["customer"][v], variants["orders"][v],
             variants["lineitem"][v]],
            [("nk",), ("nk",), ("ck",), ("ok",)],
        ))
    return Workload("UQ1", joins, cat, db)


def uq2(scale: float = 0.02, seed: int = 0, skew: float = 0.0,
        pred_mode: str = "pushdown") -> Workload:
    """UQ2 in either §8.3 predicate mode.

    * ``pred_mode="pushdown"`` — base relations filtered at build time; the
      specs carry pushdown provenance so the device engine rebuilds them as
      validity masks over the shared base relations.
    * ``pred_mode="rejection"`` — the three flavours share the *same*
      unfiltered nodes and differ only in per-join ``reject_preds``;
      candidates failing them are rejected during sampling.
    """
    if pred_mode not in ("pushdown", "rejection"):
        raise ValueError("pred_mode must be 'pushdown' or 'rejection'")
    db = generate(scale, seed=seed, skew=skew)
    cat = Catalog()
    supplier = db["supplier"].rename({"s_nk": "nk"})
    base = chain_join(
        "UQ2_BASE",
        [db["region"], db["nation"], supplier, db["partsupp"], db["part"]],
        [("rk",), ("nk",), ("sk",), ("pk",)],
    )
    # overlapping selection predicates (the paper's Q2^N / Q2^P / Q2^S flavour)
    mk = pushdown if pred_mode == "pushdown" else rejection
    j_n = mk(base, [Pred("psize", "<=", 40)], name="UQ2_JN")
    j_p = mk(base, [Pred("psize", ">=", 10)], name="UQ2_JP")
    j_s = mk(base, [Pred("psize", "in", set(range(5, 46)))], name="UQ2_JS")
    return Workload("UQ2", [j_n, j_p, j_s], cat, db)


def uq3(scale: float = 0.02, overlap: float = 0.2, seed: int = 0) -> Workload:
    db = generate(scale, seed=seed)
    cat = Catalog()
    rng_seed = seed + 101
    # output schema: (ck, nk, cbal, ok, odate)
    cust = db["customer"].project(["ck", "nk", "cbal"])
    ords = db["orders"].project(["ok", "ck", "odate"])
    cust_v = make_variants(cust, 3, overlap, seed=rng_seed)
    ords_v = make_variants(ords, 3, overlap, seed=rng_seed + 1)

    # J3a: branching tree over vertical splits of customer + orders
    cust_a, cust_b = vertical_split(cust_v[0], [["nk"], ["cbal"]], ["ck"])
    ord_a, ord_b = vertical_split(ords_v[0], [[], ["odate"]], ["ok", "ck"])
    ord_a = ord_a.project(["ok", "ck"], name="ord_a0")
    ord_b = ord_b.project(["ok", "odate"], name="ord_b0")
    j3a = JoinSpec("UQ3_JA", [
        JoinNode("cust_a", cust_a, None, ()),
        JoinNode("cust_b", cust_b, "cust_a", ("ck",)),
        JoinNode("ord_a", ord_a, "cust_a", ("ck",)),
        JoinNode("ord_b", ord_b, "ord_a", ("ok",)),
    ])

    # J3b: chain over un-split customer + vertically split orders
    ord_a1 = ords_v[1].project(["ok", "ck"], name="ord_a1")
    ord_b1 = ords_v[1].project(["ok", "odate"], name="ord_b1")
    j3b = chain_join("UQ3_JB", [cust_v[1].rename({}, name="cust1"),
                                ord_a1, ord_b1], [("ck",), ("ok",)])

    # J3c: 2-relation chain over denormalised orders
    j3c = chain_join("UQ3_JC", [cust_v[2].rename({}, name="cust2"),
                                ords_v[2].rename({}, name="ord2")], [("ck",)])
    return Workload("UQ3", [j3a, j3b, j3c], cat, db)


def uq4(scale: float = 0.02, seed: int = 0) -> Workload:
    """Cyclic union workload (beyond paper): skeleton + residual vs denormalised."""
    db = generate(scale, seed=seed)
    cat = Catalog()
    rng = np.random.default_rng(seed + 7)
    supplier = db["supplier"].rename({"s_nk": "nk"})
    partsupp, part = db["partsupp"], db["part"]
    # cycle-closing relation: preferred (pk, sk) pairs, a subset of partsupp pairs
    keep = rng.random(partsupp.nrows) < 0.5
    pref = Relation("pref", {
        "pk": partsupp.columns["pk"][keep],
        "sk": partsupp.columns["sk"][keep],
        "pref_lvl": rng.integers(0, 3, int(keep.sum())),
    })
    j_cyc = JoinSpec("UQ4_CYC", [
        JoinNode("supplier", supplier, None, ()),
        JoinNode("partsupp", partsupp, "supplier", ("sk",)),
        JoinNode("part", part, "partsupp", ("pk",)),
        JoinNode("pref", pref, None, ("pk", "sk"), kind="residual"),
    ])
    # denormalised equivalent: one wide relation for (supplier ⋈ partsupp ⋈ pref)
    from ..core.joins import full_join
    wide_spec = JoinSpec("UQ4_WIDE_BASE", [
        JoinNode("supplier", supplier, None, ()),
        JoinNode("partsupp", partsupp, "supplier", ("sk",)),
        JoinNode("pref", pref, None, ("pk", "sk"), kind="residual"),
    ])
    wide_cols = full_join(cat, wide_spec)
    # horizontal 70% subset => partial overlap with the cyclic join
    n = next(iter(wide_cols.values())).shape[0]
    hkeep = np.random.default_rng(seed + 9).random(n) < 0.7
    wide = Relation("ps_wide", {a: c[hkeep] for a, c in wide_cols.items()})
    j_chain = chain_join("UQ4_CHAIN", [wide, part], [("pk",)])
    return Workload("UQ4", [j_cyc, j_chain], cat, db)


WORKLOADS = {"UQ1": uq1, "UQ2": uq2, "UQ3": uq3, "UQ4": uq4}
