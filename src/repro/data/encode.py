"""Tuple → token encoding: the bridge from union samples to LM training.

The union sampler emits i.i.d. relational tuples; the training framework
consumes fixed-shape token batches.  Encoding is feature-hashed:

    token(attr_i = v) = N_SPECIAL + i * buckets + (mix64(v) % buckets)

Tuples are packed into sequences separated by ``SEP`` until ``seq_len`` is
filled (document-packing style), so every position carries signal and batch
shapes are static — the TPU-friendly contract.  Because the sample stream is
i.i.d. uniform over the union (the paper's guarantee), any contiguous packing
preserves the training distribution.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Sequence, Tuple

import numpy as np

from ..core.relation import mix64

PAD, BOS, EOS, SEP = 0, 1, 2, 3
N_SPECIAL = 4


@dataclasses.dataclass
class TokenEncoder:
    attrs: List[str]
    vocab_size: int

    def __post_init__(self) -> None:
        usable = self.vocab_size - N_SPECIAL
        if usable < len(self.attrs):
            raise ValueError("vocab too small for attribute bucketing")
        self.buckets = usable // len(self.attrs)

    @property
    def tokens_per_tuple(self) -> int:
        return len(self.attrs) + 1  # + SEP

    def encode_rows(self, rows: Dict[str, np.ndarray]) -> np.ndarray:
        """(n, tokens_per_tuple) int32 token matrix (SEP-terminated tuples)."""
        n = next(iter(rows.values())).shape[0]
        out = np.empty((n, self.tokens_per_tuple), dtype=np.int32)
        for i, a in enumerate(self.attrs):
            h = mix64(np.asarray(rows[a]), salt=11 + i) % np.uint64(self.buckets)
            out[:, i] = (N_SPECIAL + i * self.buckets + h.astype(np.int64)).astype(np.int32)
        out[:, -1] = SEP
        return out

    def pack(self, rows: Dict[str, np.ndarray], batch: int, seq_len: int
             ) -> Tuple[np.ndarray, np.ndarray, int]:
        """Pack tuples into (batch, seq_len) tokens + next-token targets.

        Returns (tokens, targets, tuples_consumed).  targets use PAD(=0) as
        the ignore label at sequence tails.
        """
        toks = self.encode_rows(rows)                       # (n, k)
        k = self.tokens_per_tuple
        per_seq = max((seq_len - 1) // k, 1)                # leave room for BOS
        need = per_seq * batch
        n = toks.shape[0]
        if n < need:
            raise ValueError(f"need {need} tuples, got {n}")
        body = toks[:need].reshape(batch, per_seq * k)
        tokens = np.full((batch, seq_len), PAD, dtype=np.int32)
        tokens[:, 0] = BOS
        tokens[:, 1:1 + per_seq * k] = body
        targets = np.full((batch, seq_len), PAD, dtype=np.int32)
        targets[:, :-1] = tokens[:, 1:]
        return tokens, targets, need
