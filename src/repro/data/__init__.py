"""Data substrate: TPC-H-lite generator, workloads, tuple→token encoding, pipeline."""

from .tpch import TpchLite, generate, horizontal_split, make_variants, vertical_split
from .workloads import WORKLOADS, Workload, uq1, uq2, uq3, uq4

__all__ = ["TpchLite", "WORKLOADS", "Workload", "generate", "horizontal_split",
           "make_variants", "uq1", "uq2", "uq3", "uq4", "vertical_split"]
