"""Theorem 3 / Eq 1 / cover / Algorithm 1 / Algorithm 2 properties."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from scipy import stats as sps

from repro.core.cover import build_cover
from repro.core.framework import estimate_union, warmup
from repro.core.index import Catalog
from repro.core.joins import chain_join
from repro.core.koverlap import KOverlaps, OverlapOracle, k_overlaps
from repro.core.online import OnlineUnionSampler
from repro.core.overlap import exact_union_size
from repro.core.union_sampler import (BernoulliUnionSampler,
                                      DisjointUnionSampler, SetUnionSampler)
from repro.data.workloads import uq3


# ---------------------------------------------------------------------------
# Theorem 3 on random set systems (no joins needed — pure set identity)
# ---------------------------------------------------------------------------


class _SetOracle:
    """Oracle over explicit sets (ground truth for the lattice algebra)."""

    def __init__(self, sets):
        self.sets = sets
        names = list(sets)
        import dataclasses

        @dataclasses.dataclass
        class FakeJoin:
            name: str
        self.joins = [FakeJoin(n) for n in names]
        self.by_name = {n: j for n, j in zip(names, self.joins)}
        self._cache = {}

    def overlap(self, names):
        cur = None
        for n in set(names):
            cur = self.sets[n] if cur is None else (cur & self.sets[n])
        return float(len(cur))

    def size(self, name):
        return float(len(self.sets[name]))


@given(st.integers(0, 10_000), st.integers(2, 5))
@settings(max_examples=40, deadline=None)
def test_theorem3_and_eq1_identity(seed, n_sets):
    rng = np.random.default_rng(seed)
    universe = list(range(60))
    sets = {f"J{i}": set(rng.choice(universe, size=rng.integers(5, 40),
                                    replace=False).tolist())
            for i in range(n_sets)}
    oracle = _SetOracle(sets)
    ko = k_overlaps(oracle)
    # A_j^k ground truth: elements of J_j in exactly k-1 other sets
    union = set().union(*sets.values())
    for name, s in sets.items():
        for k in range(1, n_sets + 1):
            truth = sum(1 for e in s
                        if sum(e in t for t in sets.values()) == k)
            assert ko.a[name][k - 1] == pytest.approx(truth), (name, k)
    assert ko.union_size() == pytest.approx(len(union))


@given(st.integers(0, 10_000), st.integers(2, 5))
@settings(max_examples=40, deadline=None)
def test_cover_partition_identity(seed, n_sets):
    rng = np.random.default_rng(seed)
    universe = list(range(50))
    sets = {f"J{i}": set(rng.choice(universe, size=rng.integers(5, 35),
                                    replace=False).tolist())
            for i in range(n_sets)}
    oracle = _SetOracle(sets)
    cover = build_cover(oracle)
    # ground truth cover: J'_i = J_i \ union of earlier
    seen = set()
    for name in cover.order:
        piece = sets[name] - seen
        assert cover.piece_sizes[name] == pytest.approx(len(piece)), name
        seen |= sets[name]
    assert cover.union_size == pytest.approx(len(seen))


# ---------------------------------------------------------------------------
# Algorithm 1 (uniformity; probe mode exact, record mode converging)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def wl3():
    return uq3(scale=0.01, overlap=0.3, seed=0)


def _chi2_uniform(sample_matrix, n_universe):
    uni, counts = np.unique(
        sample_matrix.view([("", sample_matrix.dtype)] * sample_matrix.shape[1]).ravel(),
        return_counts=True)
    N = sample_matrix.shape[0]
    exp = N / n_universe
    chi2 = float(((counts - exp) ** 2 / exp).sum()) + (n_universe - uni.shape[0]) * exp
    return 1 - sps.chi2.cdf(chi2, df=n_universe - 1)


def test_setunion_probe_uniform(wl3):
    cat, joins = wl3.cat, wl3.joins
    wr = warmup(cat, joins, method="exact")
    est = estimate_union(wr.oracle)
    U = exact_union_size(cat, joins)
    assert est.union_size_cover == pytest.approx(U)
    assert est.union_size_eq1 == pytest.approx(U)
    s = SetUnionSampler(cat, joins, est.cover, membership="probe", seed=7)
    ss = s.sample(120 * U)
    p = _chi2_uniform(ss.matrix(), U)
    assert p > 1e-3, f"Algorithm 1 (probe) not uniform: p={p}"


def test_setunion_record_mode_converges(wl3):
    cat, joins = wl3.cat, wl3.joins
    wr = warmup(cat, joins, method="exact")
    est = estimate_union(wr.oracle)
    U = exact_union_size(cat, joins)
    s = SetUnionSampler(cat, joins, est.cover, membership="record", seed=8)
    ss = s.sample(60 * U)
    # record mode discovers the cover lazily; allow a looser bar
    p = _chi2_uniform(ss.matrix(), U)
    assert p > 1e-5, f"record mode wildly non-uniform: p={p}"
    assert ss.stats.revisions >= 0


def test_bernoulli_union_uniform(wl3):
    cat, joins = wl3.cat, wl3.joins
    wr = warmup(cat, joins, method="exact")
    sizes = {j.name: wr.oracle.size(j.name) for j in joins}
    U = exact_union_size(cat, joins)
    s = BernoulliUnionSampler(cat, joins, sizes, float(U), seed=9)
    ss = s.sample(80 * U)
    p = _chi2_uniform(ss.matrix(), U)
    assert p > 1e-3, f"Bernoulli union sampler not uniform: p={p}"
    assert ss.stats.canonical_rejects > 0


def test_disjoint_union_proportional(wl3):
    cat, joins = wl3.cat, wl3.joins
    wr = warmup(cat, joins, method="exact")
    sizes = {j.name: wr.oracle.size(j.name) for j in joins}
    s = DisjointUnionSampler(cat, joins, sizes, seed=10)
    ss = s.sample(6000)
    tot = sum(sizes.values())
    for j_idx, j in enumerate(joins):
        frac = (ss.home == j_idx).mean()
        assert frac == pytest.approx(sizes[j.name] / tot, abs=0.03)


def test_sampling_cost_within_theorem2_bound(wl3):
    """§3.3: expected candidate draws ≲ O(N + N log N) (generous constant)."""
    cat, joins = wl3.cat, wl3.joins
    wr = warmup(cat, joins, method="exact")
    est = estimate_union(wr.oracle)
    s = SetUnionSampler(cat, joins, est.cover, membership="probe", seed=11)
    N = 2000
    ss = s.sample(N)
    bound = 40 * (N + N * np.log(max(N, 2)))
    assert ss.stats.candidate_draws < bound


# ---------------------------------------------------------------------------
# Algorithm 2 (online union)
# ---------------------------------------------------------------------------


def test_online_union_end_to_end(wl3):
    cat, joins = wl3.cat, wl3.joins
    ou = OnlineUnionSampler(cat, joins, seed=12, phi=512, rw_batch=128)
    U = exact_union_size(cat, joins)
    ss = ou.sample(40 * U)
    assert len(ss) == 40 * U
    assert ss.stats.reuse_accepts > 0
    # marginal approx-uniformity (estimates refine online; generous bar)
    mat = ss.matrix()
    uni, counts = np.unique(mat.view([("", mat.dtype)] * mat.shape[1]).ravel(),
                            return_counts=True)
    assert uni.shape[0] >= 0.9 * U
    assert counts.max() <= 12 * counts.mean()


def test_online_reuse_rate_sane(wl3):
    """Guard for the l-factor bug: copies per reuse draw must be ~1."""
    cat, joins = wl3.cat, wl3.joins
    ou = OnlineUnionSampler(cat, joins, seed=13, phi=10_000, rw_batch=256)
    ss = ou.sample(500)
    if ss.stats.reuse_accepts:
        assert ss.stats.reuse_accepts <= 3 * ss.stats.iterations


def test_rejection_mode_predicate(wl3):
    """§8.3 mode 2: sampler-side predicate == sampling the filtered union."""
    from repro.core.predicates import Pred, RejectingPredicate, pushdown
    from repro.core.joins import JoinSpec
    cat, joins = wl3.cat, wl3.joins
    preds = [Pred("odate", "<=", 1500)]
    # ground truth: union of pushed-down joins
    filtered = [JoinSpec(j.name + "#f", pushdown(j, preds).nodes) for j in joins]
    U_f = exact_union_size(cat, filtered)
    if U_f < 10:
        pytest.skip("filtered union too small for a distribution check")
    wr = warmup(cat, joins, method="exact")
    est = estimate_union(wr.oracle)
    s = SetUnionSampler(cat, joins, est.cover, seed=21,
                        predicate=RejectingPredicate(preds))
    ss = s.sample(60 * U_f)
    assert (ss.rows["odate"] <= 1500).all()
    p = _chi2_uniform(ss.matrix(), U_f)
    assert p > 1e-3, f"rejection-mode predicate sampling not uniform: p={p}"


# ---------------------------------------------------------------------------
# Record-mode revision path (Alg 1 lines 10-12) + membership matrix
# ---------------------------------------------------------------------------


def test_record_mode_revision_path():
    """A tuple recorded at a later join moves home (and drops stale copies)
    when re-sampled from an earlier join."""
    from repro.core.cover import Cover
    from repro.core.relation import Relation
    rng = np.random.default_rng(0)
    R = Relation("Rbase", {"a": np.arange(12), "v": rng.integers(0, 5, 12)})
    # two identical single-relation joins: J1's true cover piece is empty,
    # but the lazy record only discovers that through revisions
    j0 = chain_join("J0", [R], [])
    j1 = chain_join("J1", [R], [])
    cat = Catalog()
    cover = Cover(order=["J0", "J1"],
                  piece_sizes={"J0": 12.0, "J1": 12.0},
                  join_sizes={"J0": 12.0, "J1": 12.0})
    s = SetUnionSampler(cat, [j0, j1], cover, membership="record", seed=5)
    ss = s.sample(150)
    assert ss.stats.revisions > 0, "revision path never exercised"
    assert ss.stats.backtrack_removed > 0, "stale copies never removed"
    assert ss.stats.cover_rejects > 0    # re-draws at J1 after revision reject
    # after revision a tuple has exactly one home join in the output
    keys = ss.matrix()
    uniq = {}
    for i, t in enumerate(map(tuple, keys.tolist())):
        uniq.setdefault(t, set()).add(int(ss.home[i]))
    assert all(len(h) == 1 for h in uniq.values()), \
        "a tuple kept copies credited to two different joins"


def test_membership_prober_matrix(wl3):
    from repro.core.joins import full_join_matrix
    from repro.core.membership import MembershipProber
    cat, joins = wl3.cat, wl3.joins
    prober = MembershipProber(cat, joins)
    attrs = list(joins[0].output_attrs)
    truth = {j.name: set(map(tuple, full_join_matrix(cat, j, attrs=attrs).tolist()))
             for j in joins}
    # probe every tuple of join 0 plus perturbed non-members
    mat0 = full_join_matrix(cat, joins[0], attrs=attrs)
    fakes = mat0 + 5003
    probe = np.concatenate([mat0, fakes])
    rows = {a: probe[:, i] for i, a in enumerate(attrs)}
    names = [j.name for j in joins]
    m = prober.membership_matrix(rows, names)
    assert m.shape == (probe.shape[0], len(joins))
    expected = np.zeros_like(m)
    for k, name in enumerate(names):
        expected[:, k] = [tuple(t) in truth[name] for t in probe.tolist()]
    assert np.array_equal(m, expected)
    # column order follows join_names; default order covers all joins
    m_default = prober.membership_matrix(rows)
    assert np.array_equal(m_default, m)
