"""Sharded execution layer: mesh-partitioned catalog + shard_map'd rounds.

Covers the acceptance bar of the sharding refactor: a 1-shard mesh must
reproduce the unsharded fused engine bit for bit; multi-shard runs (in
subprocesses with a forced host-platform device count, following the repo's
multi-device test idiom) must stay exactly uniform; the on-mesh moment merge
must equal the host ``merge_statistics``; and the serve queue must drain
correctly under concurrent requests.  The distributed wrapper's satellites
(backend forwarding, geometric oversample growth, ``SamplerStats.merge``)
are pinned here too.
"""

import dataclasses
import os
import subprocess
import sys
import threading

import numpy as np
import pytest
from scipy import stats as sps

from repro.core.distributed import (DistributedUnionSampler, merge_statistics,
                                    merge_streams, partition_of)
from repro.core.framework import estimate_union, warmup
from repro.core.overlap import exact_union_size
from repro.core.size_estimation import RunningMean
from repro.core.union_sampler import SamplerStats, SetUnionSampler
from repro.data.workloads import uq1, uq3

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_sub(code: str, devices: int = 4, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    # drop any inherited device-count flag (e.g. from the sharded-smoke CI
    # job) so the subprocess sees exactly one
    kept = [f for f in env.get("XLA_FLAGS", "").split()
            if "xla_force_host_platform_device_count" not in f]
    env["XLA_FLAGS"] = " ".join(
        kept + [f"--xla_force_host_platform_device_count={devices}"])
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def _chi2_uniform(sample_matrix, n_universe):
    uni, counts = np.unique(
        sample_matrix.view([("", sample_matrix.dtype)] *
                           sample_matrix.shape[1]).ravel(),
        return_counts=True)
    N = sample_matrix.shape[0]
    exp = N / n_universe
    chi2 = (float(((counts - exp) ** 2 / exp).sum())
            + (n_universe - uni.shape[0]) * exp)
    return 1 - sps.chi2.cdf(chi2, df=n_universe - 1)


# ---------------------------------------------------------------------------
# host-side helpers
# ---------------------------------------------------------------------------


def test_row_range_bounds_and_fp_partition():
    from repro.core.sharding import partition_of_fp32, row_range_bounds
    b = row_range_bounds(103, 4)
    assert b[0] == 0 and b[-1] == 103
    assert (np.diff(b) >= 25).all() and (np.diff(b) <= 26).all()
    fp = np.arange(1000, dtype=np.uint32) * np.uint32(2654435761)
    owner = partition_of_fp32(fp, 4)
    assert owner.min() >= 0 and owner.max() <= 3
    # ownership is a partition: deterministic and total
    assert np.array_equal(owner, partition_of_fp32(fp, 4))


def test_sampler_stats_merge_associative():
    a = SamplerStats(iterations=3, cover_rejects=1)
    b = SamplerStats(iterations=5, candidate_draws=7, revisions=2)
    c = SamplerStats(dropped_slots=4)
    left = SamplerStats().merge(a).merge(b).merge(c)
    right = SamplerStats().merge(a).merge(SamplerStats().merge(b).merge(c))
    assert left.as_dict() == right.as_dict()
    assert left.iterations == 8 and left.revisions == 2
    # snapshot is detached
    snap = a.snapshot()
    a.iterations += 100
    assert snap.iterations == 3


def test_merge_streams_uses_stats_merge():
    wl = uq3(scale=0.01, overlap=0.3, seed=0)
    est = estimate_union(warmup(wl.cat, wl.joins, method="exact").oracle)
    parts = []
    for rank in range(2):
        d = DistributedUnionSampler(wl.cat, wl.joins, est.cover, rank=rank,
                                    world=2, seed=3)
        parts.append(d.sample(200))
    merged = merge_streams(parts, seed=1)
    assert len(merged) == 400
    total = sum(p.stats.iterations for p in parts)
    assert merged.stats.iterations == total


# ---------------------------------------------------------------------------
# 1-shard mesh == unsharded fused engine, bit for bit
# ---------------------------------------------------------------------------


def test_one_shard_mesh_bitwise_equals_jax_engine():
    from repro.core.sharding import make_sampler_mesh
    wl = uq1(scale=0.05, overlap=0.5, seed=1, n_joins=2)
    est = estimate_union(warmup(wl.cat, wl.joins, method="exact").oracle)
    plain = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=7,
                            backend="jax", round_batch=1024)
    mesh = make_sampler_mesh(world=1)
    sharded = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=7,
                              backend="jax", round_batch=1024, mesh=mesh)
    a, b = plain.sample(3000), sharded.sample(3000)
    for attr in a.attrs:
        assert np.array_equal(a.rows[attr], b.rows[attr]), attr
    assert np.array_equal(a.home, b.home)
    assert np.array_equal(a.fingerprint, b.fingerprint)


def test_sharded_catalog_world1_degenerates_to_device_engine():
    from repro.core.backends.jax_backend import DeviceJoinMembership
    from repro.core.sharding import ShardedCatalog, make_sampler_mesh
    wl = uq3(scale=0.01, overlap=0.3, seed=0)
    scat = ShardedCatalog(wl.cat, wl.joins, mesh=make_sampler_mesh(world=1))
    for j in wl.joins:
        st = scat.trees[j.name]
        assert st.mode == "replicated"
        assert st.store_bounds[0] == 0 and st.store_bounds[-1] == st.tree.n_root
        np.testing.assert_allclose(np.asarray(st.root_prefix)[0],
                                   np.asarray(st.tree.root_wprefix))
        dm = DeviceJoinMembership(j)
        sm = scat.members[j.name]
        assert len(sm.rels) == len(dm.rels)
        for r_s, r_d in zip(sm.rels, dm.rels):
            assert r_s.attrs == r_d[0]
            assert r_s.kmax == r_d[3]
            n = int(np.asarray(r_s.n_owned)[0])
            assert n == r_d[4]
            np.testing.assert_array_equal(np.asarray(r_s.fp1)[0, :n],
                                          np.asarray(r_d[1]))


def test_sharded_catalog_columns_for_roundtrip():
    """Row-range store shards reassemble into the original columns."""
    from repro.core.sharding import ShardedCatalog, make_sampler_mesh
    wl = uq3(scale=0.01, overlap=0.3, seed=0)
    scat = ShardedCatalog(wl.cat, wl.joins, mesh=make_sampler_mesh(world=1))
    rel = wl.joins[0].nodes[0].relation
    b = scat.shard_bounds(rel)
    assert b[0] == 0 and b[-1] == rel.nrows
    shards = scat.columns_for(rel)
    assert scat.columns_for(rel) is shards          # cached
    for a, c in rel.columns.items():
        got = np.concatenate([np.asarray(shards[a])[s, :b[s + 1] - b[s]]
                              for s in range(scat.world)])
        np.testing.assert_array_equal(got, c)


# ---------------------------------------------------------------------------
# distributed wrapper satellites
# ---------------------------------------------------------------------------


def test_distributed_forwards_backend_to_inner_sampler():
    wl = uq1(scale=0.05, overlap=0.5, seed=1, n_joins=2)
    est = estimate_union(warmup(wl.cat, wl.joins, method="exact").oracle)
    d = DistributedUnionSampler(wl.cat, wl.joins, est.cover, rank=0, world=2,
                                backend="jax", round_batch=512, seed=3)
    assert d.inner._engine is not None          # device engine engaged
    ss = d.sample(500)
    assert len(ss) == 500


def test_seed_split_vs_hash_partition_uniformity():
    wl = uq1(scale=0.05, overlap=0.5, seed=1, n_joins=2)
    est = estimate_union(warmup(wl.cat, wl.joins, method="exact").oracle)
    U = exact_union_size(wl.cat, wl.joins)
    world = 2
    for scheme in ("seed-split", "hash-partition"):
        parts = []
        for rank in range(world):
            d = DistributedUnionSampler(wl.cat, wl.joins, est.cover,
                                        rank=rank, world=world, scheme=scheme,
                                        seed=5)
            parts.append(d.sample(40 * U))
        merged = merge_streams(parts, seed=2)
        if scheme == "hash-partition":
            # per-rank streams are partition-pure
            for rank, p in enumerate(parts):
                assert (partition_of(p.fingerprint, world) == rank).all()
        p_val = _chi2_uniform(merged.matrix(), U)
        assert p_val > 1e-3, f"{scheme} union stream not uniform (p={p_val})"


def test_hash_partition_underfill_error_carries_counts():
    wl = uq3(scale=0.01, overlap=0.3, seed=0)
    est = estimate_union(warmup(wl.cat, wl.joins, method="exact").oracle)
    d = DistributedUnionSampler(wl.cat, wl.joins, est.cover, rank=0,
                                world=64, scheme="hash-partition", seed=3)
    with pytest.raises(RuntimeError, match=r"got \d+ of 4000"):
        d.sample(4000, oversample=0.01, max_rounds=1)


def test_hash_partition_geometric_growth_completes():
    """A partition smaller than |U|/world finishes via oversample growth."""
    wl = uq1(scale=0.05, overlap=0.5, seed=1, n_joins=2)
    est = estimate_union(warmup(wl.cat, wl.joins, method="exact").oracle)
    d = DistributedUnionSampler(wl.cat, wl.joins, est.cover, rank=3, world=4,
                                scheme="hash-partition", seed=9)
    # tiny initial oversample: the fixed-oversample code under-fills every
    # round; geometric growth must still converge within the budget
    ss = d.sample(300, oversample=0.05, max_rounds=16)
    assert len(ss) == 300
    assert (partition_of(ss.fingerprint, 4) == 3).all()


# ---------------------------------------------------------------------------
# multi-device paths (subprocess with forced host device count)
# ---------------------------------------------------------------------------


def test_on_mesh_moment_merge_matches_host_merge_statistics():
    out = _run_sub(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.sharding import make_sampler_mesh, psum_merge_moments
from repro.core.size_estimation import RunningMean
from repro.core.distributed import merge_statistics

world, batch = 4, 64
rng = np.random.default_rng(0)
xs = rng.exponential(5.0, (world, batch))

mesh = make_sampler_mesh(world=world)
def f(x):
    x = x[0]
    mean = jnp.mean(x)
    m2 = jnp.sum((x - mean) ** 2)
    n, gm, gm2 = psum_merge_moments(jnp.int32(x.shape[0]), mean, m2, "shards")
    return n[None], gm[None], gm2[None]
n, gm, gm2 = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("shards"),),
                               out_specs=P("shards"), check_rep=False))(
    jnp.asarray(xs, jnp.float32))

host_parts = []
for s in range(world):
    r = RunningMean()
    r.update_batch(xs[s])
    host_parts.append(r)
host = merge_statistics(host_parts)
assert int(n[0]) == host.count == world * batch
np.testing.assert_allclose(float(gm[0]), host.mean, rtol=1e-5)
np.testing.assert_allclose(float(gm2[0]), host.m2, rtol=1e-4)
print("OK")
""")
    assert "OK" in out


def test_multi_shard_uniform_and_matches_host_marginal():
    out = _run_sub(r"""
import numpy as np
from scipy import stats as sps
from repro.core.framework import estimate_union, warmup
from repro.core.overlap import exact_union_size
from repro.core.sharding import ShardedCatalog, make_sampler_mesh
from repro.core.union_sampler import SetUnionSampler
from repro.data.workloads import uq1

wl = uq1(scale=0.05, overlap=0.5, seed=1, n_joins=2)
est = estimate_union(warmup(wl.cat, wl.joins, method="exact").oracle)
U = exact_union_size(wl.cat, wl.joins)
mesh = make_sampler_mesh(world=4)
s = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=11, backend="jax",
                    round_batch=512, mesh=mesh)
N = 120 * U
ss = s.sample(N)
assert len(ss) == N
m = ss.matrix()
uni, counts = np.unique(m.view([("", m.dtype)] * m.shape[1]).ravel(),
                        return_counts=True)
exp = N / U
chi2 = float(((counts - exp) ** 2 / exp).sum()) + (U - uni.shape[0]) * exp
p = 1 - sps.chi2.cdf(chi2, df=U - 1)
assert p > 1e-3, p

host = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=3).sample(8000)
fa = np.bincount(host.home, minlength=2) / len(host)
fb = np.bincount(ss.home, minlength=2) / len(ss)
assert np.abs(fa - fb).max() < 0.03, (fa, fb)

# on-mesh ONLINE-UNION refinement smoke
from repro.core.online import OnlineUnionSampler
ou = OnlineUnionSampler(wl.cat, wl.joins, seed=5, phi=512, rw_batch=64,
                        backend="jax", mesh=mesh)
out = ou.sample(100)
assert len(out) == 100
counts = {k: v.count for k, v in ou.estimator.size_stats.items()}
assert all(c % (4 * 64) == 0 and c > 0 for c in counts.values()), counts
print("OK")
""", devices=4, timeout=900)
    assert "OK" in out


def test_multi_shard_cyclic_union_uniform():
    """UQ4's cyclic piece under a 4-shard mesh: residual verification stays
    local (replicated node indexes), cover membership rides the one
    fingerprint exchange, and the union stream stays exactly uniform."""
    out = _run_sub(r"""
import numpy as np
from scipy import stats as sps
from repro.core.framework import estimate_union, warmup
from repro.core.overlap import exact_union_size
from repro.core.sharding import make_sampler_mesh
from repro.core.union_sampler import SetUnionSampler
from repro.data.workloads import uq4

wl = uq4(scale=0.02, seed=0)
est = estimate_union(warmup(wl.cat, wl.joins, method="exact").oracle)
U = exact_union_size(wl.cat, wl.joins)
mesh = make_sampler_mesh(world=4)
s = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=11, backend="jax",
                    round_batch=512, mesh=mesh)
N = 120 * U
ss = s.sample(N)
assert len(ss) == N
m = ss.matrix()
uni, counts = np.unique(m.view([("", m.dtype)] * m.shape[1]).ravel(),
                        return_counts=True)
exp = N / U
chi2 = float(((counts - exp) ** 2 / exp).sum()) + (U - uni.shape[0]) * exp
p = 1 - sps.chi2.cdf(chi2, df=U - 1)
assert p > 1e-3, p
print("OK")
""", devices=4, timeout=900)
    assert "OK" in out


# ---------------------------------------------------------------------------
# serve queue
# ---------------------------------------------------------------------------


def test_serve_queue_drains_under_concurrent_requests():
    from repro.serve import SampleService
    wl = uq3(scale=0.01, overlap=0.3, seed=0)
    est = estimate_union(warmup(wl.cat, wl.joins, method="exact").oracle)
    sampler = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=3)
    results = {}
    errors = []
    with SampleService(sampler, batch=512, prefetch=2) as svc:
        def worker(tid, n):
            try:
                results[tid] = svc.request(n, timeout=120)
            except Exception as e:            # pragma: no cover
                errors.append(e)
        threads = [threading.Thread(target=worker, args=(t, 150 + 50 * t))
                   for t in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors
        assert svc.served == sum(150 + 50 * t for t in range(4))
    # each response has exactly the requested size and consistent columns
    for tid, ss in results.items():
        assert len(ss) == 150 + 50 * tid
        for a in ss.attrs:
            assert ss.rows[a].shape[0] == len(ss)
    # queue slices are disjoint segments of one i.i.d. stream: pooled
    # fingerprints across requests must match the engine's served count
    total = sum(len(ss) for ss in results.values())
    assert total == sum(150 + 50 * t for t in range(4))
    # merged accounting is visible and associative
    st = SamplerStats()
    for ss in results.values():
        st.merge(ss.stats)
    assert st.iterations > 0


def test_service_errors_on_unstarted_and_propagates_engine_failure():
    from repro.serve import SampleService

    class Boom:
        attrs = ["a"]
        stats = SamplerStats()

        def sample(self, n):
            raise ValueError("engine exploded")

    svc = SampleService(Boom(), batch=16, prefetch=1)
    with pytest.raises(RuntimeError, match="not started"):
        svc.request(4)
    with svc:
        with pytest.raises(RuntimeError, match="producer failed"):
            svc.request(4, timeout=10)
