"""Unit tests: relations, indexes, joins, splitting, predicates."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from conftest import brute_force_join, tiny_db

from repro.core.index import Catalog, build_index, build_rowset_index
from repro.core.joins import (JoinNode, JoinSpec, chain_join, full_join,
                              full_join_matrix, join_size,
                              materialize_residual)
from repro.core.predicates import Pred, pushdown
from repro.core.relation import Relation, combine_columns, fingerprint128
from repro.core.splitting import build_template, split_join, split_plans


# ---------------------------------------------------------------------------
# relation / fingerprints
# ---------------------------------------------------------------------------


def test_relation_basics():
    r = Relation("r", {"a": np.array([1, 2, 3]), "b": np.array([4, 5, 6])})
    assert r.nrows == 3
    assert r.attrs == ["a", "b"]
    f = r.filter(np.array([True, False, True]))
    assert f.nrows == 2
    p = r.project(["b"])
    assert p.attrs == ["b"]


def test_combine_columns_exact_packing_reversible_order():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 50, 200)
    b = rng.integers(0, 37, 200)
    k = combine_columns([a, b])
    # distinct pairs -> distinct keys
    pairs = set(zip(a.tolist(), b.tolist()))
    assert len(set(k.tolist())) == len(pairs)


@given(st.lists(st.integers(0, 1000), min_size=1, max_size=64))
@settings(max_examples=30, deadline=None)
def test_fingerprint_equal_rows_equal_fp(vals):
    a = np.asarray(vals, dtype=np.int64)
    f1 = fingerprint128([a, a + 1])
    f2 = fingerprint128([a.copy(), a + 1])
    assert np.array_equal(f1, f2)


def test_fingerprint_sensitive_to_order_and_value():
    a = np.array([1, 2, 3], dtype=np.int64)
    b = np.array([3, 2, 1], dtype=np.int64)
    assert not np.array_equal(fingerprint128([a, b]), fingerprint128([b, a]))
    assert not np.array_equal(fingerprint128([a]), fingerprint128([a + 1]))


# ---------------------------------------------------------------------------
# sorted index
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31), st.integers(2, 40))
@settings(max_examples=25, deadline=None)
def test_sorted_index_ranges_match_numpy(seed, dom):
    rng = np.random.default_rng(seed)
    col = rng.integers(0, dom, 300)
    rel = Relation("x", {"a": col})
    idx = build_index(rel, ["a"])
    q = rng.integers(-1, dom + 1, 64)
    lo, hi = idx.ranges(q)
    sv = np.sort(col)
    assert np.array_equal(lo, np.searchsorted(sv, q, "left"))
    assert np.array_equal(hi, np.searchsorted(sv, q, "right"))
    # row ids at positions actually hold the queried key
    for i, v in enumerate(q):
        if hi[i] > lo[i]:
            rows = idx.row_ids_at(np.arange(lo[i], hi[i]))
            assert (col[rows] == v).all()


def test_rowset_index_membership():
    rng = np.random.default_rng(1)
    rel = Relation("x", {"a": rng.integers(0, 10, 100),
                         "b": rng.integers(0, 10, 100)})
    rs = build_rowset_index(rel, ["a", "b"])
    probe = {"a": np.concatenate([rel.columns["a"][:20], np.array([99])]),
             "b": np.concatenate([rel.columns["b"][:20], np.array([99])])}
    got = rs.contains_rows(probe)
    assert got[:20].all()
    assert not got[20]


def test_catalog_stats():
    cat = Catalog()
    rel = Relation("x", {"a": np.array([1, 1, 1, 2, 3, 3])})
    st_ = cat.stats(rel, ["a"])
    assert st_.distinct == 3
    assert st_.max_degree == 3
    assert np.array_equal(st_.degree_of(np.array([1, 2, 3, 4])),
                          np.array([3, 1, 2, 0]))


# ---------------------------------------------------------------------------
# joins: full join vs brute force
# ---------------------------------------------------------------------------


def test_full_join_matches_brute_force(cat, chain_rst):
    res = full_join(cat, chain_rst)
    expected = brute_force_join(chain_rst)
    attrs = chain_rst.output_attrs
    got = {tuple(int(res[a][i]) for a in attrs)
           for i in range(len(next(iter(res.values()))))}
    want = {tuple(int(r[a]) for a in attrs) for r in expected}
    assert got == want
    n = next(iter(res.values())).shape[0]
    assert n == len(expected)
    assert join_size(cat, chain_rst) == len(expected)


def test_tree_join_and_validation(cat):
    R, S, T = tiny_db()
    # branching tree: S root with children R (on b) and T (on c)
    spec = JoinSpec("tree", [
        JoinNode("S", S, None, ()),
        JoinNode("R", R, "S", ("b",)),
        JoinNode("T", T, "S", ("c",)),
    ])
    assert not spec.is_chain
    res = full_join_matrix(cat, spec)
    want = brute_force_join(spec)
    assert res.shape[0] == len(want)
    with pytest.raises(ValueError):
        JoinSpec("bad", [JoinNode("S", S, None, ()),
                         JoinNode("R", R, "S", ("zzz",))])


def test_cyclic_join_residual(cat):
    rng = np.random.default_rng(2)
    R = Relation("R", {"a": rng.integers(0, 6, 30), "b": rng.integers(0, 6, 30),
                       "rid": np.arange(30)})
    S = Relation("S", {"b": rng.integers(0, 6, 30), "c": rng.integers(0, 6, 30),
                       "sid": np.arange(30)})
    T = Relation("T", {"c": rng.integers(0, 6, 30), "a": rng.integers(0, 6, 30),
                       "tid": np.arange(30)})
    spec = JoinSpec("tri", [
        JoinNode("R", R, None, ()),
        JoinNode("S", S, "R", ("b",)),
        JoinNode("T", T, None, ("c", "a"), kind="residual"),
    ])
    assert spec.is_cyclic
    res = full_join_matrix(cat, spec)
    want = brute_force_join(spec)
    assert res.shape[0] == len(want)


# ---------------------------------------------------------------------------
# splitting / templates
# ---------------------------------------------------------------------------


def test_template_covers_schema(cat, chain_rst):
    tpl = build_template([chain_rst])
    assert sorted(tpl) == sorted(chain_rst.output_attrs)


def test_split_plan_sources_valid(cat, chain_rst):
    plans = split_plans([chain_rst])
    plan = plans[0]
    for pair in plan.pairs:
        if pair.source_alias is not None:
            rel = chain_rst.node(pair.source_alias).relation
            assert set(pair.attrs) <= set(rel.attrs)
        else:
            assert pair.path_aliases


def test_split_fake_edges_prefer_same_source():
    rng = np.random.default_rng(3)
    # one wide relation: all pairs co-located => all edges after first are fake
    W = Relation("W", {c: rng.integers(0, 5, 20) for c in "abcd"})
    spec = JoinSpec("w", [JoinNode("W", W, None, ())])
    plan = split_join(spec, ["a", "b", "c", "d"])
    assert all(p.fake_edge_to_prev for p in plan.pairs[1:])


# ---------------------------------------------------------------------------
# predicates
# ---------------------------------------------------------------------------


def test_pushdown_equals_posthoc_filter(cat, chain_rst):
    preds = [Pred("d", "<=", 6), Pred("a", ">", 2)]
    filtered = pushdown(chain_rst, preds)
    res_f = full_join_matrix(cat, filtered, attrs=chain_rst.output_attrs)
    res = full_join(cat, chain_rst)
    keep = (res["d"] <= 6) & (res["a"] > 2)
    attrs = chain_rst.output_attrs
    want = np.stack([res[a][keep] for a in attrs], axis=1)
    got = {tuple(r) for r in res_f.tolist()}
    exp = {tuple(r) for r in want.tolist()}
    assert got == exp
