"""Infrastructure: checkpointing, fault tolerance, pipeline, distributed
sampling, gradient compression, optimizers."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.framework import estimate_union, warmup
from repro.core.distributed import (DistributedUnionSampler, merge_statistics,
                                    merge_streams, partition_of)
from repro.core.size_estimation import RunningMean
from repro.data.encode import TokenEncoder
from repro.data.pipeline import SyntheticPipeline, UnionSamplePipeline
from repro.data.workloads import uq3
from repro.launch.ft import FTConfig, TrainSupervisor
from repro.train.grad_compress import compress_decompress, init_error_feedback
from repro.train.optimizer import OptConfig, apply_update, init_opt_state


# ---------------------------------------------------------------------------
# checkpointer
# ---------------------------------------------------------------------------


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"step": jnp.asarray(3, jnp.int32),
            "params": {"w": jnp.asarray(rng.standard_normal((4, 5))),
                       "b": jnp.asarray(rng.standard_normal(5))},
            "opt": {"m.w": jnp.zeros((4, 5))}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    st = _state()
    ck.save(3, st, {"rng": [1, 2, 3]})
    assert ck.latest_step() == 3
    got, pp = ck.restore()
    assert pp["rng"] == [1, 2, 3]
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(st["params"]["w"]))


def test_checkpoint_gc_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        st = _state(s)
        st["step"] = jnp.asarray(s)
        ck.save(s, st)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2
    assert ck.latest_step() == 4


def test_checkpoint_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state())
    d = os.path.join(tmp_path, "step_00000001")
    fn = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, fn))
    np.save(os.path.join(d, fn), arr + 1)
    with pytest.raises(IOError):
        ck.restore(1)


# ---------------------------------------------------------------------------
# fault-tolerant supervisor
# ---------------------------------------------------------------------------


def test_supervisor_restart_after_failure(tmp_path):
    ck = Checkpointer(str(tmp_path))
    calls = {"n": 0}

    def step_fn(state, batch):
        s = dict(state)
        s["step"] = state["step"] + 1
        s["params"] = {"w": state["params"]["w"] + 1.0}
        return s, {"loss": 0.0}

    def next_batch():
        return {"x": np.zeros(2)}

    failed = {"done": False}

    def injector(step):
        if step == 7 and not failed["done"]:
            failed["done"] = True
            raise RuntimeError("simulated preemption")

    sup = TrainSupervisor(step_fn, next_batch, ck,
                          FTConfig(checkpoint_every=2, max_restarts=3))
    state = {"step": jnp.asarray(0), "params": {"w": jnp.zeros(3)}}
    out = sup.run(state, 10, fail_injector=injector)
    assert int(out["step"]) == 10
    assert sup.stats.restarts == 1
    # params consistent with step count (each step +1, restart resumed from ckpt)
    np.testing.assert_allclose(np.asarray(out["params"]["w"]),
                               np.full(3, 10.0))


def test_supervisor_straggler_skip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    n = {"i": 0}

    def next_batch():
        n["i"] += 1
        return None if n["i"] % 3 == 0 else {"x": 1}  # every 3rd batch late

    def step_fn(state, batch):
        return {"step": state["step"] + 1}, {}

    sup = TrainSupervisor(step_fn, next_batch, ck, FTConfig(checkpoint_every=100))
    out = sup.run({"step": jnp.asarray(0)}, 6)
    assert int(out["step"]) == 6
    assert sup.stats.skipped_batches >= 2


# ---------------------------------------------------------------------------
# pipeline / encoding
# ---------------------------------------------------------------------------


def test_token_encoder_pack_shapes():
    enc = TokenEncoder(["a", "b", "c"], vocab_size=1024)
    rng = np.random.default_rng(0)
    rows = {k: rng.integers(0, 100, 300) for k in "abc"}
    toks, tgts, used = enc.pack(rows, batch=4, seq_len=64)
    assert toks.shape == (4, 64) and tgts.shape == (4, 64)
    assert toks.dtype == np.int32
    assert (toks[:, 0] == 1).all()              # BOS
    assert (toks < 1024).all() and (toks >= 0).all()
    np.testing.assert_array_equal(tgts[:, :-1], toks[:, 1:])


def test_union_pipeline_end_to_end():
    wl = uq3(scale=0.01, overlap=0.3, seed=0)
    wr = warmup(wl.cat, wl.joins, method="exact")
    est = estimate_union(wr.oracle)
    from repro.core.union_sampler import SetUnionSampler
    sampler = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=3)
    enc = TokenEncoder(sorted(wl.joins[0].output_attrs), vocab_size=2048)
    pipe = UnionSamplePipeline(sampler, enc, batch=2, seq_len=32)
    toks, tgts = pipe.next_batch()
    assert toks.shape == (2, 32)
    st = pipe.state_dict()
    pipe.load_state_dict(st)
    assert pipe.stats.batches == 1


# ---------------------------------------------------------------------------
# distributed sampling
# ---------------------------------------------------------------------------


def test_seed_split_streams_uniform():
    from scipy import stats as sps
    wl = uq3(scale=0.01, overlap=0.3, seed=0)
    wr = warmup(wl.cat, wl.joins, method="exact")
    est = estimate_union(wr.oracle)
    from repro.core.overlap import exact_union_size
    U = exact_union_size(wl.cat, wl.joins)
    parts = []
    for rank in range(4):
        ds = DistributedUnionSampler(wl.cat, wl.joins, est.cover,
                                     rank=rank, world=4, seed=5)
        parts.append(ds.sample(20 * U))
    merged = merge_streams(parts)
    mat = merged.matrix()
    uni, counts = np.unique(mat.view([("", mat.dtype)] * mat.shape[1]).ravel(),
                            return_counts=True)
    exp = len(merged) / U
    chi2 = float(((counts - exp) ** 2 / exp).sum()) + (U - uni.shape[0]) * exp
    p = 1 - sps.chi2.cdf(chi2, df=U - 1)
    assert p > 1e-3


def test_hash_partition_disjoint():
    wl = uq3(scale=0.01, overlap=0.3, seed=0)
    wr = warmup(wl.cat, wl.joins, method="exact")
    est = estimate_union(wr.oracle)
    seen = {}
    for rank in range(2):
        ds = DistributedUnionSampler(wl.cat, wl.joins, est.cover, rank=rank,
                                     world=2, scheme="hash-partition", seed=6)
        ss = ds.sample(200)
        pid = partition_of(ss.fingerprint, 2)
        assert (pid == rank).all()
        seen[rank] = {tuple(r) for r in ss.matrix().tolist()}
    assert not (seen[0] & seen[1])


def test_running_mean_merge_associative():
    rng = np.random.default_rng(0)
    xs = rng.standard_normal(1000)
    bulk = RunningMean()
    bulk.update_batch(xs)
    parts = []
    for i in range(4):
        rm = RunningMean()
        rm.update_batch(xs[i * 250:(i + 1) * 250])
        parts.append(rm)
    merged = merge_statistics(parts)
    assert merged.mean == pytest.approx(bulk.mean)
    assert merged.variance == pytest.approx(bulk.variance, rel=1e-9)


# ---------------------------------------------------------------------------
# optimizers / grad compression
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(kind):
    opt = OptConfig(kind=kind, lr=0.1, weight_decay=0.0)
    target = jnp.asarray(np.random.default_rng(0).standard_normal((8, 4)))
    params = {"w": jnp.zeros((8, 4))}
    state = init_opt_state(opt, params)
    step = jnp.zeros((), jnp.int32)
    for i in range(200):
        g = {"w": params["w"] - target}
        params, state = apply_update(opt, params, g, state, step + i)
    assert float(jnp.abs(params["w"] - target).mean()) < 0.05


def test_grad_compress_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.standard_normal((64, 64)) * 1e-3)}
    state = {"ef": init_error_feedback(g_true)}
    acc = np.zeros((64, 64))
    n = 50
    for _ in range(n):
        out, state = compress_decompress(g_true, state)
        acc += np.asarray(out["w"])
    # error feedback: accumulated compressed grads ≈ accumulated true grads
    np.testing.assert_allclose(acc / n, np.asarray(g_true["w"]),
                               rtol=0.02, atol=1e-6)


def test_compressed_psum_multidevice_subprocess():
    """compressed_psum == psum (within quant error) on a real 4-device mesh."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh, shard_map
from repro.train.grad_compress import compressed_psum
mesh = make_mesh((4,), ("d",))
x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 128)), jnp.float32)
def f(x):
    return compressed_psum(x, "d"), jax.lax.psum(x, "d")
got, want = jax.jit(shard_map(f, mesh=mesh, in_specs=P("d"),
                              out_specs=(P("d"), P("d"))))(x)
err = float(jnp.max(jnp.abs(got - want)))
scale = float(jnp.max(jnp.abs(want)))
assert err <= 0.05 * scale + 1e-5, (err, scale)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_train_step_with_grad_compression():
    """compress_grads=True end-to-end: error feedback state threads through."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import (TrainConfig, init_train_state,
                                        make_train_step)
    cfg = get_smoke_config("minitron-8b")
    tc = TrainConfig(opt=OptConfig(lr=1e-3), total_steps=10, warmup_steps=1,
                     compress_grads=True)
    state = init_train_state(cfg, tc, seed=0)
    assert "ef" in state
    step = jax.jit(make_train_step(cfg, tc))
    rng = np.random.default_rng(3)
    batch = {"tokens": jnp.asarray(rng.integers(4, cfg.vocab, (2, 64)), jnp.int32),
             "targets": jnp.asarray(rng.integers(4, cfg.vocab, (2, 64)), jnp.int32)}
    s1, m1 = step(state, batch)
    s2, m2 = step(s1, batch)
    assert np.isfinite(float(m2["loss"]))
    # error-feedback buffers are being used (non-zero residuals)
    ef_norm = sum(float(jnp.abs(v).sum()) for v in s2["ef"].values())
    assert ef_norm > 0


def test_microbatch_equivalence():
    """n_microbatches=2 gradients ≈ single-batch gradients (same data)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import (TrainConfig, init_train_state,
                                        make_train_step)
    cfg = get_smoke_config("minitron-8b")
    rng = np.random.default_rng(4)
    batch = {"tokens": jnp.asarray(rng.integers(4, cfg.vocab, (4, 64)), jnp.int32),
             "targets": jnp.asarray(rng.integers(4, cfg.vocab, (4, 64)), jnp.int32)}
    outs = []
    for n_micro in (1, 2):
        tc = TrainConfig(opt=OptConfig(lr=1e-2), total_steps=10,
                         warmup_steps=1, n_microbatches=n_micro)
        state = init_train_state(cfg, tc, seed=0)
        s1, _ = jax.jit(make_train_step(cfg, tc))(state, batch)
        outs.append(np.asarray(s1["params"]["blocks.wq"]))
    # same update direction within bf16 tolerance
    d = np.abs(outs[0] - outs[1]).max()
    scale = np.abs(outs[0]).max()
    assert d <= 0.1 * scale, (d, scale)


def test_moe_shard_map_equivalence_subprocess():
    """shard_map EP MoE == dense MoE (dropless) on a real 8-device mesh."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh, set_mesh
from repro.models.moe import MoEDims, moe_ffn, moe_ffn_dist, moe_param_shapes
mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
dims = MoEDims(d_model=32, n_experts=8, top_k=2, d_ff=64, capacity_factor=16.0)
params = {k: jnp.asarray(rng.standard_normal(s) * 0.1, jnp.float32)
          for k, s in moe_param_shapes(dims).items()}
x = jnp.asarray(rng.standard_normal((4, 16, 32)), jnp.float32)
dense_out, _ = jax.jit(lambda p, x: moe_ffn(p, x, dims, capacity=64))(params, x)
with set_mesh(mesh):
    dist_out, _ = jax.jit(lambda p, x: moe_ffn_dist(p, x, dims))(params, x)
    # production loss shape (transformer.forward_train: loss + 0.01*aux) —
    # a loss that drops aux feeds a symbolic-Zero cotangent into the aux
    # pmean, which 0.4.x shard_map cannot transpose
    def loss(p, x):
        out, aux = moe_ffn_dist(p, x, dims)
        return out.sum() + 0.01 * aux
    g = jax.jit(jax.grad(loss))(params, x)
err = float(jnp.abs(dense_out - dist_out).max())
assert err < 2e-5, err
gn = sum(float(jnp.abs(v).sum()) for v in g.values())
assert np.isfinite(gn) and gn > 0
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
