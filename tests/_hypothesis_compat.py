"""Hypothesis with a plain-pytest fallback.

Tier-1 tests property-test with hypothesis when it is installed (see
``requirements-dev.txt``); environments without it (minimal CI images, the
benchmark container) still need the suite to collect and run.  This module
re-exports the real ``given``/``settings``/``st`` when available and otherwise
provides a tiny deterministic stand-in: each ``@given`` test runs
``max_examples`` seeded random examples drawn from the same strategy shapes
(``integers``, ``sampled_from``, ``lists`` — the only ones the suite uses).

Import as ``from _hypothesis_compat import given, settings, st``.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rnd: random.Random):
            return self._draw(rnd)

    class _Strategies:
        """The subset of hypothesis.strategies the test-suite uses."""

        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def sampled_from(seq) -> _Strategy:
            items = list(seq)
            return _Strategy(lambda rnd: rnd.choice(items))

        @staticmethod
        def lists(elements: _Strategy, min_size: int = 0,
                  max_size: int = 16) -> _Strategy:
            return _Strategy(lambda rnd: [
                elements.example_from(rnd)
                for _ in range(rnd.randint(min_size, max_size))
            ])

    st = _Strategies()

    def settings(max_examples: int = 20, deadline=None, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            def wrapper():
                n = getattr(fn, "_compat_max_examples", 20)
                rnd = random.Random(0xC0FFEE)
                for _ in range(n):
                    fn(*(s.example_from(rnd) for s in strategies))
            # plain zero-arg signature on purpose: pytest must not try to
            # resolve the drawn arguments as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
