"""Engine-wide telemetry (DESIGN.md §10): metrics core, tracing, endpoints.

Pinned here:

* metrics core — registry get-or-create, labeled children, thread-safe
  increments, quantile interpolation, Prometheus text exposition shape,
  and the ``REPRO_OBS`` kill switch;
* :class:`TraceRing` bounded wrap with monotone sequence numbers;
* :class:`MetricsServer` ``/metrics`` + ``/healthz``;
* ``SamplerStats.merge``/``snapshot`` semantics and the serve queue's
  merged accounting under concurrent producers;
* **parity** — the per-piece carry counters ride in the jitted programs
  unconditionally, so device/host streams stay bitwise identical whether
  telemetry is on or off, and ``piece_stats`` itself agrees bit for bit;
* BENCH ``write_json`` appending runs to ``history`` instead of clobbering;
* ONLINE-UNION exposing its refinement history (``refresh_count``,
  ``last_refresh_at``, trace events) instead of discarding it.
"""

import json
import re
import threading
import urllib.request

import numpy as np
import pytest

from repro import obs
from repro.core.backends import get_backend
from repro.core.backends.jax_backend import JaxUnionSampler
from repro.core.framework import estimate_union, warmup
from repro.core.union_sampler import SamplerStats, SetUnionSampler
from repro.data.workloads import uq1
from repro.serve.service import SampleService


@pytest.fixture
def registry():
    """Fresh registry installed as the global one for the test's duration."""
    reg = obs.MetricsRegistry()
    prev = obs.set_registry(reg)
    try:
        yield reg
    finally:
        obs.set_registry(prev)


@pytest.fixture
def obs_on():
    obs.set_enabled(True)
    try:
        yield
    finally:
        obs.set_enabled(None)


# ---------------------------------------------------------------------------
# metrics core
# ---------------------------------------------------------------------------


def test_registry_get_or_create_and_kind_conflicts(registry):
    c1 = registry.counter("t_total", "help one")
    c2 = registry.counter("t_total")
    assert c1 is c2
    with pytest.raises(ValueError):
        registry.gauge("t_total")           # same name, different kind
    with pytest.raises(ValueError):
        registry.counter("bad name!")       # invalid metric name


def test_counter_labels_and_negative_rejection(registry):
    c = registry.counter("req_total", "requests", labelnames=("join",))
    c.labels("a").inc()
    c.labels("a").inc(2)
    c.labels(join="b").inc(5)
    snap = registry.snapshot()["req_total"]["series"]
    assert snap[(("join", "a"),)] == 3
    assert snap[(("join", "b"),)] == 5
    with pytest.raises(ValueError):
        c.labels("a").inc(-1)


def test_gauge_set_function_pull_time(registry):
    g = registry.gauge("depth", "queue depth")
    box = {"v": 7}
    g.set_function(lambda: box["v"])
    assert registry.snapshot()["depth"]["series"][()] == 7
    box["v"] = 3
    assert registry.snapshot()["depth"]["series"][()] == 3


def test_histogram_quantiles_and_exposition(registry):
    h = registry.histogram("lat_seconds", "latency",
                           buckets=(0.001, 0.01, 0.1, 1.0))
    for v in [0.0005] * 50 + [0.05] * 50:
        h.observe(v)
    assert h.quantile(0.25) <= 0.001
    assert 0.01 <= h.quantile(0.99) <= 0.1
    text = registry.render()
    # cumulative buckets, +Inf terminal, _sum/_count present
    buckets = re.findall(r'lat_seconds_bucket{le="([^"]+)"} (\d+)', text)
    counts = [int(c) for _, c in buckets]
    assert counts == sorted(counts) and buckets[-1][0] == "+Inf"
    assert counts[-1] == 100
    assert re.search(r"^lat_seconds_count 100$", text, re.M)
    assert "# TYPE lat_seconds histogram" in text


def test_thread_safe_increments(registry):
    c = registry.counter("race_total")

    def work():
        for _ in range(10_000):
            c.inc()

    ts = [threading.Thread(target=work) for _ in range(8)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert registry.snapshot()["race_total"]["series"][()] == 80_000


def test_kill_switch_env_and_override(monkeypatch):
    monkeypatch.setenv("REPRO_OBS", "off")
    obs.set_enabled(None)
    assert not obs.enabled()
    obs.set_enabled(True)
    assert obs.enabled()
    obs.set_enabled(None)
    monkeypatch.setenv("REPRO_OBS", "on")
    assert obs.enabled()


# ---------------------------------------------------------------------------
# trace ring
# ---------------------------------------------------------------------------


def test_trace_ring_wrap_and_seq():
    ring = obs.TraceRing(capacity=4)
    for i in range(10):
        ring.append("tick", i=i)
    assert len(ring) == 4 and ring.total == 10
    evs = ring.events()
    assert [e["i"] for e in evs] == [6, 7, 8, 9]
    assert [e["seq"] for e in evs] == [6, 7, 8, 9]
    assert ring.last()["i"] == 9
    assert ring.events("other") == []


# ---------------------------------------------------------------------------
# HTTP endpoints
# ---------------------------------------------------------------------------


def test_metrics_server_endpoints(registry):
    registry.counter("up_total", "ticks").inc(3)
    with obs.MetricsServer(registry, port=0) as srv:
        with urllib.request.urlopen(f"{srv.url}/metrics") as r:
            body = r.read().decode()
            assert r.status == 200
            assert r.headers["Content-Type"] == obs.PROMETHEUS_CONTENT_TYPE
        assert "up_total 3" in body
        with urllib.request.urlopen(f"{srv.url}/healthz") as r:
            assert r.read().decode().strip() == "ok"
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{srv.url}/nope")


# ---------------------------------------------------------------------------
# SamplerStats merge / snapshot
# ---------------------------------------------------------------------------


def test_sampler_stats_merge_and_snapshot():
    a = SamplerStats(iterations=3, candidate_draws=10, cover_rejects=1)
    b = SamplerStats(iterations=2, candidate_draws=5, reuse_accepts=4)
    snap = a.snapshot()
    out = a.merge(b)
    assert out is a                                  # in-place, returns self
    assert a.iterations == 5 and a.candidate_draws == 15
    assert a.cover_rejects == 1 and a.reuse_accepts == 4
    assert snap.iterations == 3                      # snapshot unaffected
    # associativity on a third operand
    c = SamplerStats(iterations=1)
    lhs = SamplerStats().merge(a).merge(c)
    rhs = SamplerStats().merge(c).merge(a)
    assert lhs.as_dict() == rhs.as_dict()


# ---------------------------------------------------------------------------
# engine parity with telemetry on / off + piece_stats consistency
# ---------------------------------------------------------------------------


def _cover(wl):
    return estimate_union(warmup(wl.cat, wl.joins, method="exact").oracle).cover


def _engine(wl, cover, mode, seed=7):
    backend = get_backend("jax", wl.cat, wl.joins, seed=2)
    return JaxUnionSampler(backend, cover, seed=seed, round_batch=512,
                           fused_rounds=mode)


def _assert_same(a, b):
    for attr in a.attrs:
        np.testing.assert_array_equal(a.rows[attr], b.rows[attr])
    np.testing.assert_array_equal(a.home, b.home)
    np.testing.assert_array_equal(a.fingerprint, b.fingerprint)


def test_parity_unchanged_by_telemetry(registry):
    """Samples are bitwise identical device vs host, obs on vs off — the
    per-piece counters are pure extra carry outputs, never inputs."""
    wl = uq1(scale=0.02, overlap=0.4, seed=0, n_joins=2)
    cover = _cover(wl)
    streams = {}
    for obs_state in (True, False):
        obs.set_enabled(obs_state)
        try:
            dev, host = _engine(wl, cover, "device"), _engine(wl, cover, "host")
            for n in (700, 333):
                _assert_same(dev.sample(n), host.sample(n))
            assert dev.stats.as_dict() == host.stats.as_dict()
            assert np.array_equal(dev.piece_stats, host.piece_stats)
            streams[obs_state] = dev.sample(200)
        finally:
            obs.set_enabled(None)
    _assert_same(streams[True], streams[False])


def test_piece_stats_consistency(registry, obs_on):
    """Per-piece draws tie out to the scalar candidate_draws counter, and
    the registry's per-join series mirror piece_stats."""
    wl = uq1(scale=0.02, overlap=0.4, seed=0, n_joins=2)
    s = _engine(wl, _cover(wl), "device")
    s.sample(800)
    d = s.piece_stats_dict()
    assert sum(v["draws"] for v in d.values()) == s.stats.candidate_draws
    assert all(v["draws"] > 0 for v in d.values())
    assert all(v["accepts"] <= v["draws"] for v in d.values())
    series = registry.snapshot()["repro_engine_piece_draws_total"]["series"]
    for name, v in d.items():
        assert series[(("join", name),)] == v["draws"]


# ---------------------------------------------------------------------------
# serve: merged accounting under concurrent requesters + request metrics
# ---------------------------------------------------------------------------


def test_serve_concurrent_accounting_and_metrics(registry, obs_on):
    wl = uq1(scale=0.02, overlap=0.5, seed=1, n_joins=2)
    cover = _cover(wl)
    s = SetUnionSampler(wl.cat, wl.joins, cover, seed=13, backend="jax",
                        round_batch=1024, fused_rounds="device")
    assert callable(getattr(s, "sample_async", None))
    got, errs = [], []

    def worker(n):
        try:
            got.append(len(svc.request(n)))
        except Exception as e:          # pragma: no cover - diagnostic
            errs.append(e)

    with SampleService(s, batch=1024, prefetch=2) as svc:
        ts = [threading.Thread(target=worker, args=(n,))
              for n in (300, 700, 450, 1100)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        st = svc.stats()
        assert not errs and sorted(got) == [300, 450, 700, 1100]
        assert svc.served == 2550
        # merged accounting equals the single engine's own counters
        assert st.as_dict() == s.stats.as_dict()
    # after stop() the producers are quiesced and the final collector
    # refresh has run — gauges now agree with the engine's settled counters
    snap = registry.snapshot()
    assert snap["repro_serve_requests_total"]["series"][()] == 4
    assert snap["repro_serve_samples_total"]["series"][()] == 2550
    lat = snap["repro_serve_request_seconds"]["series"][()]
    assert lat["count"] == 4 and lat["sum"] > 0
    assert snap["repro_serve_request_seconds_p50"]["series"][()] > 0
    # engine stat gauges carry the replica label
    eng = snap["repro_serve_engine_stat"]["series"]
    assert eng[(("replica", "0"), ("field", "candidate_draws"))] \
        == s.stats.candidate_draws


def test_serve_respects_kill_switch(registry):
    obs.set_enabled(False)
    try:
        wl = uq1(scale=0.02, overlap=0.5, seed=1, n_joins=2)
        s = SetUnionSampler(wl.cat, wl.joins, _cover(wl), seed=13,
                            backend="jax", round_batch=1024,
                            fused_rounds="device")
        with SampleService(s, batch=1024, prefetch=1) as svc:
            assert len(svc.request(500)) == 500
        assert "repro_serve_requests_total" not in registry.snapshot()
    finally:
        obs.set_enabled(None)


# ---------------------------------------------------------------------------
# BENCH history append
# ---------------------------------------------------------------------------


def test_write_json_appends_history(tmp_path):
    from benchmarks.common import write_json
    path = str(tmp_path / "BENCH_x.json")
    write_json(path, records=[{"name": "r1", "samples_per_s": 100.0}])
    write_json(path, records=[{"name": "r1", "samples_per_s": 120.0}])
    d = json.loads((tmp_path / "BENCH_x.json").read_text())
    assert [r["samples_per_s"] for r in d["records"]] == [120.0]
    assert len(d["history"]) == 2
    assert [h["records"][0]["samples_per_s"] for h in d["history"]] \
        == [100.0, 120.0]
    assert all(h["git_sha"] for h in d["history"])
    assert d["history"][-1]["ts"]


def test_write_json_migrates_legacy_clobber_files(tmp_path):
    from benchmarks.common import write_json
    path = tmp_path / "BENCH_legacy.json"
    path.write_text(json.dumps(
        {"meta": {"git_sha": "old"},
         "records": [{"name": "r1", "samples_per_s": 50.0}]}))
    write_json(str(path), records=[{"name": "r1", "samples_per_s": 70.0}])
    d = json.loads(path.read_text())
    assert len(d["history"]) == 2
    assert d["history"][0]["git_sha"] == "old"
    assert d["history"][0]["records"][0]["samples_per_s"] == 50.0


# ---------------------------------------------------------------------------
# ONLINE-UNION refinement history
# ---------------------------------------------------------------------------


def test_online_exposes_refinement_history(registry, obs_on):
    from repro.core.online import OnlineUnionSampler
    wl = uq1(scale=0.02, overlap=0.5, seed=0, n_joins=2)
    s = OnlineUnionSampler(wl.cat, wl.joins, seed=3, phi=5)
    assert s.refresh_count == 0 and s.last_refresh_at == -1
    assert s.trace.last("init")["union_size"] > 0
    s.sample(600)
    assert s.refresh_count >= 1
    assert 0 < s.last_refresh_at <= s.stats.iterations
    assert s.backtrack_count == s.stats.backtrack_removed
    ev = s.trace.last("refresh")
    assert ev["at_iteration"] == s.last_refresh_at
    assert set(ev["hist_gap"]) == set(s.names)
    assert isinstance(ev["confident"], bool) and ev["kept"] >= 0
    snap = registry.snapshot()
    assert snap["repro_online_refreshes_total"]["series"][()] \
        == s.refresh_count
    assert snap["repro_online_union_size"]["series"][()] > 0
