"""Estimation subsystem: device estimator == host reference.

Covers the acceptance criteria of the estimator refactor:

* host-vs-device equivalence of the HT size/overlap statistics — exact walk
  counts and tight numerical agreement on shared walk traces (the device
  accumulators are float32; the host reference is float64),
* device walk probabilities exactly reproduce the wander-join law
  ``p(t) = 1/|R_root| · Π 1/d_i`` recomputed from host indexes,
* CI coverage: the 90% half-widths bracket the exact join/overlap/union
  sizes on small TPC-H-style workloads,
* the reservoir-capped walk pool (bounded memory, estimates untouched),
* ONLINE-UNION backend routing (``"jax"`` → device estimator, unknown
  selectors raise), and
* the device histogram-overlap algebra matching the host §5 bounds.
"""

import numpy as np
import pytest

from conftest import tiny_db

from repro.core.estimators import (EstimatorBackend, NumpyEstimator,
                                   ReservoirPool, get_estimator)
from repro.core.estimators.jax_estimator import (DeviceHistogramOverlap,
                                                 DeviceRunning,
                                                 DeviceWalkJoin, JaxEstimator,
                                                 _batch_moments,
                                                 _merge_moments)
from repro.core.index import Catalog
from repro.core.joins import chain_join, full_join_matrix
from repro.core.overlap import (HistogramOverlap, RandomWalkOverlap,
                                exact_overlap, exact_union_size)
from repro.core.relation import combine_columns
from repro.core.size_estimation import RunningMean, WanderJoinSizeEstimator
from repro.data.tpch import make_variants


def _two_chains(seed=0, overlap=0.5):
    """Two chain joins over variant relations with controlled overlap."""
    R, S, T = tiny_db(seed, n_r=80, n_s=90, n_t=70)
    cat = Catalog()
    Rv = make_variants(R, 2, overlap, seed=seed + 10)
    Sv = make_variants(S, 2, overlap, seed=seed + 11)
    Tv = make_variants(T, 2, overlap, seed=seed + 12)
    j0 = chain_join("J0", [Rv[0], Sv[0], Tv[0]], ["b", "c"])
    j1 = chain_join("J1", [Rv[1], Sv[1], Tv[1]], ["b", "c"])
    return cat, [j0, j1]


# ---------------------------------------------------------------------------
# factory / protocol
# ---------------------------------------------------------------------------


def test_estimator_factory_and_protocol():
    cat, joins = _two_chains(0)
    for name in ("numpy", "jax"):
        est = get_estimator(name, cat, joins, seed=0, batch=64)
        assert isinstance(est, EstimatorBackend)
        assert est.name == name
    inst = NumpyEstimator(cat, joins)
    assert get_estimator(inst, cat, joins) is inst
    with pytest.raises(ValueError, match="unknown estimator"):
        get_estimator("torch", cat, joins)
    # the historical host class is the numpy estimator
    assert issubclass(RandomWalkOverlap, NumpyEstimator)


# ---------------------------------------------------------------------------
# device walks: exact wander-join probabilities
# ---------------------------------------------------------------------------


def test_device_walk_probabilities_match_host_law():
    import jax
    from repro.core.join_sampler import JoinSampler
    cat, joins = _two_chains(1)
    spec = joins[0]
    w = DeviceWalkJoin(cat, spec)
    rows, prob, ok = jax.jit(lambda k: w.draw(k, 1024))(jax.random.PRNGKey(7))
    rows = {a: np.asarray(v, np.int64) for a, v in rows.items()}
    prob, ok = np.asarray(prob), np.asarray(ok)
    assert ok.any()
    js = JoinSampler(cat, spec, method="wj")
    expect = np.full(1024, 1.0 / js.n_root)
    alive = np.ones(1024, bool)
    for n in js.order[1:]:
        idx = cat.index(js._reduced[n.alias], list(n.edge_attrs))
        d = idx.degrees(combine_columns([rows[a] for a in n.edge_attrs]))
        alive &= d > 0
        expect = np.where(alive, expect / np.maximum(d, 1), 0.0)
    assert np.array_equal(ok, alive)
    assert np.allclose(prob[ok], expect[ok], rtol=1e-5)


def test_device_walk_pallas_path_matches_jnp():
    """use_pallas routes hops through the fused kernel; identical draws."""
    import jax
    R, S, T = tiny_db(3)
    cat = Catalog()
    spec = chain_join("RST", [R, S, T], ["b", "c"])
    w1 = DeviceWalkJoin(cat, spec, use_pallas=False)
    w2 = DeviceWalkJoin(cat, spec, use_pallas=True)
    key = jax.random.PRNGKey(0)
    r1, p1, o1 = jax.jit(lambda k: w1.draw(k, 256))(key)
    r2, p2, o2 = jax.jit(lambda k: w2.draw(k, 256))(key)
    assert np.array_equal(np.asarray(o1), np.asarray(o2))
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    for a in spec.output_attrs:
        assert np.array_equal(np.asarray(r1[a]), np.asarray(r2[a])), a


# ---------------------------------------------------------------------------
# shared-trace equivalence: device accumulators == host RunningMean
# ---------------------------------------------------------------------------


def test_device_accumulator_matches_host_on_shared_trace():
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    # heavy-tailed draws like 1/p(t): spread over 6 orders of magnitude
    xs = (10.0 ** rng.uniform(0, 6, 4096)) * (rng.random(4096) < 0.7)
    host = RunningMean()
    dev = DeviceRunning()
    for lo in range(0, xs.shape[0], 512):
        b = xs[lo:lo + 512]
        host.update_batch(b)
        dev.state = _merge_moments(*dev.state,
                                   *_batch_moments(jnp.asarray(b, jnp.float32)))
    assert dev.count == host.count
    assert dev.mean == pytest.approx(host.mean, rel=1e-4)
    assert dev.variance == pytest.approx(host.variance, rel=1e-3)
    assert dev.half_width(0.90) == pytest.approx(host.half_width(0.90), rel=1e-3)


def test_device_observe_stats_match_host_fed_same_walks():
    """Feed the device walk trace into the host reference accumulators."""
    import jax
    cat, joins = _two_chains(2, overlap=0.7)
    est = JaxEstimator(cat, joins, seed=4, batch=512)
    host_size, host_ov = RunningMean(), RunningMean()
    prober = NumpyEstimator(cat, joins).prober
    pivot = est._pivot(joins)
    other = [j for j in joins if j.name != pivot.name][0]
    for _ in range(6):
        est.observe(joins, rounds=1)
    # replay the pooled device walks through the float64 host pipeline
    for rows, prob in est.walk_pool[pivot.name]:
        ok = prob > 0
        inv = np.where(ok, 1.0 / np.maximum(prob, 1e-300), 0.0)
        host_size.update_batch(inv)
        ind = ok & prober.contains(other.name, rows)
        host_ov.update_batch(np.where(ind, inv, 0.0))
    dsize = est.size_stats[pivot.name]
    dov = est.overlap_stats[frozenset(j.name for j in joins)]
    assert dsize.count == host_size.count == 6 * 512
    assert dov.count == host_ov.count
    assert dsize.mean == pytest.approx(host_size.mean, rel=1e-4)
    assert dov.mean == pytest.approx(host_ov.mean, rel=1e-4)
    assert dsize.half_width(0.90) == pytest.approx(host_size.half_width(0.90),
                                                   rel=1e-3)
    assert dov.half_width(0.90) == pytest.approx(host_ov.half_width(0.90),
                                                 rel=1e-3)


# ---------------------------------------------------------------------------
# independent traces: estimates agree with ground truth, CIs bracket it
# ---------------------------------------------------------------------------


def test_device_estimates_and_ci_coverage():
    cat, joins = _two_chains(1, overlap=0.7)
    exact_sizes = {j.name: full_join_matrix(cat, j).shape[0] for j in joins}
    exact_ov = exact_overlap(cat, joins)
    exact_u = exact_union_size(cat, joins)
    est = JaxEstimator(cat, joins, seed=1, batch=1024)
    ov = est.estimate(joins, rel_halfwidth=0.1, max_walks=40_000,
                      min_walks=8192)
    sizes = {j.name: est.join_size(j, min_walks=8192) for j in joins}
    for j in joins:
        assert sizes[j.name] == pytest.approx(exact_sizes[j.name], rel=0.2)
        # 90% CI brackets the exact size (seeded; 3x guards tail flake)
        hw = est.size_stats[j.name].half_width(0.90)
        assert abs(sizes[j.name] - exact_sizes[j.name]) <= 3 * hw
    assert ov.value == pytest.approx(exact_ov, rel=0.3)
    assert abs(ov.value - exact_ov) <= 3 * ov.half_width
    # union size via |J0| + |J1| - |O|: half-widths compose additively
    u_est = sum(sizes.values()) - ov.value
    u_hw = (ov.half_width +
            sum(est.size_stats[j.name].half_width(0.90) for j in joins))
    assert abs(u_est - exact_u) <= 3 * u_hw
    assert u_est == pytest.approx(exact_u, rel=0.25)


def test_host_and_device_estimates_agree_on_independent_traces():
    cat, joins = _two_chains(0, overlap=0.6)
    h = NumpyEstimator(cat, joins, seed=2, batch=1024)
    d = JaxEstimator(cat, joins, seed=3, batch=1024)
    ho = h.estimate(joins, rel_halfwidth=0.15, max_walks=30_000, min_walks=8192)
    do = d.estimate(joins, rel_halfwidth=0.15, max_walks=30_000, min_walks=8192)
    # independent streams: estimates must agree within joint CI
    assert abs(ho.value - do.value) <= 3 * (ho.half_width + do.half_width)


def test_device_estimator_empty_join_is_zero():
    R, S, T = tiny_db(0)
    S_empty = S.filter(np.zeros(S.nrows, dtype=bool), name="S_empty")
    cat = Catalog()
    spec = chain_join("EMPTY", [R, S_empty, T], ["b", "c"])
    est = JaxEstimator(cat, [spec], seed=0, batch=256)
    out = est.observe([spec], rounds=2)
    assert out.value == 0.0
    assert est.size_stats[spec.name].count == 512
    assert est.join_size(spec, min_walks=256) == 0.0


# ---------------------------------------------------------------------------
# reservoir pool cap
# ---------------------------------------------------------------------------


def test_reservoir_pool_caps_memory_without_touching_estimates():
    cat, joins = _two_chains(3)
    uncapped = NumpyEstimator(cat, joins, seed=9, batch=128, pool_cap=10_000)
    capped = NumpyEstimator(cat, joins, seed=9, batch=128, pool_cap=4)
    for _ in range(20):
        a = uncapped.observe([joins[0]], rounds=1)
        b = capped.observe([joins[0]], rounds=1)
        assert a.value == b.value and a.walks == b.walks
    name = uncapped._pivot([joins[0]]).name
    assert len(uncapped.walk_pool[name]) == 20
    assert len(capped.walk_pool[name]) == 4
    # retained batches are real walk batches
    for rows, prob in capped.walk_pool[name]:
        assert prob.shape == (128,)
    assert capped.drain_pool()[name] is not None
    assert capped.walk_pool == {}


def test_reservoir_pool_unit():
    pool = ReservoirPool(cap=3, seed=0)
    for i in range(50):
        pool.add("J", ({"x": np.array([i])}, np.array([float(i)])))
    assert pool.n_batches("J") == 3
    kept = sorted(int(p[0]) for _, p in pool.pools["J"])
    assert len(set(kept)) == 3          # three distinct batches survive
    with pytest.raises(ValueError):
        ReservoirPool(cap=0)


# ---------------------------------------------------------------------------
# ONLINE-UNION routing
# ---------------------------------------------------------------------------


def test_online_union_routes_backend_to_estimator():
    from repro.core.online import OnlineUnionSampler
    cat, joins = _two_chains(1, overlap=0.6)
    ou = OnlineUnionSampler(cat, joins, seed=5, phi=256, rw_batch=64,
                            backend="jax")
    assert isinstance(ou.estimator, JaxEstimator)
    # device membership indexes are shared with the sampling backend
    assert ou.estimator.members is ou.backend.members
    ss = ou.sample(100)
    assert len(ss) == 100
    ou_np = OnlineUnionSampler(cat, joins, seed=5, phi=256, rw_batch=64)
    assert isinstance(ou_np.estimator, NumpyEstimator)
    assert ou_np.rw is ou_np.estimator   # historical alias


def test_online_union_unknown_backend_raises():
    from repro.core.online import OnlineUnionSampler
    cat, joins = _two_chains(0)
    with pytest.raises(ValueError, match="unknown backend"):
        OnlineUnionSampler(cat, joins, backend="torch")
    with pytest.raises(ValueError, match="unknown estimator"):
        OnlineUnionSampler(cat, joins, estimator="torch")


def test_warmup_backend_routing():
    from repro.core.framework import warmup
    cat, joins = _two_chains(2)
    from repro.core.framework import estimate_union
    wr = warmup(cat, joins, method="random_walk", backend="jax",
                rw_max_walks=2048, rw_batch=256)
    assert isinstance(wr.aux, JaxEstimator)
    assert estimate_union(wr.oracle).union_size_cover > 0
    wr_h = warmup(cat, joins, method="histogram", backend="jax")
    assert isinstance(wr_h.aux, DeviceHistogramOverlap)


# ---------------------------------------------------------------------------
# device histogram overlap == host
# ---------------------------------------------------------------------------


def test_device_histogram_matches_host():
    from repro.data.workloads import uq3
    wl = uq3(scale=0.01, overlap=0.3, seed=0)
    host = HistogramOverlap(wl.cat, wl.joins)
    dev = DeviceHistogramOverlap(wl.cat, wl.joins)
    import itertools
    deltas = [list(d) for r in (1, 2, 3)
              for d in itertools.combinations(wl.joins, r)]
    for delta in deltas:
        h = host.estimate(delta)
        d = dev.estimate(delta)
        assert d == pytest.approx(h, rel=1e-5), \
            f"delta={[j.name for j in delta]}: host {h} device {d}"
    for j in wl.joins:
        assert dev.join_size_bound(j) == host.join_size_bound(j)


def test_device_histogram_is_sound_upper_bound():
    for seed in range(3):
        cat, joins = _two_chains(seed)
        dev = DeviceHistogramOverlap(cat, joins)
        assert dev.estimate(joins) >= exact_overlap(cat, joins)


# ---------------------------------------------------------------------------
# WanderJoinSizeEstimator device routing
# ---------------------------------------------------------------------------


def test_wander_join_size_estimator_jax_backend():
    R, S, T = tiny_db(3)
    cat = Catalog()
    spec = chain_join("RST", [R, S, T], ["b", "c"])
    true_size = full_join_matrix(cat, spec).shape[0]
    est = WanderJoinSizeEstimator(cat, spec, seed=0, batch=1024, backend="jax")
    for _ in range(20):
        est.step()
    assert est.walks == 20 * 1024
    assert est.estimate == pytest.approx(true_size, rel=0.15)
    with pytest.raises(ValueError, match="backend"):
        WanderJoinSizeEstimator(cat, spec, backend="torch")
