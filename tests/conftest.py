"""Shared fixtures: tiny relational databases and workloads.

NOTE: no XLA_FLAGS here — tests must see 1 CPU device (the dry-run sets its
own device count in its own process).
"""

import numpy as np
import pytest

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.index import Catalog
from repro.core.joins import JoinNode, JoinSpec, chain_join
from repro.core.relation import Relation


def tiny_db(seed=0, n_r=40, n_s=60, n_t=50, dom=12):
    """Three small relations forming chains R(a,b) ⋈ S(b,c) ⋈ T(c,d)."""
    rng = np.random.default_rng(seed)
    R = Relation("R", {"a": rng.integers(0, dom, n_r),
                       "b": rng.integers(0, dom, n_r),
                       "rid": np.arange(n_r)})
    S = Relation("S", {"b": rng.integers(0, dom, n_s),
                       "c": rng.integers(0, dom, n_s),
                       "sid": np.arange(n_s)})
    T = Relation("T", {"c": rng.integers(0, dom, n_t),
                       "d": rng.integers(0, dom, n_t),
                       "tid": np.arange(n_t)})
    return R, S, T


@pytest.fixture
def cat():
    return Catalog()


@pytest.fixture
def chain_rst(cat):
    R, S, T = tiny_db()
    return chain_join("RST", [R, S, T], ["b", "c"])


def brute_force_join(spec: JoinSpec):
    """O(n^k) nested-loop join for ground truth on tiny data."""
    order = spec.expansion_order()
    rows = [dict(zip(order[0].relation.attrs, vals))
            for vals in zip(*order[0].relation.columns.values())]
    for node in order[1:]:
        rel = node.relation
        rel_rows = [dict(zip(rel.attrs, vals))
                    for vals in zip(*rel.columns.values())]
        out = []
        for r in rows:
            for s in rel_rows:
                if all(r[a] == s[a] for a in node.edge_attrs):
                    m = dict(r)
                    m.update(s)
                    out.append(m)
        rows = out
    return rows
