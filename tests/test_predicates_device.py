"""§8.3 predicates and record-mode membership on the device engine.

Acceptance bar of the predicate tentpole: both §8.3 treatments of the UQ2
regime run inside the fused Algorithm-1 round with host-identical
semantics — chi-square uniformity against the exact filtered universes on
both engines (pushdown AND rejection mode), the fused device loop bit-equal
to its host twin on a shared trace (``pred_rejects`` included), the device
record engine equivalent to a sequential host dict replay of its captured
rounds (revisions and emission invalidation included), and a 1-device mesh
reproducing the unsharded engine bit for bit under rejection predicates.
"""

import numpy as np
import pytest
from scipy import stats as sps

from repro.core.backends.jax_backend import (JaxRecordUnionSampler, fp32_np)
from repro.core.framework import estimate_union, warmup
from repro.core.index import Catalog
from repro.core.joins import chain_join
from repro.core.overlap import exact_union_size
from repro.core.predicates import Pred, pred_mask_np, rejection
from repro.core.union_sampler import SetUnionSampler
from repro.data.tpch import generate
from repro.data.workloads import uq2


def _chi2_uniform(sample_matrix, n_universe):
    uni, counts = np.unique(
        sample_matrix.view([("", sample_matrix.dtype)] *
                           sample_matrix.shape[1]).ravel(),
        return_counts=True)
    N = sample_matrix.shape[0]
    exp = N / n_universe
    chi2 = (float(((counts - exp) ** 2 / exp).sum())
            + (n_universe - uni.shape[0]) * exp)
    return 1 - sps.chi2.cdf(chi2, df=n_universe - 1)


@pytest.fixture(scope="module", params=["pushdown", "rejection"])
def uq2_setup(request):
    wl = uq2(scale=0.02, seed=0, pred_mode=request.param)
    est = estimate_union(warmup(wl.cat, wl.joins, method="exact").oracle)
    U = exact_union_size(wl.cat, wl.joins)
    return request.param, wl, est, U


# ---------------------------------------------------------------------------
# chi-square uniformity: both §8.3 modes, both engines, exact filtered law
# ---------------------------------------------------------------------------


def test_uq2_uniform_both_engines(uq2_setup):
    mode, wl, est, U = uq2_setup
    N = 120 * U
    for backend in ("numpy", "jax"):
        s = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=7,
                            backend=backend, round_batch=2048)
        ss = s.sample(N)
        assert len(ss) == N
        p = _chi2_uniform(ss.matrix(), U)
        assert p > 1e-3, f"{backend} not uniform on UQ2/{mode} (p={p})"
        if mode == "rejection":
            # in-round predicate kills happened and were accounted
            assert ss.stats.pred_rejects > 0, backend
            # every emitted row satisfies its home piece's own predicates
            for j, spec in enumerate(wl.joins):
                sel = ss.home == j
                if spec.reject_preds and sel.any():
                    rows = {a: ss.rows[a][sel] for a in ss.attrs}
                    assert pred_mask_np(spec.reject_preds, rows).all(), \
                        spec.name


def test_uq2_rejection_pred_rejects_in_stats_dict(uq2_setup):
    mode, wl, est, U = uq2_setup
    if mode != "rejection":
        pytest.skip("rejection-mode accounting only")
    s = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=5, backend="jax",
                        round_batch=1024)
    ss = s.sample(2000)
    d = ss.stats.as_dict()
    assert d["pred_rejects"] == ss.stats.pred_rejects > 0


# ---------------------------------------------------------------------------
# shared trace: fused device loop == host twin, bit for bit, preds included
# ---------------------------------------------------------------------------


def test_fused_device_matches_host_twin_bitwise(uq2_setup):
    mode, wl, est, U = uq2_setup
    kw = dict(seed=9, backend="jax", round_batch=512)
    a = SetUnionSampler(wl.cat, wl.joins, est.cover,
                        fused_rounds="device", **kw).sample(3000)
    b = SetUnionSampler(wl.cat, wl.joins, est.cover,
                        fused_rounds="host", **kw).sample(3000)
    for attr in a.attrs:
        assert np.array_equal(a.rows[attr], b.rows[attr]), attr
    assert np.array_equal(a.home, b.home)
    assert np.array_equal(a.fingerprint, b.fingerprint)
    assert a.stats.as_dict() == b.stats.as_dict()
    if mode == "rejection":
        assert a.stats.pred_rejects > 0


# ---------------------------------------------------------------------------
# 1-device mesh: sharded loop == unsharded under rejection predicates
# ---------------------------------------------------------------------------


def test_uq2_one_shard_mesh_bitwise_equals_jax_engine(uq2_setup):
    from repro.core.sharding import make_sampler_mesh
    mode, wl, est, U = uq2_setup
    if mode != "rejection":
        pytest.skip("sharded predicate path is the rejection lowering")
    plain = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=11,
                            backend="jax", round_batch=1024)
    sharded = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=11,
                              backend="jax", round_batch=1024,
                              mesh=make_sampler_mesh(world=1))
    a, b = plain.sample(3000), sharded.sample(3000)
    for attr in a.attrs:
        assert np.array_equal(a.rows[attr], b.rows[attr]), attr
    assert np.array_equal(a.home, b.home)
    assert a.stats.as_dict() == b.stats.as_dict()
    assert a.stats.pred_rejects > 0


# ---------------------------------------------------------------------------
# record-mode membership on device
# ---------------------------------------------------------------------------


def test_record_engine_uniform(uq2_setup):
    mode, wl, est, U = uq2_setup
    N = 60 * U
    for backend in ("numpy", "jax"):
        s = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=13,
                            membership="record", backend=backend,
                            round_batch=2048)
        if backend == "jax":
            assert isinstance(s._engine, JaxRecordUnionSampler)
        ss = s.sample(N)
        assert len(ss) == N
        p = _chi2_uniform(ss.matrix(), U)
        assert p > 1e-3, \
            f"{backend} record-mode not uniform on UQ2/{mode} (p={p})"


@pytest.fixture(scope="module")
def revision_workload():
    """Two rejection flavours of partsupp ⋈ part whose predicate windows
    overlap on the middle psize quintile: the later cover piece claims
    overlap tuples an earlier piece then re-draws, exercising the record
    engine's revision + emission-invalidation path (not just inserts)."""
    db = generate(0.1, seed=1)
    base = chain_join("PSP", [db["partsupp"], db["part"]], [("pk",)])
    ps = db["part"].columns["psize"]
    lo, hi = int(np.percentile(ps, 40)), int(np.percentile(ps, 60))
    j1 = rejection(base, [Pred("psize", "<=", hi)], name="PSP_LOW")
    j2 = rejection(base, [Pred("psize", ">=", lo)], name="PSP_HIGH")
    cat = Catalog()
    est = estimate_union(warmup(cat, [j1, j2], method="exact").oracle)
    return cat, [j1, j2], est


def test_record_engine_matches_sequential_host_replay(revision_workload):
    """The device record rounds (batched fingerprint-multiset updates) must
    equal a strictly sequential host dict replay of the captured candidate
    stream — same final record dict, same revision/invalidation/cover-reject
    counts, and every emitted row's home settled to its final record home."""
    cat, joins, est = revision_workload
    s = SetUnionSampler(cat, joins, est.cover, membership="record", seed=7,
                        backend="jax", round_batch=64)
    eng = s._engine
    assert isinstance(eng, JaxRecordUnionSampler)
    eng.debug_capture = True
    out = s.sample(1200)
    assert len(out) == 1200
    assert s.stats.revisions > 0          # the interesting path was exercised
    assert s.stats.backtrack_removed > 0

    # sequential replay: feed every captured candidate through a host dict.
    # Cover rejections (home < j) are counted over the whole batch — they are
    # state-independent within a piece (inserts/revisions only set home = j)
    # and the device counts them before applying the take quota.
    attrs = sorted(eng.attrs)
    rec = {}
    rev = rej = inval = 0
    for rd in eng.captured:
        need = rd["need"]
        for j, (rows, acc) in enumerate(rd["pieces"]):
            f1 = fp32_np([rows[a].astype(np.int64) for a in attrs],
                         salt=1).astype(np.uint64)
            f2 = fp32_np([rows[a].astype(np.int64) for a in attrs],
                         salt=2).astype(np.uint64)
            fps = (f1 << np.uint64(32)) | f2
            taken = 0
            for i in np.nonzero(acc)[0]:
                fp = int(fps[i])
                e = rec.get(fp)
                if e is not None and e[0] < j:
                    rej += 1
                    continue
                if taken >= need[j]:
                    continue
                if e is None:
                    rec[fp] = [j, 1]
                elif e[0] > j:
                    rev += 1
                    inval += e[1]
                    rec[fp] = [j, 1]
                else:
                    e[1] += 1
                taken += 1

    assert eng.record_dict() == {k: tuple(v) for k, v in rec.items()}
    assert rev == s.stats.revisions
    assert inval == s.stats.backtrack_removed
    assert rej == s.stats.cover_rejects

    # settled emission: every returned row's home equals its final record home
    dev = eng.record_dict()
    f1 = fp32_np([out.rows[a].astype(np.int64) for a in attrs],
                 salt=1).astype(np.uint64)
    f2 = fp32_np([out.rows[a].astype(np.int64) for a in attrs],
                 salt=2).astype(np.uint64)
    fps = (f1 << np.uint64(32)) | f2
    assert all(dev[int(fp)][0] == h for fp, h in zip(fps, out.home))


def test_record_engine_rejects_mesh(revision_workload):
    cat, joins, est = revision_workload
    from repro.core.sharding import make_sampler_mesh
    with pytest.raises(ValueError, match="record"):
        SetUnionSampler(cat, joins, est.cover, membership="record",
                        backend="jax", mesh=make_sampler_mesh(world=1))
