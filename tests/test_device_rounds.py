"""Persistent device-resident round loop: parity, banking, pipelined serve.

Acceptance bar of the perf tentpole: moving the whole multi-round
Algorithm-1 loop into one jitted ``lax.while_loop`` (device-resident
shortfall carry, FIFO ring-buffer surplus banks, on-device stats) must not
change a single emitted sample relative to the host-driven round loop it
replaces.  Pinned here:

* device loop vs host loop — bit-equal rows/home/fingerprint *and* identical
  ``SamplerStats`` across multiple calls whose surplus banks carry over;
* FIFO-bank equivalence with a tiny ring capacity (wrap-around exercised);
* chi-square uniformity of UQ1 and cyclic UQ4 streams served through the
  pipelined ``SampleService`` (``sample_async`` dispatch-then-drain);
* a 1-device sharded pin: the in-loop fingerprint exchange (collectives
  inside the device loop) matches the between-round exchange of the host
  mode, and both match the unsharded engine.
"""

import numpy as np
from _hypothesis_compat import given, settings, st
from scipy import stats as sps

from repro.core.backends import get_backend
from repro.core.backends.jax_backend import JaxUnionSampler
from repro.core.framework import estimate_union, warmup
from repro.core.overlap import exact_union_size
from repro.core.union_sampler import SetUnionSampler
from repro.data.workloads import uq1, uq4
from repro.serve.service import SampleService


def _cover(wl):
    return estimate_union(warmup(wl.cat, wl.joins, method="exact").oracle).cover


def _assert_same_samples(a, b):
    assert a.attrs == b.attrs
    for attr in a.attrs:
        np.testing.assert_array_equal(a.rows[attr], b.rows[attr])
    np.testing.assert_array_equal(a.home, b.home)
    np.testing.assert_array_equal(a.fingerprint, b.fingerprint)


def _chi2_p(matrix, n_universe):
    uni, counts = np.unique(
        matrix.view([("", matrix.dtype)] * matrix.shape[1]).ravel(),
        return_counts=True)
    exp = matrix.shape[0] / n_universe
    chi2 = (float(((counts - exp) ** 2 / exp).sum())
            + (n_universe - uni.shape[0]) * exp)
    return 1 - sps.chi2.cdf(chi2, df=n_universe - 1)


# ---------------------------------------------------------------------------
# device loop == host loop, bit for bit
# ---------------------------------------------------------------------------


def test_device_loop_matches_host_loop_bitwise():
    wl = uq1(scale=0.02, overlap=0.4, seed=0, n_joins=2)
    cover = _cover(wl)
    dev = SetUnionSampler(wl.cat, wl.joins, cover, seed=11, backend="jax",
                          round_batch=512, fused_rounds="device")
    host = SetUnionSampler(wl.cat, wl.joins, cover, seed=11, backend="jax",
                           round_batch=512, fused_rounds="host")
    # successive odd-sized calls: the second and third reuse banked surplus
    # and carried shortfall from the first, so the whole carry state — not
    # just one round — must agree
    for n in (700, 1500, 333):
        _assert_same_samples(dev.sample(n), host.sample(n))
        assert dev.stats.as_dict() == host.stats.as_dict()


def test_fifo_bank_ring_wrap_equivalence():
    """A tiny ring capacity forces head wrap-around and push clipping; the
    device ring buffer must still replay the host twin's FIFO exactly."""
    wl = uq1(scale=0.02, overlap=0.4, seed=0, n_joins=2)
    cover = _cover(wl)

    def engine(mode):
        backend = get_backend("jax", wl.cat, wl.joins, seed=2)
        return JaxUnionSampler(backend, cover, seed=7, round_batch=512,
                               surplus_cap=64, fused_rounds=mode)

    dev, host = engine("device"), engine("host")
    for n in (333, 87, 512, 1025, 64):
        _assert_same_samples(dev.sample(n), host.sample(n))
    assert dev.stats.as_dict() == host.stats.as_dict()


# ---------------------------------------------------------------------------
# FIFO ring edges: cap-boundary wrap + the W=min(rb, 256) drain clamp at
# rb < 256, = 256, and > 256 (property-tested over request sequences)
# ---------------------------------------------------------------------------

# engine pairs are module-cached: each property example continues the same
# carry state, and the dev/host twins advance in lockstep so every prefix of
# the request stream is itself a parity check
_RING_PAIRS = {}


def _ring_pair(rb, cap):
    if (rb, cap) not in _RING_PAIRS:
        wl = uq1(scale=0.02, overlap=0.4, seed=0, n_joins=2)
        cover = _cover(wl)

        def engine(mode):
            backend = get_backend("jax", wl.cat, wl.joins, seed=3)
            return JaxUnionSampler(backend, cover, seed=17, round_batch=rb,
                                   surplus_cap=cap, fused_rounds=mode)

        _RING_PAIRS[(rb, cap)] = (engine("device"), engine("host"))
    return _RING_PAIRS[(rb, cap)]


def test_drain_window_clamp_across_round_batches():
    """W = min(rb, 256) on both sides of the clamp, including rb < 256."""
    for rb, want in ((128, 128), (256, 256), (512, 256), (1024, 256)):
        dev, _ = _ring_pair(rb, 48) if rb in (128, 512) else _ring_pair(rb, 64)
        assert dev._drain_w == want == min(rb, 256)


@settings(max_examples=4, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=400),
                min_size=1, max_size=3))
def test_ring_bank_cap_wrap_property(ns):
    """Tiny non-multiple caps force head wrap + push clipping at every
    drain-clamp regime; the device ring must replay the host FIFO exactly."""
    for rb, cap in ((128, 48), (256, 64), (512, 48)):
        dev, host = _ring_pair(rb, cap)
        for n in ns:
            _assert_same_samples(dev.sample(n), host.sample(n))
        assert dev.stats.as_dict() == host.stats.as_dict()


# ---------------------------------------------------------------------------
# pipelined serve path stays exactly uniform
# ---------------------------------------------------------------------------


def _serve_uniform(wl, n_per_cell=120):
    cover = _cover(wl)
    U = exact_union_size(wl.cat, wl.joins)
    s = SetUnionSampler(wl.cat, wl.joins, cover, seed=13, backend="jax",
                        round_batch=1024, fused_rounds="device")
    assert callable(getattr(s, "sample_async", None))  # pipelined path taken
    with SampleService(s, batch=2048, prefetch=2) as svc:
        ss = svc.request(n_per_cell * U)
    assert len(ss) == n_per_cell * U
    p = _chi2_p(ss.matrix(), U)
    assert p > 1e-3, p


def test_pipelined_serve_uniform_uq1():
    _serve_uniform(uq1(scale=0.02, overlap=0.5, seed=1, n_joins=2))


def test_pipelined_serve_uniform_uq4_cyclic():
    _serve_uniform(uq4(scale=0.01, seed=0))


# ---------------------------------------------------------------------------
# sharded (world=1): in-loop exchange == between-round exchange
# ---------------------------------------------------------------------------


def test_psum_counters_matches_host_merge():
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.core.sharding import (SHARD_AXIS, make_sampler_mesh,
                                     psum_counters)
    from repro.core.union_sampler import SamplerStats
    mesh = make_sampler_mesh(world=1)
    vec = jnp.array([3, 7, 1, 0, 2], jnp.int32)
    merged = jax.jit(shard_map(
        lambda v: psum_counters(v, SHARD_AXIS), mesh=mesh,
        in_specs=P(), out_specs=P()))(vec)
    host = SamplerStats(iterations=3, candidate_draws=7, cover_rejects=1,
                        residual_rejects=0, dropped_slots=2)
    assert merged.tolist() == [host.iterations, host.candidate_draws,
                               host.cover_rejects, host.residual_rejects,
                               host.dropped_slots]


def test_sharded_world1_inloop_exchange_matches_between_rounds():
    from repro.core.sharding import make_sampler_mesh
    wl = uq1(scale=0.02, overlap=0.4, seed=0, n_joins=2)
    cover = _cover(wl)

    def engine(mode, mesh):
        return SetUnionSampler(wl.cat, wl.joins, cover, seed=9,
                               backend="jax", round_batch=512, mesh=mesh,
                               fused_rounds=mode)

    in_loop = engine("device", make_sampler_mesh(world=1))
    between = engine("host", make_sampler_mesh(world=1))
    plain = engine("device", None)
    for n in (900, 411):
        a, b, c = in_loop.sample(n), between.sample(n), plain.sample(n)
        _assert_same_samples(a, b)
        _assert_same_samples(a, c)
        assert in_loop.stats.as_dict() == between.stats.as_dict()
        assert in_loop.stats.as_dict() == plain.stats.as_dict()
