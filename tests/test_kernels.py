"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp/numpy oracles."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.searchsorted import PreparedKeys, searchsorted_pallas


# ---------------------------------------------------------------------------
# searchsorted
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31), st.integers(1, 3000), st.integers(1, 800),
       st.sampled_from([8, 64, 2**20, 2**45]))
@settings(max_examples=25, deadline=None)
def test_searchsorted_sweep(seed, nk, nq, dom):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.integers(-dom, dom, nk).astype(np.int64))
    qs = rng.integers(-2 * dom, 2 * dom, nq).astype(np.int64)
    lo, hi = ops.searchsorted(keys, qs)
    lo_r, hi_r = ref.searchsorted_ref(keys, qs)
    assert np.array_equal(lo, lo_r)
    assert np.array_equal(hi, hi_r)


def test_searchsorted_equal_runs_across_blocks():
    keys = np.sort(np.repeat(np.arange(5, dtype=np.int64), 200))
    qs = np.arange(-1, 7, dtype=np.int64)
    lo, hi = ops.searchsorted(keys, qs)
    lo_r, hi_r = ref.searchsorted_ref(keys, qs)
    assert np.array_equal(lo, lo_r) and np.array_equal(hi, hi_r)


def test_searchsorted_prepared_reuse():
    rng = np.random.default_rng(0)
    keys = np.sort(rng.integers(0, 1000, 5000).astype(np.int64))
    prep = PreparedKeys(keys)
    for _ in range(3):
        qs = rng.integers(0, 1000, 300).astype(np.int64)
        lo, hi = searchsorted_pallas(prep, qs)
        lo_r, hi_r = ref.searchsorted_ref(keys, qs)
        assert np.array_equal(lo, lo_r) and np.array_equal(hi, hi_r)


# ---------------------------------------------------------------------------
# walk hop
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31), st.integers(1, 2000), st.integers(1, 600))
@settings(max_examples=20, deadline=None)
def test_walk_hop_sweep(seed, nk, nq):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.integers(0, max(nk // 4, 2), nk).astype(np.int64))
    qs = rng.integers(-1, max(nk // 4, 2) + 1, nq).astype(np.int64)
    u = rng.random(nq).astype(np.float32)
    pos, deg = ops.walk_hop(keys, qs, u)
    pos_r, deg_r = ref.walk_hop_ref(keys, qs, u)
    assert np.array_equal(deg, deg_r)
    alive = deg_r > 0
    assert np.array_equal(pos[alive], pos_r[alive])


# ---------------------------------------------------------------------------
# segdegree
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31), st.integers(1, 4000), st.integers(1, 200))
@settings(max_examples=20, deadline=None)
def test_segdegree_sweep(seed, n, dom):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.integers(0, dom, n).astype(np.int64))
    d, m = ops.segdegree(keys)
    d_r, m_r = ref.segdegree_ref(keys)
    assert (d, m) == (d_r, m_r)


def test_segdegree_run_spanning_many_blocks():
    keys = np.full(1000, 42, dtype=np.int64)
    assert ops.segdegree(keys) == (1, 1000)


# ---------------------------------------------------------------------------
# weighted pick
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31))
@settings(max_examples=15, deadline=None)
def test_ranged_weighted_pick(seed):
    rng = np.random.default_rng(seed)
    n = 500
    w = rng.random(n)
    w[rng.random(n) < 0.3] = 0.0
    cs = np.concatenate([[0.0], np.cumsum(w)])
    lo = rng.integers(0, n - 50, 200)
    hi = lo + rng.integers(1, 50, 200)
    u = rng.random(200)
    pos = ops.ranged_weighted_pick(cs, lo, hi, u)
    assert ((pos >= lo) & (pos < hi)).all()
    nonempty = (cs[hi] - cs[lo]) > 0
    assert (w[pos[nonempty]] > 0).all()


def test_ranged_weighted_pick_distribution():
    w = np.array([1.0, 0.0, 3.0, 0.0, 6.0], dtype=np.float64)
    cs = np.concatenate([[0.0], np.cumsum(w)])
    rng = np.random.default_rng(0)
    N = 30_000
    lo = np.zeros(N, np.int64)
    hi = np.full(N, 5, np.int64)
    pos = ops.ranged_weighted_pick(cs, lo, hi, rng.random(N))
    freq = np.bincount(pos, minlength=5) / N
    np.testing.assert_allclose(freq, w / w.sum(), atol=0.02)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,KVH,D,S,cap,win", [
    (2, 8, 4, 128, 384, 0.0, 0),
    (1, 16, 8, 128, 256, 50.0, 0),
    (2, 4, 1, 128, 512, 0.0, 128),
    (1, 8, 8, 64, 256, 30.0, 64),
    (3, 4, 2, 64, 130, 0.0, 0),     # unaligned S -> padding path
])
def test_decode_attention_allclose(B, H, KVH, D, S, cap, win):
    rng = np.random.default_rng(B * 1000 + S)
    q = rng.standard_normal((B, H, D)).astype(np.float32)
    k = rng.standard_normal((B, S, KVH, D)).astype(np.float32)
    v = rng.standard_normal((B, S, KVH, D)).astype(np.float32)
    lens = rng.integers(max(S // 2, 1), S + 1, B)
    out = ops.decode_attention(q, k, v, lens, softcap=cap, window=win)
    want = ref.decode_attention_ref(q, k, v, lens, softcap=cap, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_bf16():
    rng = np.random.default_rng(5)
    B, H, KVH, D, S = 2, 8, 4, 128, 256
    q = rng.standard_normal((B, H, D)).astype(jnp.bfloat16)
    k = rng.standard_normal((B, S, KVH, D)).astype(jnp.bfloat16)
    v = rng.standard_normal((B, S, KVH, D)).astype(jnp.bfloat16)
    lens = np.full(B, S)
    out = np.asarray(ops.decode_attention(q, k, v, lens), dtype=np.float32)
    want = np.asarray(ref.decode_attention_ref(
        np.asarray(q, np.float32), np.asarray(k, np.float32),
        np.asarray(v, np.float32), lens))
    np.testing.assert_allclose(out, want, rtol=5e-2, atol=5e-2)
