"""Adaptive round planner: EMA budgets, parity, uniformity, cost model.

The planner spends the fused loop's candidate budget where expected yield is
highest — per-piece acceptance EMAs carried as device state, integer budgets
water-filled from owed work minus bank coverage.  Everything it decides is a
pure function of carried *counts*, never sample values, so the uniformity
argument of the shortfall carry is untouched.  Pinned here:

* fixed-point planner arithmetic is bit-identical under numpy and jnp (the
  host twin is the parity oracle for the device carry);
* ``plan="adaptive"`` device loop == host twin, samples *and* stats, across
  calls whose EMAs/banks carry over — unsharded and world=1 sharded;
* chi-square uniformity of adaptive streams on UQ1 (acyclic) and UQ4
  (cyclic), jax engine and 1-device mesh;
* ``SamplerStats.psi()`` / ``samples_emitted`` accounting and the
  ``repro_round_waste_ratio`` gauge;
* the ONLINE-UNION host twin (``OnlineUnionSampler(plan="adaptive")``)
  batches fresh draws by the same EMAs and reseeds them at φ-refresh;
* :class:`PlanCache` cost-model fit/suggest determinism and the
  ``round_batch=None`` autotune entry point.
"""

import numpy as np
import pytest
from scipy import stats as sps

import jax.numpy as jnp

from repro.core import planner
from repro.core.framework import estimate_union, warmup
from repro.core.online import OnlineUnionSampler
from repro.core.overlap import exact_union_size
from repro.core.union_sampler import SamplerStats, SetUnionSampler
from repro.data.workloads import uq1, uq4


def _cover(wl):
    return estimate_union(warmup(wl.cat, wl.joins, method="exact").oracle).cover


def _assert_same_samples(a, b):
    assert a.attrs == b.attrs
    for attr in a.attrs:
        np.testing.assert_array_equal(a.rows[attr], b.rows[attr])
    np.testing.assert_array_equal(a.home, b.home)
    np.testing.assert_array_equal(a.fingerprint, b.fingerprint)


def _chi2_p(matrix, n_universe):
    uni, counts = np.unique(
        matrix.view([("", matrix.dtype)] * matrix.shape[1]).ravel(),
        return_counts=True)
    exp = matrix.shape[0] / n_universe
    chi2 = (float(((counts - exp) ** 2 / exp).sum())
            + (n_universe - uni.shape[0]) * exp)
    return 1 - sps.chi2.cdf(chi2, df=n_universe - 1)


# ---------------------------------------------------------------------------
# fixed-point arithmetic: numpy and jnp agree bit for bit
# ---------------------------------------------------------------------------


def test_budget_and_ema_bitwise_numpy_vs_jnp():
    rng = np.random.default_rng(0)
    for _ in range(50):
        nj = int(rng.integers(1, 6))
        need = rng.integers(0, 1 << 14, nj).astype(np.int32)
        bank = rng.integers(0, 4096, nj).astype(np.int32)
        ema = rng.integers(1, planner.EMA_ONE + 1, nj).astype(np.int32)
        bmax = rng.integers(64, 8192, nj).astype(np.int32)
        dw = np.int32(rng.integers(1, 257))
        b_np = planner.budget_for(need, bank, ema, bmax, dw, np)
        b_j = planner.budget_for(jnp.asarray(need), jnp.asarray(bank),
                                 jnp.asarray(ema), jnp.asarray(bmax),
                                 dw, jnp)
        np.testing.assert_array_equal(np.asarray(b_np, np.int32),
                                      np.asarray(b_j))
        # masked-out pieces draw 0; owed pieces draw at least the floor
        assert (np.asarray(b_np)[np.maximum(need - np.minimum(bank, dw), 0)
                                 == 0] == 0).all()

        drawn = rng.integers(0, 1 << 20, nj).astype(np.int32)
        counts = np.stack([rng.integers(0, d + 1, 4) for d in drawn]
                          ).astype(np.int32)
        shifts = planner.ema_shifts(drawn.tolist())
        e0 = rng.integers(0, planner.EMA_ONE + 1, (nj, 4)).astype(np.int32)
        u_np = planner.ema_update(e0, drawn, counts, shifts, np)
        u_j = planner.ema_update(jnp.asarray(e0), jnp.asarray(drawn),
                                 jnp.asarray(counts), jnp.asarray(shifts),
                                 jnp)
        np.testing.assert_array_equal(np.asarray(u_np, np.int32),
                                      np.asarray(u_j))
        # rates are fractions: EMA state stays inside [0, EMA_ONE + slack]
        assert (np.asarray(u_np) >= 0).all()


def test_ema_converges_toward_observed_rate():
    ema = np.asarray([[planner.EMA_ONE, planner.EMA_ONE, 0, 0]], np.int32)
    drawn = np.asarray([256], np.int32)
    # piece accepts 64/256 = 0.25 of its budget every round
    counts = np.asarray([[64, 256, 0, 0]], np.int32)
    sh = planner.ema_shifts([256])
    for _ in range(64):
        ema = planner.ema_update(ema, drawn, counts, sh, np)
    assert abs(int(ema[0, 0]) - planner.EMA_ONE // 4) <= 8


def test_ema_shifts_prevent_overflow():
    shifts = planner.ema_shifts([8, 4096, 1 << 20])
    for b, s in zip([8, 4096, 1 << 20], shifts):
        assert (b >> s) * planner.EMA_ONE < 2 ** 31


# ---------------------------------------------------------------------------
# adaptive device loop == host twin (EMAs ride the carry across calls)
# ---------------------------------------------------------------------------


def test_adaptive_device_matches_host_twin_bitwise():
    wl = uq1(scale=0.02, overlap=0.4, seed=0, n_joins=2)
    cover = _cover(wl)

    def engine(mode):
        return SetUnionSampler(wl.cat, wl.joins, cover, seed=11,
                               backend="jax", round_batch=128,
                               fused_rounds=mode, plan="adaptive")

    dev, host = engine("device"), engine("host")
    for n in (700, 333, 1025):
        _assert_same_samples(dev.sample(n), host.sample(n))
        assert dev.stats.as_dict() == host.stats.as_dict()


def test_adaptive_sharded_world1_matches_unsharded():
    from repro.core.sharding import make_sampler_mesh
    wl = uq1(scale=0.02, overlap=0.4, seed=0, n_joins=2)
    cover = _cover(wl)

    def engine(mesh, mode="device"):
        return SetUnionSampler(wl.cat, wl.joins, cover, seed=9,
                               backend="jax", round_batch=512, mesh=mesh,
                               fused_rounds=mode, plan="adaptive")

    sharded = engine(make_sampler_mesh(world=1))
    between = engine(make_sampler_mesh(world=1), mode="host")
    plain = engine(None)
    for n in (900, 411):
        a, b, c = sharded.sample(n), between.sample(n), plain.sample(n)
        _assert_same_samples(a, b)
        _assert_same_samples(a, c)
        assert sharded.stats.as_dict() == plain.stats.as_dict()


def test_adaptive_cuts_waste_vs_static():
    """The tentpole's psi story: EMA budgets + wider selection slots spend
    fewer counted candidate draws per emitted sample than the fixed batch."""
    wl = uq1(scale=0.02, overlap=0.4, seed=0, n_joins=2)
    cover = _cover(wl)
    psis = {}
    for plan in ("static", "adaptive"):
        s = SetUnionSampler(wl.cat, wl.joins, cover, seed=5, backend="jax",
                            round_batch=256, fused_rounds="device", plan=plan)
        s.sample(2000)
        psis[plan] = s.stats.psi()
    assert psis["adaptive"] < psis["static"]


def test_record_engine_rejects_adaptive():
    wl = uq1(scale=0.02, overlap=0.4, seed=0, n_joins=2)
    cover = _cover(wl)
    with pytest.raises(ValueError, match="record"):
        SetUnionSampler(wl.cat, wl.joins, cover, seed=3, backend="jax",
                        membership="record", plan="adaptive")
    with pytest.raises(ValueError, match="plan"):
        SetUnionSampler(wl.cat, wl.joins, cover, seed=3, backend="jax",
                        plan="bogus")


# ---------------------------------------------------------------------------
# uniformity: budgets depend on counts only, so the stream stays 1/|U|
# ---------------------------------------------------------------------------


def _uniform_p(wl, mesh=None, n_per_cell=120, rb=1024):
    cover = _cover(wl)
    U = exact_union_size(wl.cat, wl.joins)
    s = SetUnionSampler(wl.cat, wl.joins, cover, seed=13, backend="jax",
                        round_batch=rb, mesh=mesh, fused_rounds="device",
                        plan="adaptive")
    ss = s.sample(n_per_cell * U)
    return _chi2_p(ss.matrix(), U)


def test_adaptive_uniform_uq1():
    p = _uniform_p(uq1(scale=0.02, overlap=0.5, seed=1, n_joins=2))
    assert p > 1e-3, p


def test_adaptive_uniform_uq4_cyclic():
    p = _uniform_p(uq4(scale=0.01, seed=0))
    assert p > 1e-3, p


def test_adaptive_uniform_uq1_sharded():
    from repro.core.sharding import make_sampler_mesh
    p = _uniform_p(uq1(scale=0.02, overlap=0.5, seed=1, n_joins=2),
                   mesh=make_sampler_mesh(world=1))
    assert p > 1e-3, p


def test_adaptive_uniform_uq4_sharded():
    from repro.core.sharding import make_sampler_mesh
    p = _uniform_p(uq4(scale=0.01, seed=0),
                   mesh=make_sampler_mesh(world=1))
    assert p > 1e-3, p


# ---------------------------------------------------------------------------
# psi accounting + waste gauge
# ---------------------------------------------------------------------------


def test_psi_helper_and_merge():
    st = SamplerStats(candidate_draws=300, samples_emitted=100)
    assert st.psi() == 3.0
    assert SamplerStats().psi() == 0.0
    merged = st.merge(SamplerStats(candidate_draws=100, samples_emitted=100))
    assert merged.psi() == 2.0


def test_waste_gauge_published():
    from repro import obs
    wl = uq1(scale=0.02, overlap=0.4, seed=0, n_joins=2)
    cover = _cover(wl)
    if not obs.enabled():
        pytest.skip("obs disabled via REPRO_OBS=off")
    s = SetUnionSampler(wl.cat, wl.joins, cover, seed=3, backend="jax",
                        round_batch=256, fused_rounds="device",
                        plan="adaptive")
    s.sample(1000)
    text = obs.get_registry().render()
    assert "repro_round_waste_ratio" in text
    assert "repro_engine_piece_ema" in text


# ---------------------------------------------------------------------------
# ONLINE-UNION host twin: EMA-batched fresh draws + φ-refresh reseed
# ---------------------------------------------------------------------------


def test_online_adaptive_emits_and_reseeds():
    wl = uq1(scale=0.05, overlap=0.4, seed=0, n_joins=2)
    s = OnlineUnionSampler(wl.cat, wl.joins, seed=3, phi=300, pool_cap=8,
                           plan="adaptive")
    out = s.sample(800)
    assert out.home.shape[0] == 800
    assert s.stats.samples_emitted == 800
    # PiecePlanner seeded once at init and reseeded at every φ-refresh
    assert s.planner is not None
    assert s.planner.refreshes == 1 + s.refresh_count
    with pytest.raises(ValueError):
        OnlineUnionSampler(wl.cat, wl.joins, seed=3, plan="bogus")


def test_piece_planner_batches_track_acceptance():
    wl = uq1(scale=0.02, overlap=0.4, seed=0, n_joins=2)
    cover = _cover(wl)
    pl = planner.PiecePlanner(cover, {})
    k0 = pl.suggest_batch(1)
    # persistent rejection drives the EMA down and the batch size up
    for _ in range(32):
        pl.observe(1, drawn=k0, accepted=0)
    assert pl.suggest_batch(1) > k0
    # perfect acceptance drives it back toward 1-2 candidates
    for _ in range(64):
        pl.observe(1, drawn=8, accepted=8)
    assert pl.suggest_batch(1) <= 2


# ---------------------------------------------------------------------------
# host-side cost model: deterministic fit + autotune entry point
# ---------------------------------------------------------------------------


def test_plan_cache_fit_and_suggest():
    pc = planner.PlanCache()
    key = "k1"
    # t_round = 1ms + 1us/slot, 2 slots/rb, ~0.9 emitted per rb slot pair
    for rb in (256, 1024, 4096):
        slots = 2 * rb
        t_round = 1e-3 + 1e-6 * slots
        rounds = 50
        pc.observe(key, rb, slots, rounds, seconds=t_round * rounds,
                   samples=int(0.9 * rb * rounds))
    c0, c1 = pc.fit(key)
    assert c0 == pytest.approx(1e-3, rel=0.05)
    assert c1 == pytest.approx(1e-6, rel=0.05)
    plan = pc.suggest(key)
    # per-round overhead amortises with bigger batches: the model picks the
    # largest candidate once c0 dominates, deterministically
    assert plan == pc.suggest(key)
    assert plan.round_batch == 8192
    assert plan.surplus_cap == 8 * plan.round_batch
    assert plan.drain_window == min(plan.round_batch, 256)


def test_plan_cache_min_displaces_compile_polluted_first_call():
    pc = planner.PlanCache()
    pc.observe("k", 256, 512, 10, seconds=5.0, samples=1000)   # compile hit
    pc.observe("k", 256, 512, 10, seconds=0.5, samples=1000)   # warm
    pc.observe("k", 256, 512, 10, seconds=0.9, samples=1000)   # noise
    (o,) = pc._obs["k"].values()
    assert o.seconds == 0.5


def test_round_batch_none_autotunes_from_cache():
    wl = uq1(scale=0.02, overlap=0.4, seed=0, n_joins=2)
    cover = _cover(wl)
    planner.PLAN_CACHE.reset()
    # cold cache: falls back to the 4096 default
    s = SetUnionSampler(wl.cat, wl.joins, cover, seed=3, backend="jax",
                        round_batch=None)
    assert s.autotuned_plan is None
    assert s._engine.round_batch == 4096
    # a timed sample() feeds the cache under this catalog's fingerprint...
    s.sample(2000)
    key = planner.plan_key(wl.cat, s.joins, cover)
    assert planner.PLAN_CACHE.fit(key) is not None
    # ...so the next round_batch=None build consults the model
    s2 = SetUnionSampler(wl.cat, wl.joins, cover, seed=3, backend="jax",
                         round_batch=None)
    assert s2.autotuned_plan is not None
    assert s2._engine.round_batch == s2.autotuned_plan.round_batch
    planner.PLAN_CACHE.reset()
