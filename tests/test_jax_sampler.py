"""Device (jitted) chain sampler == host sampler: distribution equivalence."""

import numpy as np
import pytest
from scipy import stats as sps

from conftest import tiny_db

from repro.core.index import Catalog
from repro.core.jax_sampler import JaxChainSampler
from repro.core.joins import chain_join, full_join_matrix
from repro.core.join_sampler import JoinSampler


def _chain(seed=0):
    R, S, T = tiny_db(seed)
    return Catalog(), chain_join(f"RSTj{seed}", [R, S, T], ["b", "c"])


def test_total_weight_matches_host():
    cat, spec = _chain(0)
    js = JaxChainSampler(cat, spec, seed=0)
    host = JoinSampler(cat, spec, method="ew")
    assert js.total_weight == pytest.approx(host.exact_acyclic_size())


def test_jax_sampler_uniform_chi2():
    cat, spec = _chain(1)
    mat = full_join_matrix(cat, spec)
    n_tuples = mat.shape[0]
    js = JaxChainSampler(cat, spec, seed=1)
    N = 60 * n_tuples
    rows = js.sample_uniform(N, batch=4096)
    got = np.stack([rows[a] for a in spec.output_attrs], axis=1)
    uni, counts = np.unique(got.view([("", got.dtype)] * got.shape[1]).ravel(),
                            return_counts=True)
    assert uni.shape[0] == n_tuples
    exp = N / n_tuples
    chi2 = float(((counts - exp) ** 2 / exp).sum())
    p = 1 - sps.chi2.cdf(chi2, df=n_tuples - 1)
    assert p > 1e-3, f"jitted sampler not uniform (p={p})"


def test_jax_sampler_matches_host_marginals():
    cat, spec = _chain(2)
    js = JaxChainSampler(cat, spec, seed=2)
    host = JoinSampler(cat, spec, method="ew")
    rng = np.random.default_rng(0)
    N = 4000
    r_j = js.sample_uniform(N, batch=2048)
    r_h, _ = host.sample_uniform(rng, N, batch=2048)
    # same marginal distribution per attribute (two-sample chi-square)
    for a in spec.output_attrs:
        vj, cj = np.unique(r_j[a], return_counts=True)
        vh, ch = np.unique(r_h[a], return_counts=True)
        dom = np.union1d(vj, vh)
        fj = np.zeros(dom.shape[0])
        fh = np.zeros(dom.shape[0])
        fj[np.searchsorted(dom, vj)] = cj
        fh[np.searchsorted(dom, vh)] = ch
        tot = fj + fh
        keep = tot >= 8
        if keep.sum() < 2:
            continue
        chi2 = ((fj[keep] - fh[keep]) ** 2 / tot[keep]).sum()
        p = 1 - sps.chi2.cdf(chi2, df=int(keep.sum()) - 1)
        assert p > 1e-4, f"attr {a}: device/host marginals differ (p={p})"


def test_jax_sampler_rejects_non_chain():
    import numpy as np
    from repro.core.joins import JoinNode, JoinSpec
    from repro.core.relation import Relation
    rng = np.random.default_rng(0)
    R = Relation("R", {"a": rng.integers(0, 4, 10), "b": rng.integers(0, 4, 10)})
    S = Relation("S", {"b": rng.integers(0, 4, 10), "c": rng.integers(0, 4, 10)})
    T = Relation("T", {"b": rng.integers(0, 4, 10), "d": rng.integers(0, 4, 10)})
    tree = JoinSpec("tree", [JoinNode("R", R, None, ()),
                             JoinNode("S", S, "R", ("b",)),
                             JoinNode("T", T, "R", ("b",))])
    with pytest.raises(ValueError):
        JaxChainSampler(Catalog(), tree)
