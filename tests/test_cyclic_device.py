"""Cyclic joins on the device engine (§8.2 skeleton + residual rejection).

Acceptance bar of the cyclic tentpole: the device engine must run cyclic
joins end-to-end with host-identical uniformity — chi-square against the
exact universe on the UQ4 workload for both engines, the residual d/M
accept/reject decision bit-equal to the host reference on a shared
(injected-uniform) trace, residual-rejection accounting present in
``SamplerStats`` on both engines, and a 1-device mesh reproducing the
unsharded fused engine bit for bit on the cyclic union.
"""

import numpy as np
import pytest
from scipy import stats as sps

from conftest import brute_force_join, tiny_db

from repro.core.backends import NumpyBackend
from repro.core.backends.jax_backend import DeviceTreeJoin, JaxBackend
from repro.core.framework import estimate_union, warmup
from repro.core.index import Catalog
from repro.core.join_sampler import JoinSampler
from repro.core.joins import JoinNode, JoinSpec, chain_join, full_join
from repro.core.overlap import exact_union_size
from repro.core.relation import Relation, combine_columns
from repro.core.union_sampler import SetUnionSampler
from repro.data.workloads import uq4


def _cyclic_spec(seed=0, n_q=40):
    """R(a,b) ⋈_b S(b,c) skeleton + residual Q(a,c,qid) closing the cycle.

    Q holds duplicate (a, c) pairs with multiplicities in {1, 2, 4}, so the
    residual degree d varies, M = 4, and the d/M thresholds (0.25, 0.5, 1.0)
    are exactly representable in both float32 and float64 — the shared-trace
    test can demand bit-equal accept decisions across engines.
    """
    R, S, T = tiny_db(seed)
    rng = np.random.default_rng(seed + 1)
    a = rng.integers(0, 12, n_q)
    c = rng.integers(0, 12, n_q)
    mult = rng.choice([1, 2, 4], size=n_q, p=[0.5, 0.3, 0.2])
    # enforce M == 4 regardless of the random draw
    mult[0] = 4
    Q = Relation("Q", {"a": np.repeat(a, mult), "c": np.repeat(c, mult),
                       "qid": np.arange(int(mult.sum()))})
    spec = JoinSpec("CYC", [
        JoinNode("R", R, None, ()),
        JoinNode("S", S, "R", ("b",)),
        JoinNode("Q", Q, None, ("a", "c"), kind="residual"),
    ])
    return Catalog(), spec


def _chi2_vs_expected(sample_matrix, expected_matrix):
    def keyed(m):
        return m.view([("", m.dtype)] * m.shape[1]).ravel()
    uni, exp_counts = np.unique(keyed(expected_matrix), return_counts=True)
    s_uni, s_counts = np.unique(keyed(sample_matrix), return_counts=True)
    assert np.isin(s_uni, uni).all(), "sampled a tuple outside the join"
    counts = np.zeros(uni.shape[0])
    counts[np.searchsorted(uni, s_uni)] = s_counts
    N = sample_matrix.shape[0]
    exp = N * exp_counts / exp_counts.sum()
    chi2 = float(((counts - exp) ** 2 / exp).sum())
    return 1 - sps.chi2.cdf(chi2, df=uni.shape[0] - 1)


def _chi2_uniform(sample_matrix, n_universe):
    uni, counts = np.unique(
        sample_matrix.view([("", sample_matrix.dtype)] *
                           sample_matrix.shape[1]).ravel(),
        return_counts=True)
    N = sample_matrix.shape[0]
    exp = N / n_universe
    chi2 = (float(((counts - exp) ** 2 / exp).sum())
            + (n_universe - uni.shape[0]) * exp)
    return 1 - sps.chi2.cdf(chi2, df=n_universe - 1)


# ---------------------------------------------------------------------------
# single cyclic join: device draws follow the exact multiplicity law
# ---------------------------------------------------------------------------


def test_device_cyclic_source_distribution():
    cat, spec = _cyclic_spec(0)
    truth = brute_force_join(spec)
    assert truth, "degenerate test spec"
    attrs = spec.output_attrs
    mat = np.asarray([[r[a] for a in attrs] for r in truth], dtype=np.int64)
    be = JaxBackend(cat, [spec], seed=2, device_batch=2048)
    src = be.source(spec.name)
    assert src.tree.has_residual
    rows, draws = src.draw(np.random.default_rng(0), 30_000)
    assert draws > 30_000            # residual rejection costs extra draws
    got = np.stack([rows[a] for a in attrs], axis=1)
    p = _chi2_vs_expected(got, mat)
    assert p > 1e-3, f"device cyclic sampler distribution off (p={p})"
    assert src.pop_residual_rejects() > 0
    assert src.pop_residual_rejects() == 0        # drained


def test_device_cyclic_source_matches_host_distribution():
    """Same chi-square bar for the host source on the same spec (host
    reference sanity for the device comparison)."""
    cat, spec = _cyclic_spec(0)
    truth = brute_force_join(spec)
    attrs = spec.output_attrs
    mat = np.asarray([[r[a] for a in attrs] for r in truth], dtype=np.int64)
    be = NumpyBackend(cat, [spec])
    rows, _ = be.source(spec.name).draw(np.random.default_rng(1), 30_000)
    got = np.stack([rows[a] for a in attrs], axis=1)
    p = _chi2_vs_expected(got, mat)
    assert p > 1e-3, f"host cyclic sampler distribution off (p={p})"


# ---------------------------------------------------------------------------
# shared trace: device residual accept/reject == host, bit for bit
# ---------------------------------------------------------------------------


def test_residual_rejection_matches_host_on_shared_trace():
    import jax.numpy as jnp
    cat, spec = _cyclic_spec(3)
    host = JoinSampler(cat, spec, method="ew")
    tree = DeviceTreeJoin(cat, spec)
    (ridx, rcfg), = [(i, c) for i, c in enumerate(tree.node_cfgs)
                     if c.kind == "residual"]
    assert rcfg.max_degree == host.edges["Q"].max_degree == 4

    # one shared trace: skeleton tuples drawn once on the host + one shared
    # uniform vector per decision (float32 so both engines compare the same
    # values against the same exactly-representable d/M thresholds)
    skel = JoinSpec("SKEL", [n for n in spec.nodes if n.kind == "tree"])
    rng = np.random.default_rng(7)
    sb = JoinSampler(cat, skel, method="ew").sample_batch(rng, 4096)
    walk_ok = sb.ok
    u_pick = rng.random(4096, dtype=np.float32)
    u_acc = rng.random(4096, dtype=np.float32)

    # host reference: residual range probe + d/M acceptance
    plan = host.edges["Q"]
    key = combine_columns([sb.rows[a] for a in ("a", "c")])
    lo, hi = plan.index.ranges(key)
    d = hi - lo
    ok_h = walk_ok & (d > 0)
    accept_h = ok_h & (u_acc.astype(np.float64)
                       < d / np.float64(plan.max_degree))

    # device: the same rows + the same uniforms through the traced step
    rows_dev = {a: jnp.asarray(c.astype(np.int32))
                for a, c in sb.rows.items()}
    _, ok_d, ratio = tree._residual_step(
        ridx, rcfg, rows_dev, jnp.asarray(walk_ok),
        jnp.ones(4096, jnp.float32), jnp.asarray(u_pick))
    accept_d = np.asarray(ok_d & (jnp.asarray(u_acc) < ratio))

    assert np.array_equal(np.asarray(ok_d), ok_h)
    assert np.array_equal(accept_d, accept_h)
    # the residual-rejection count — walks alive at every edge but killed by
    # the d/M test — is therefore identical too, and non-trivial
    rej_h = int((ok_h & ~accept_h).sum())
    rej_d = int((np.asarray(ok_d) & ~accept_d).sum())
    assert rej_h == rej_d
    assert 0 < rej_h < int(ok_h.sum())


def test_residual_reject_stats_populated_on_both_engines():
    """SamplerStats.residual_rejects counts the d/M kills on both engines."""
    cat, spec = _cyclic_spec(5)
    wide_cols = full_join(cat, spec)
    wide = Relation("WIDE", {a: c[: max(1, c.shape[0] // 2)]
                             for a, c in wide_cols.items()})
    j2 = chain_join("J2", [wide], [])
    joins = [spec, j2]
    est = estimate_union(warmup(cat, joins, method="exact").oracle)
    for backend in ("numpy", "jax"):
        s = SetUnionSampler(cat, joins, est.cover, seed=11, backend=backend,
                            round_batch=1024)
        ss = s.sample(1500)
        assert len(ss) == 1500
        assert ss.stats.residual_rejects > 0, backend
        assert ss.stats.as_dict()["residual_rejects"] == \
            ss.stats.residual_rejects


# ---------------------------------------------------------------------------
# UQ4 end-to-end: device == host uniformity; 1-device mesh bit-for-bit
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def uq4_setup():
    wl = uq4(scale=0.02, seed=0)
    est = estimate_union(warmup(wl.cat, wl.joins, method="exact").oracle)
    U = exact_union_size(wl.cat, wl.joins)
    return wl, est, U


def test_uq4_device_vs_host_uniformity(uq4_setup):
    wl, est, U = uq4_setup
    N = 120 * U
    for backend in ("numpy", "jax"):
        s = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=7,
                            backend=backend, round_batch=2048)
        ss = s.sample(N)
        assert len(ss) == N
        p = _chi2_uniform(ss.matrix(), U)
        assert p > 1e-3, f"{backend} not uniform on UQ4 (p={p})"


def test_uq4_one_shard_mesh_bitwise_equals_jax_engine(uq4_setup):
    from repro.core.sharding import make_sampler_mesh
    wl, est, U = uq4_setup
    plain = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=9,
                            backend="jax", round_batch=1024)
    sharded = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=9,
                              backend="jax", round_batch=1024,
                              mesh=make_sampler_mesh(world=1))
    a, b = plain.sample(3000), sharded.sample(3000)
    for attr in a.attrs:
        assert np.array_equal(a.rows[attr], b.rows[attr]), attr
    assert np.array_equal(a.home, b.home)
    assert np.array_equal(a.fingerprint, b.fingerprint)
    assert a.stats.as_dict() == b.stats.as_dict()


def test_uq4_online_refines_on_device(uq4_setup):
    from repro.core.online import OnlineUnionSampler
    wl, est, U = uq4_setup
    ou = OnlineUnionSampler(wl.cat, wl.joins, seed=5, phi=256, rw_batch=64,
                            backend="jax")
    ss = ou.sample(150)
    assert len(ss) == 150
    # φ-refinement observed the cyclic member (wander-join walks hop the
    # residual edge) — its size accumulator has walks
    assert ou.estimator.size_stats["UQ4_CYC"].count > 0
