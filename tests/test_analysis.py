"""Static invariant analyzer: rule fixtures, baseline policy, self-run,
and the jaxpr/recompile audits against the real engines.

Layer 1/3 (AST lint) is exercised on small seeded fixtures — one
tripping and one clean snippet per rule family — so a rule that stops
firing (or starts over-firing) fails here before it silently weakens the
CI gate.  The self-run test then asserts the shipped tree is clean
modulo the justified baseline, which is what the ``analysis-gate`` CI
job enforces.  Layer 2 builds the same UQ1/UQ4 engines tier-1 uses and
pins the structural invariants: device/host RNG-primitive parity, zero
collectives unsharded, host-sequence-plus-one-banking-``all_gather``
under a world=1 mesh, donated carries, and one loop trace per capacity
class.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.findings import Baseline, Finding
from repro.analysis.lint import run_lint

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GATE = os.path.join(REPO, "scripts", "analysis_gate.py")


def _lint_snippet(tmp_path, source, name="snippet.py", prefix=None):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    prefixes = [prefix] if prefix is not None else None
    return run_lint([str(p)], rel_prefixes=prefixes)


def _rules(findings):
    return sorted({f.rule for f in findings})


# -- layer 1: rule fixtures ---------------------------------------------------

def test_tracer_branch_fires_and_static_config_is_clean(tmp_path):
    bad = _lint_snippet(tmp_path, """
        import jax

        @jax.jit
        def f(x, y):
            if x > 0:
                return y
            return -y
    """)
    assert _rules(bad) == ["tracer-branch"]

    clean = _lint_snippet(tmp_path, """
        import jax
        from typing import Optional

        @jax.jit
        def f(x, causal: bool, window: Optional[int]):
            if causal:                  # static config flag
                x = x + 1
            if window is not None:      # is-None check is static
                x = x * 2
            if x.shape[0] == 4:         # shape info is static
                x = x - 1
            return x
    """)
    assert clean == []


def test_host_escape_fires_only_in_traced_functions(tmp_path):
    bad = _lint_snippet(tmp_path, """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            a = float(x)
            b = x.item()
            c = np.maximum(x, 0)
            return a + b + c
    """)
    assert _rules(bad) == ["host-escape"] and len(bad) == 3

    clean = _lint_snippet(tmp_path, """
        import numpy as np

        def host_only(x):
            return float(x) + np.maximum(x, 0).item()
    """)
    assert clean == []


def test_fixed_point_rule_fires_on_marked_functions_only(tmp_path):
    bad = _lint_snippet(tmp_path, """
        def budget(a, b):  # analysis: fixed-point
            return a * 0.5 + b / 2
    """)
    assert _rules(bad) == ["f64-in-planner"]

    clean = _lint_snippet(tmp_path, """
        def budget(a, b):  # analysis: fixed-point
            return (a >> 1) + b // 2

        def unmarked(a):
            return a * 0.5
    """)
    assert clean == []


def test_nondeterminism_rule(tmp_path):
    bad = _lint_snippet(tmp_path, """
        import jax, time

        @jax.jit
        def f(x):
            return x + time.time()
    """)
    assert _rules(bad) == ["nondeterminism"]


def test_int32_packing_rule_scoped_to_core(tmp_path):
    src = """
        import numpy as np

        def pack(cols, widths):
            key = np.zeros(4, np.int32)
            for c, w in zip(cols, widths):
                key = key * w + c
            return key
    """
    assert _rules(_lint_snippet(tmp_path, src, prefix="core")) \
        == ["int32-overflow"]
    # same code outside core/ (host CLI arithmetic) is not flagged
    assert _lint_snippet(tmp_path, src, prefix="launch") == []
    # a module-level domain guard clears it
    guarded = src + "        _I32_LIM = 2 ** 31\n"
    assert _lint_snippet(tmp_path, guarded, prefix="core") == []


def test_missing_fallback_rule(tmp_path):
    bad = _lint_snippet(tmp_path, """
        import warnings

        def pick(kind):
            if kind != "jax":
                warnings.warn("no device twin; falling back to host")
            return kind
    """)
    assert _rules(bad) == ["missing-fallback"]

    clean = _lint_snippet(tmp_path, """
        import warnings
        from repro import obs

        def pick(kind):
            if kind != "jax":
                warnings.warn("no device twin; falling back to host")
                obs.record_fallback("backend", detail=kind)
            return kind
    """)
    assert clean == []


def test_lock_discipline_rule(tmp_path):
    bad = _lint_snippet(tmp_path, """
        import threading

        class Service:
            def __init__(self):
                self._lock = threading.Lock()
                self._cursor = 0
                self._q = None

            def request(self):
                with self._lock:
                    self._cursor += 1
                    return self._q.get()

            def reset(self):
                self._cursor = 0
    """)
    assert _rules(bad) == ["lock-discipline"] and len(bad) == 2

    clean = _lint_snippet(tmp_path, """
        import threading

        class Service:
            def __init__(self):
                self._lock = threading.Lock()
                self._cursor = 0
                self._q = None

            def request(self):
                with self._lock:
                    self._cursor += 1
                return self._q.get(timeout=1.0)

            def reset(self):
                with self._lock:
                    self._cursor = 0
    """)
    assert clean == []


def test_estimator_pull_rule(tmp_path):
    bad = _lint_snippet(tmp_path, """
        class Online:
            def _score(self, name):
                st = self.estimator.size_stats[name]
                return st.mean * st.count

            def sample(self, n):
                return [self._score(j) for j in range(n)]
    """)
    assert _rules(bad) == ["estimator-pull"]

    clean = _lint_snippet(tmp_path, """
        class Online:
            def _refresh_size_cache(self):
                out = {}
                for name, st in self.estimator.size_stats.items():
                    out[name] = st.mean * st.count
                self._cache = out

            def sample(self, n):
                return [self._cache for _ in range(n)]
    """)
    assert clean == []


def test_inline_allow_suppresses(tmp_path):
    clean = _lint_snippet(tmp_path, """
        import jax

        @jax.jit
        def f(x, y):
            if x > 0:  # analysis: allow(tracer-branch)
                return y
            return -y
    """)
    assert clean == []


# -- fingerprints and baseline ------------------------------------------------

def test_fingerprint_ignores_line_numbers():
    a = Finding("r", "p.py", 10, "f", "msg", detail="tok")
    b = Finding("r", "p.py", 99, "f", "other msg", detail="tok")
    assert a.fingerprint == b.fingerprint


def test_baseline_split_and_stale(tmp_path):
    f1 = Finding("r", "p.py", 1, "f", "m", detail="one")
    f2 = Finding("r", "p.py", 2, "g", "m", detail="two")
    bl = tmp_path / "bl.json"
    bl.write_text(json.dumps({"findings": [
        {"fingerprint": f1.fingerprint, "reason": "known, accepted"},
        {"fingerprint": "deadbeefdeadbeef", "reason": "gone"},
    ]}))
    base = Baseline.load(str(bl))
    active, suppressed = base.split([f1, f2])
    assert active == [f2] and suppressed == [f1]
    assert base.stale([f1, f2]) == ["deadbeefdeadbeef"]


def test_baseline_requires_reason(tmp_path):
    bl = tmp_path / "bl.json"
    bl.write_text(json.dumps({"findings": [{"fingerprint": "abc"}]}))
    with pytest.raises(ValueError):
        Baseline.load(str(bl))


# -- the gate, end to end -----------------------------------------------------

def test_gate_exits_nonzero_on_seeded_fixture(tmp_path):
    p = tmp_path / "seeded.py"
    p.write_text(textwrap.dedent("""
        import jax, time

        @jax.jit
        def f(x):
            if x > 0:
                return float(x)
            return x + time.time()
    """))
    proc = subprocess.run(
        [sys.executable, GATE, "--layers", "ast", str(p), "--json"],
        capture_output=True, text=True)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    out = json.loads(proc.stdout)
    fired = {f["rule"] for f in out["findings"]}
    assert {"tracer-branch", "host-escape", "nondeterminism"} <= fired


def test_gate_self_run_is_clean_modulo_baseline(tmp_path):
    stats = tmp_path / "stats.json"
    proc = subprocess.run(
        [sys.executable, GATE, "--layers", "ast",
         "--baseline", os.path.join(REPO, "analysis_baseline.json"),
         "--stats", str(stats)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(stats.read_text())
    assert data["active"] == 0
    assert data["stale_baseline"] == 0


# -- layer 2: jaxpr + recompile audits on the real engines --------------------

jax = pytest.importorskip("jax")


@pytest.mark.parametrize("label,spec", [
    ("uq1-static", dict(workload="uq1", plan="static")),
    ("uq1-adaptive", dict(workload="uq1", plan="adaptive")),
    ("uq4-static", dict(workload="uq4", plan="static")),
])
def test_jaxpr_audit_unsharded(label, spec):
    from repro.analysis.jaxpr_audit import audit_unsharded, build_engine
    findings, report = audit_unsharded(build_engine(**spec), label)
    assert findings == [], [f.render() for f in findings]
    assert report["rng"], "device loop must draw RNG primitives"
    assert report["collectives"] == []


def test_jaxpr_audit_sharded_world1():
    from repro.analysis.jaxpr_audit import audit_sharded, build_engine
    eng = build_engine(workload="uq1", plan="static", world=1)
    findings, report = audit_sharded(eng, "uq1-sharded-w1")
    assert findings == [], [f.render() for f in findings]
    # the whole round body rides on a single banking exchange
    assert report["collectives"] == ["axis_index", "all_gather"]


def test_recompile_audit_one_trace_per_capacity_class():
    from repro.analysis.jaxpr_audit import build_engine
    from repro.analysis.recompile import audit_recompile_engine
    eng = build_engine(workload="uq1", plan="static")
    findings, report = audit_recompile_engine(eng, "uq1-static")
    assert findings == [], [f.render() for f in findings]
    assert report["traces"] == 2
    assert report["capacity_classes"] == [1024, 2048]
