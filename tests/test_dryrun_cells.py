"""Dry-run integration: one real cell lowered+compiled in a subprocess
(own process so the 16-device XLA flag never leaks into this test session),
plus HLO-census self-consistency checks."""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["REPRO_DRYRUN_DEVICES"] = "16"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_dryrun_single_cell_compiles_and_reports():
    out = _run(r"""
from repro.launch import dryrun
from repro.launch.mesh import make_mesh
import jax, json
mesh = make_mesh((4, 4), ("data", "model"))
res = dryrun.lower_cell("mamba2-780m", "decode_32k", mesh)
r = res["roofline"]
assert res["compile_s"] > 0
assert res["memory"]["per_device_total"] > 0
assert r["compute_s"] >= 0 and r["memory_s"] > 0
assert r["dominant"] in ("compute", "memory", "collective")
assert r["params_total"] > 5e8          # ~780M
print(json.dumps({"dom": r["dominant"],
                  "mem_gib": res["memory"]["per_device_total"] / 2**30}))
""")
    d = json.loads(out.strip().splitlines()[-1])
    assert d["mem_gib"] < 64


def test_census_matches_cost_analysis_when_unscanned():
    """With 1-layer models every while has trip 1 — census dot-flops must be
    within 2x of XLA's own (elementwise-inclusive) count."""
    out = _run(r"""
from repro.launch import dryrun
import jax, json
import dataclasses
from repro.configs import get_smoke_config
from repro.models.transformer import forward_train, param_specs
from repro.launch.hlo_census import census
import jax.numpy as jnp
cfg = dataclasses.replace(get_smoke_config("minitron-8b"), n_layers=1,
                          remat=False, q_chunk=64, kv_chunk=64,
                          loss_chunk=64)
batch = {"tokens": jax.ShapeDtypeStruct((2, 64), jnp.int32),
         "targets": jax.ShapeDtypeStruct((2, 64), jnp.int32)}
co = jax.jit(lambda p, b: forward_train(p, cfg, b)).lower(
    param_specs(cfg), batch).compile()
cs = census(co.as_text())
from repro.launch.mesh import cost_analysis_dict
raw = float(cost_analysis_dict(co).get("flops", 0.0))
assert cs.flops > 0 and raw > 0
ratio = cs.flops / raw
assert 0.4 < ratio < 2.0, (cs.flops, raw)
print(json.dumps({"ratio": ratio}))
""")
    assert "ratio" in out


def test_all_cells_accounted():
    from repro.configs import ASSIGNED_ARCHS, SHAPES, all_cells
    cells = all_cells()
    assert len(cells) == len(ASSIGNED_ARCHS) * len(SHAPES) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(skipped) == 7            # long_500k on pure full-attention
    assert all(c[1] == "long_500k" for c in skipped)
    assert {a for a, s, ok, w in cells if s == "long_500k" and ok} == {
        "mamba2-780m", "zamba2-7b", "gemma2-9b"}


def test_sweep_artifacts_if_present():
    """Validate the committed sweep artifacts (skips if the sweep wasn't run)."""
    art = os.path.join(os.path.dirname(__file__), "..", "artifacts")
    if not os.path.isdir(art):
        pytest.skip("no artifacts/ (run repro.launch.dryrun)")
    import glob
    files = glob.glob(os.path.join(art, "*", "*.json"))
    assert files
    n_err = 0
    for f in files:
        d = json.load(open(f))
        if "error" in d:
            n_err += 1
    assert n_err == 0, f"{n_err} failed cells in artifacts"
