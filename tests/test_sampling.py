"""Join sampler (EW/EO/WJ) + size/overlap estimator properties."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from scipy import stats as sps

from conftest import brute_force_join, tiny_db

from repro.core.index import Catalog
from repro.core.joins import JoinNode, JoinSpec, chain_join, full_join_matrix
from repro.core.join_sampler import JoinSampler
from repro.core.overlap import (HistogramOverlap, RandomWalkOverlap,
                                distinct_tuples, exact_overlap)
from repro.core.size_estimation import (WanderJoinSizeEstimator, olken_bound)
from repro.data.workloads import uq1, uq3


def _chain(seed=0):
    R, S, T = tiny_db(seed)
    return Catalog(), chain_join(f"RST{seed}", [R, S, T], ["b", "c"])


# ---------------------------------------------------------------------------
# Exact weights
# ---------------------------------------------------------------------------


def test_ew_total_equals_join_size():
    cat, spec = _chain(0)
    s = JoinSampler(cat, spec, method="ew")
    assert s.exact_acyclic_size() == full_join_matrix(cat, spec).shape[0]


def test_ew_sampling_uniform_chi2():
    cat, spec = _chain(1)
    s = JoinSampler(cat, spec, method="ew")
    mat = full_join_matrix(cat, spec)
    n_tuples = mat.shape[0]
    assert n_tuples > 30
    rng = np.random.default_rng(0)
    N = 60 * n_tuples
    rows, draws = s.sample_uniform(rng, N, batch=4096)
    # EW on acyclic joins: zero rejection (draws only overshoot by the final
    # batch's granularity)
    assert draws <= N + 4096
    got = np.stack([rows[a] for a in spec.output_attrs], axis=1)
    uni, counts = np.unique(got.view([("", got.dtype)] * got.shape[1]).ravel(),
                            return_counts=True)
    assert uni.shape[0] == n_tuples
    exp = N / n_tuples
    chi2 = float(((counts - exp) ** 2 / exp).sum())
    p = 1 - sps.chi2.cdf(chi2, df=n_tuples - 1)
    assert p > 1e-3, f"EW sampling not uniform (p={p})"


def test_eo_sampling_uniform_chi2():
    cat, spec = _chain(2)
    s = JoinSampler(cat, spec, method="eo")
    mat = full_join_matrix(cat, spec)
    n_tuples = mat.shape[0]
    rng = np.random.default_rng(0)
    N = 50 * n_tuples
    rows, draws = s.sample_uniform(rng, N, batch=4096)
    assert draws > N  # EO rejects
    got = np.stack([rows[a] for a in spec.output_attrs], axis=1)
    uni, counts = np.unique(got.view([("", got.dtype)] * got.shape[1]).ravel(),
                            return_counts=True)
    exp = N / n_tuples
    chi2 = float(((counts - exp) ** 2 / exp).sum()) + (n_tuples - uni.shape[0]) * exp
    p = 1 - sps.chi2.cdf(chi2, df=n_tuples - 1)
    assert p > 1e-3, f"EO sampling not uniform (p={p})"


def test_wj_horvitz_thompson_unbiased():
    cat, spec = _chain(3)
    true_size = full_join_matrix(cat, spec).shape[0]
    est = WanderJoinSizeEstimator(cat, spec, seed=0, batch=1024)
    for _ in range(30):
        est.step()
    assert est.estimate == pytest.approx(true_size, rel=0.15)


def test_wj_ci_stopping():
    cat, spec = _chain(4)
    true_size = full_join_matrix(cat, spec).shape[0]
    est = WanderJoinSizeEstimator(cat, spec, seed=1, batch=512)
    v = est.run(confidence=0.90, rel_halfwidth=0.10, max_walks=60_000)
    assert v == pytest.approx(true_size, rel=0.25)


def test_olken_bound_is_upper_bound():
    for seed in range(5):
        cat, spec = _chain(seed)
        assert olken_bound(cat, spec) >= full_join_matrix(cat, spec).shape[0]


# ---------------------------------------------------------------------------
# Cyclic join sampling (skeleton + residual accept/reject)
# ---------------------------------------------------------------------------


def _cyclic(seed=0):
    rng = np.random.default_rng(seed)
    R = Relation = None
    from repro.core.relation import Relation
    R = Relation("R", {"a": rng.integers(0, 5, 25), "b": rng.integers(0, 5, 25),
                       "rid": np.arange(25)})
    S = Relation("S", {"b": rng.integers(0, 5, 25), "c": rng.integers(0, 5, 25),
                       "sid": np.arange(25)})
    T = Relation("T", {"c": rng.integers(0, 5, 40), "a": rng.integers(0, 5, 40),
                       "tid": np.arange(40)})
    spec = JoinSpec("tri", [
        JoinNode("R", R, None, ()),
        JoinNode("S", S, "R", ("b",)),
        JoinNode("T", T, None, ("c", "a"), kind="residual"),
    ])
    return Catalog(), spec


def test_cyclic_sampling_uniform():
    cat, spec = _cyclic(0)
    mat = full_join_matrix(cat, spec)
    n_tuples = mat.shape[0]
    assert n_tuples > 20
    s = JoinSampler(cat, spec, method="ew")
    rng = np.random.default_rng(0)
    N = 60 * n_tuples
    rows, draws = s.sample_uniform(rng, N, batch=8192)
    got = np.stack([rows[a] for a in spec.output_attrs], axis=1)
    uni, counts = np.unique(got.view([("", got.dtype)] * got.shape[1]).ravel(),
                            return_counts=True)
    exp = N / n_tuples
    chi2 = float(((counts - exp) ** 2 / exp).sum()) + (n_tuples - uni.shape[0]) * exp
    p = 1 - sps.chi2.cdf(chi2, df=n_tuples - 1)
    assert p > 1e-3, f"cyclic sampling not uniform (p={p})"


# ---------------------------------------------------------------------------
# Overlap estimators
# ---------------------------------------------------------------------------


def _two_chains(seed=0, overlap=0.5):
    """Two chain joins over variant relations with controlled overlap."""
    from repro.data.tpch import make_variants
    R, S, T = tiny_db(seed, n_r=80, n_s=90, n_t=70)
    cat = Catalog()
    Rv = make_variants(R, 2, overlap, seed=seed + 10)
    Sv = make_variants(S, 2, overlap, seed=seed + 11)
    Tv = make_variants(T, 2, overlap, seed=seed + 12)
    j0 = chain_join("J0", [Rv[0], Sv[0], Tv[0]], ["b", "c"])
    j1 = chain_join("J1", [Rv[1], Sv[1], Tv[1]], ["b", "c"])
    return cat, [j0, j1]


def test_histogram_overlap_is_sound_upper_bound():
    for seed in range(4):
        cat, joins = _two_chains(seed)
        hist = HistogramOverlap(cat, joins)
        bound = hist.estimate(joins)
        exact = exact_overlap(cat, joins)
        assert bound >= exact, f"seed={seed}: bound {bound} < exact {exact}"


def test_random_walk_overlap_converges():
    cat, joins = _two_chains(1, overlap=0.7)
    exact = exact_overlap(cat, joins)
    rw = RandomWalkOverlap(cat, joins, seed=0, batch=1024)
    est = rw.estimate(joins, rel_halfwidth=0.2, max_walks=40_000, min_walks=4096)
    if exact == 0:
        assert est.value < 5
    else:
        assert est.value == pytest.approx(exact, rel=0.5)


def test_random_walk_join_size():
    cat, joins = _two_chains(2)
    rw = RandomWalkOverlap(cat, joins, seed=3, batch=1024)
    true0 = full_join_matrix(cat, joins[0]).shape[0]
    est = rw.join_size(joins[0], min_walks=8192)
    assert est == pytest.approx(true0, rel=0.2)
