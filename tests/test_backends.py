"""Backend layer: device engine == host engine (distribution + membership).

Covers the acceptance criteria of the backend refactor: the jax backend's
candidate sources, membership oracle, and fused Algorithm-1 rounds must be
distributionally equivalent to the numpy reference on TPC-H-style union
workloads (chains, high-overlap predicate unions, and a branching tree).
"""

import numpy as np
import pytest
from scipy import stats as sps

from conftest import tiny_db

from repro.core.backends import NumpyBackend, get_backend
from repro.core.backends.base import Backend, CandidateSource, MembershipOracle
from repro.core.backends.jax_backend import (DeviceJoinMembership,
                                             DeviceTreeJoin, JaxBackend,
                                             fp32_np)
from repro.core.framework import estimate_union, warmup
from repro.core.index import Catalog
from repro.core.joins import JoinNode, JoinSpec, chain_join, full_join_matrix
from repro.core.overlap import exact_union_size
from repro.core.union_sampler import SetUnionSampler
from repro.data.workloads import uq1, uq2, uq3, uq4


def _tree_spec(seed=0):
    """Branching (non-chain) acyclic join over the tiny DB."""
    R, S, T = tiny_db(seed)
    S = S.rename({"c": "cs"})
    T = T.rename({"c": "ct", "d": "b"})     # T joins the root on b as well
    return Catalog(), JoinSpec("tree", [
        JoinNode("R", R, None, ()),
        JoinNode("S", S, "R", ("b",)),
        JoinNode("T", T, "R", ("b",)),
    ])


def _chi2_vs_expected(sample_matrix, expected_matrix):
    """Chi-square of sampled tuple counts against the exact multiplicity law."""
    def keyed(m):
        return m.view([("", m.dtype)] * m.shape[1]).ravel()
    uni, exp_counts = np.unique(keyed(expected_matrix), return_counts=True)
    s_uni, s_counts = np.unique(keyed(sample_matrix), return_counts=True)
    assert np.isin(s_uni, uni).all(), "sampled a tuple outside the join"
    counts = np.zeros(uni.shape[0])
    counts[np.searchsorted(uni, s_uni)] = s_counts
    N = sample_matrix.shape[0]
    exp = N * exp_counts / exp_counts.sum()
    chi2 = float(((counts - exp) ** 2 / exp).sum())
    return 1 - sps.chi2.cdf(chi2, df=uni.shape[0] - 1)


def _chi2_uniform(sample_matrix, n_universe):
    uni, counts = np.unique(
        sample_matrix.view([("", sample_matrix.dtype)] * sample_matrix.shape[1]).ravel(),
        return_counts=True)
    N = sample_matrix.shape[0]
    exp = N / n_universe
    chi2 = float(((counts - exp) ** 2 / exp).sum()) + (n_universe - uni.shape[0]) * exp
    return 1 - sps.chi2.cdf(chi2, df=n_universe - 1)


# ---------------------------------------------------------------------------
# protocols / factory
# ---------------------------------------------------------------------------


def test_backend_factory_and_protocols():
    cat, spec = _tree_spec(0)
    for name in ("numpy", "jax"):
        be = get_backend(name, cat, [spec], seed=0)
        assert isinstance(be, Backend)
        assert isinstance(be.source(spec.name), CandidateSource)
        assert isinstance(be.oracle(), MembershipOracle)
    # passing an instance through is the identity
    be = NumpyBackend(cat, [spec])
    assert get_backend(be, cat, [spec]) is be
    with pytest.raises(ValueError):
        get_backend("torch", cat, [spec])


# ---------------------------------------------------------------------------
# candidate source: device tree draws match the exact multiplicity law
# ---------------------------------------------------------------------------


def test_jax_tree_source_distribution():
    cat, spec = _tree_spec(1)
    mat = full_join_matrix(cat, spec)
    be = JaxBackend(cat, [spec], seed=2, device_batch=2048)
    src = be.source(spec.name)
    assert not src.is_empty()
    rows, draws = src.draw(np.random.default_rng(0), 40_000)
    assert draws >= 40_000
    got = np.stack([rows[a] for a in spec.output_attrs], axis=1)
    p = _chi2_vs_expected(got, mat)
    assert p > 1e-3, f"device tree sampler distribution off (p={p})"


def test_jax_tree_total_weight_matches_host():
    from repro.core.join_sampler import JoinSampler
    cat, spec = _tree_spec(2)
    tree = DeviceTreeJoin(cat, spec)
    host = JoinSampler(cat, spec, method="ew")
    assert tree.total_weight == pytest.approx(host.exact_acyclic_size())


def test_pallas_probe_path_matches_jnp():
    """use_pallas routes range probes through the kernels; same draws."""
    import jax
    cat, spec = _tree_spec(3)
    t_jnp = DeviceTreeJoin(cat, spec, use_pallas=False)
    t_pal = DeviceTreeJoin(cat, spec, use_pallas=True)
    key = jax.random.PRNGKey(0)
    r1, ok1, wok1 = jax.jit(lambda k: t_jnp.draw(k, 256))(key)
    r2, ok2, wok2 = jax.jit(lambda k: t_pal.draw(k, 256))(key)
    assert np.array_equal(np.asarray(ok1), np.asarray(ok2))
    assert np.array_equal(np.asarray(wok1), np.asarray(wok2))
    for a in spec.output_attrs:
        assert np.array_equal(np.asarray(r1[a]), np.asarray(r2[a])), a


# ---------------------------------------------------------------------------
# membership oracle: device == host, bit for bit
# ---------------------------------------------------------------------------


def test_membership_oracle_matches_host():
    wl = uq3(scale=0.01, overlap=0.3, seed=0)
    host = NumpyBackend(wl.cat, wl.joins).oracle()
    dev = JaxBackend(wl.cat, wl.joins).oracle()
    # probe a mix of real union tuples and perturbed non-members
    wr = warmup(wl.cat, wl.joins, method="exact")
    est = estimate_union(wr.oracle)
    s = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=3)
    ss = s.sample(500)
    rows = dict(ss.rows)
    names = [j.name for j in wl.joins]
    m_host = host.membership_matrix(rows, names)
    m_dev = dev.membership_matrix(rows, names)
    assert m_host.any(axis=1).all()          # union samples are members
    assert np.array_equal(m_host, m_dev)
    bad = {a: c + 1009 for a, c in rows.items()}
    assert np.array_equal(host.membership_matrix(bad, names),
                          dev.membership_matrix(bad, names))


def test_device_membership_fp_duplicate_window():
    """kmax duplicate handling: colliding fp1 values still verify via fp2."""
    from repro.core.relation import Relation
    rng = np.random.default_rng(0)
    rel = Relation("R", {"a": rng.integers(0, 4, 500),
                         "b": rng.integers(0, 4, 500)})
    spec = chain_join("J", [rel], [])
    dm = DeviceJoinMembership(spec)
    attrs = tuple(sorted(rel.attrs))
    fp1 = fp32_np([rel.columns[a] for a in attrs], salt=1)
    # 500 rows over 16 value pairs: fp1 duplicates guaranteed
    assert dm.rels[0][3] >= 2
    import jax, jax.numpy as jnp
    rows = {a: jnp.asarray(rel.columns[a].astype(np.int32)) for a in rel.attrs}
    assert np.asarray(jax.jit(dm.contains)(rows)).all()


# ---------------------------------------------------------------------------
# fused Algorithm-1 rounds: jax == numpy distribution on union workloads
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wl_fn,kw", [
    (uq1, dict(scale=0.05, overlap=0.5, seed=1, n_joins=2)),   # chains
    (uq2, dict(scale=0.02, seed=0)),                           # high overlap
    (uq2, dict(scale=0.02, seed=0, pred_mode="rejection")),    # §8.3 in-round
    (uq3, dict(scale=0.01, overlap=0.3, seed=0)),              # tree join
    (uq4, dict(scale=0.02, seed=0)),                           # cyclic (§8.2)
], ids=["uq1-chains", "uq2-overlap", "uq2-rejection", "uq3-tree",
        "uq4-cyclic"])
def test_set_union_jax_uniform(wl_fn, kw):
    wl = wl_fn(**kw)
    wr = warmup(wl.cat, wl.joins, method="exact")
    est = estimate_union(wr.oracle)
    U = exact_union_size(wl.cat, wl.joins)
    s = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=7, backend="jax",
                        round_batch=2048)
    N = 120 * U
    ss = s.sample(N)
    assert len(ss) == N
    p = _chi2_uniform(ss.matrix(), U)
    assert p > 1e-3, f"device Algorithm-1 not uniform on {wl.name} (p={p})"


def test_set_union_jax_matches_numpy_home_marginal():
    wl = uq1(scale=0.05, overlap=0.5, seed=1, n_joins=2)
    wr = warmup(wl.cat, wl.joins, method="exact")
    est = estimate_union(wr.oracle)
    a = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=3).sample(8000)
    b = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=3, backend="jax",
                        round_batch=1024).sample(8000)
    fa = np.bincount(a.home, minlength=2) / len(a)
    fb = np.bincount(b.home, minlength=2) / len(b)
    assert np.abs(fa - fb).max() < 0.03


# ---------------------------------------------------------------------------
# validation / fallbacks
# ---------------------------------------------------------------------------


def test_jax_backend_rejects_unsupported_modes():
    """Mode gates: predicates and membership="record" now run fused; only
    strict_paper_loop (and non-lowerable predicates) stay on the host."""
    wl = uq3(scale=0.01, overlap=0.3, seed=0)
    wr = warmup(wl.cat, wl.joins, method="exact")
    est = estimate_union(wr.oracle)
    from repro.core.backends.jax_backend import (JaxRecordUnionSampler,
                                                 JaxUnionSampler)
    from repro.core.predicates import Pred, RejectingPredicate
    s = SetUnionSampler(wl.cat, wl.joins, est.cover, membership="record",
                        backend="jax")
    assert isinstance(s._engine, JaxRecordUnionSampler)
    s = SetUnionSampler(wl.cat, wl.joins, est.cover, backend="jax",
                        predicate=RejectingPredicate([Pred("odate", "<=", 1)]))
    assert isinstance(s._engine, JaxUnionSampler)
    # a predicate outside the int32 comparison domain degrades to the host
    # Algorithm-1 loop (no error) ...
    s = SetUnionSampler(wl.cat, wl.joins, est.cover, backend="jax",
                        predicate=RejectingPredicate(
                            [Pred("odate", "<=", 2 ** 40)]))
    assert s._engine is None
    # ... and strict_paper_loop remains the host-only ablation
    s = SetUnionSampler(wl.cat, wl.joins, est.cover, strict_paper_loop=True,
                        backend="jax")
    assert s._engine is None
    with pytest.raises(ValueError, match="ew"):
        JaxBackend(wl.cat, wl.joins, join_method="eo")


def test_jax_backend_runs_cyclic():
    """Cyclic joins build and draw on device (§8.2 skeleton+residual)."""
    wl = uq4(scale=0.02, seed=0)
    be = JaxBackend(wl.cat, wl.joins)
    assert be.supports_fused_rounds() and not be.degraded
    src = be.source("UQ4_CYC")
    assert src.tree.has_residual and not src.is_empty()
    rows, draws = src.draw(np.random.default_rng(0), 500)
    assert draws >= 500
    # every drawn tuple is a member of the cyclic join (host 128-bit oracle)
    host = NumpyBackend(wl.cat, wl.joins).oracle()
    assert host.contains("UQ4_CYC", rows).all()
    # device membership matrix equals the host's on cyclic joins too
    dev = be.oracle()
    names = [j.name for j in wl.joins]
    assert np.array_equal(host.membership_matrix(rows, names),
                          dev.membership_matrix(rows, names))


def test_mixed_union_degrades_per_join():
    """A union where ONE join trips a device limit degrades that join to the
    host source (one warning) instead of raising for the whole union."""
    from repro.core.relation import Relation
    rng = np.random.default_rng(0)
    big = 1 << 31                            # outside the int32 device domain
    R1 = Relation("R1", {"a": rng.integers(0, 8, 50),
                         "b": rng.integers(0, 8, 50)})
    R2 = Relation("R2", {"a": np.concatenate([rng.integers(0, 8, 49),
                                              np.asarray([big])]),
                         "b": rng.integers(0, 8, 50)})
    j_ok = chain_join("J_OK", [R1], [])
    j_bad = chain_join("J_BAD", [R2], [])
    cat = Catalog()
    with pytest.warns(UserWarning, match="fall back to host"):
        be = JaxBackend(cat, [j_ok, j_bad])
    assert not be.supports_fused_rounds()
    assert set(be.degraded) == {"J_BAD"}
    assert "J_OK" in be.trees                # device-eligible join stays on it
    # both sources still draw; the sampler runs on the host loop
    from repro.core.cover import Cover
    cover = Cover(["J_OK", "J_BAD"], {"J_OK": 50.0, "J_BAD": 50.0},
                  {"J_OK": 50.0, "J_BAD": 50.0})
    with pytest.warns(UserWarning, match="host oracle"):
        s = SetUnionSampler(cat, [j_ok, j_bad], cover, seed=3, backend=be)
        ss = s.sample(300)
    assert len(ss) == 300
    assert s._engine is None                 # fused rounds disabled


def test_online_union_jax_backend_smoke():
    from repro.core.online import OnlineUnionSampler
    wl = uq3(scale=0.01, overlap=0.3, seed=0)
    ou = OnlineUnionSampler(wl.cat, wl.joins, seed=5, phi=512, rw_batch=128,
                            backend="jax")
    ss = ou.sample(200)
    assert len(ss) == 200
