"""Per-arch smoke tests + decode/prefill consistency + SSD math checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.models.layers import decode_attention, flash_attention
from repro.models.serve import decode_step, init_cache, prefill_step
from repro.models.ssm import SSMDims, mamba2_block, mamba2_decode, ssm_param_shapes
from repro.models.transformer import forward_train, init_params


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_forward_shapes_no_nans(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, seed=0)
    rng = np.random.default_rng(0)
    B, S = 2, 64
    batch = {"tokens": jnp.asarray(rng.integers(4, cfg.vocab, (B, S)), jnp.int32),
             "targets": jnp.asarray(rng.integers(4, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.frontend != "none":
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_frontend_tokens, cfg.d_model)),
            cfg.compute_dtype)
    loss, metrics = jax.jit(lambda p, b: forward_train(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    assert metrics["tokens"] > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_one_train_step(arch):
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import TrainConfig, init_train_state, make_train_step
    cfg = get_smoke_config(arch)
    tc = TrainConfig(opt=OptConfig(lr=1e-3), total_steps=10, warmup_steps=1)
    state = init_train_state(cfg, tc, seed=0)
    step = jax.jit(make_train_step(cfg, tc))
    rng = np.random.default_rng(1)
    B, S = 2, 64
    batch = {"tokens": jnp.asarray(rng.integers(4, cfg.vocab, (B, S)), jnp.int32),
             "targets": jnp.asarray(rng.integers(4, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.frontend != "none":
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_frontend_tokens, cfg.d_model)),
            cfg.compute_dtype)
    state2, metrics = step(state, batch)
    assert int(state2["step"]) == 1
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = float(jnp.abs(state2["params"]["embed"] - state["params"]["embed"]).max())
    assert delta > 0


@pytest.mark.parametrize("arch", ["minitron-8b", "gemma2-9b", "mamba2-780m",
                                  "zamba2-7b", "phi3.5-moe-42b-a6.6b"])
def test_decode_matches_stepwise_forward(arch):
    """Greedy-decode logits from the cache path == full forward logits.

    Decodes tokens one at a time from an empty cache and compares the final
    step's logits against prefill over the same prefix — validates RoPE
    positions, cache updates, ring buffers, and SSM state recurrences.
    """
    cfg = get_smoke_config(arch)
    params = init_params(cfg, seed=0)
    rng = np.random.default_rng(2)
    B, T = 2, 9
    toks = rng.integers(4, cfg.vocab, (B, T)).astype(np.int32)

    cache = init_cache(cfg, B, 32)
    dstep = jax.jit(lambda c, t, l: decode_step(params, cfg, c, t, l))
    logits = None
    for t in range(T):
        lengths = jnp.full((B,), t, jnp.int32)
        cache, logits = dstep(cache, jnp.asarray(toks[:, t:t + 1]), lengths)

    batch = {"tokens": jnp.asarray(toks)}
    if cfg.frontend != "none":
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_frontend_tokens, cfg.d_model)),
            cfg.compute_dtype)
        pytest.skip("frontend archs: decode consistency covered by dense cases")
    full = jax.jit(lambda p, b: prefill_step(p, cfg, b))(params, batch)
    got = np.asarray(logits, np.float32)
    want = np.asarray(full, np.float32)
    # bf16 compute: compare top-1 agreement + correlation
    corr = np.corrcoef(got.ravel(), want.ravel())[0, 1]
    assert corr > 0.99, f"decode/forward correlation {corr}"
    assert (got.argmax(-1) == want.argmax(-1)).mean() >= 0.5


def test_flash_attention_matches_naive():
    rng = np.random.default_rng(3)
    B, S, H, KV, D = 2, 128, 8, 4, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=64)
    # naive reference
    G = H // KV
    qr = q.reshape(B, S, KV, G, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qr, k) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    want = jnp.einsum("bkgst,btkd->bskgd", p, v).reshape(B, S, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_window_and_softcap():
    rng = np.random.default_rng(4)
    B, S, H, KV, D = 1, 128, 4, 4, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=16, cap=20.0,
                          q_chunk=32, kv_chunk=32)
    logits = jnp.einsum("bshd,bthd->bhst", q, k) / np.sqrt(D)
    logits = 20.0 * jnp.tanh(logits / 20.0)
    i, j = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    mask = (i >= j) & (j > i - 16)
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    want = jnp.einsum("bhst,bthd->bshd", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_equals_recurrence():
    """Chunked SSD scan == token-by-token recurrence (the SSD duality)."""
    rng = np.random.default_rng(5)
    dims = SSMDims(d_model=32, d_inner=64, n_heads=4, head_dim=16, state=8)
    B, S = 2, 64
    from repro.models.ssm import ssd_chunked
    u = rng.standard_normal((B, S, 4, 16)).astype(np.float32)
    log_a = -np.abs(rng.standard_normal((B, S, 4))).astype(np.float32) * 0.1
    Bc = rng.standard_normal((B, S, 8)).astype(np.float32)
    Cc = rng.standard_normal((B, S, 8)).astype(np.float32)
    y, h = ssd_chunked(jnp.asarray(u), jnp.asarray(log_a), jnp.asarray(Bc),
                       jnp.asarray(Cc), chunk=16)
    # recurrence
    hs = np.zeros((B, 4, 8, 16), np.float32)
    ys = np.zeros((B, S, 4, 16), np.float32)
    for t in range(S):
        a = np.exp(log_a[:, t])                      # (B,H)
        hs = hs * a[:, :, None, None] + np.einsum("bn,bhp->bhnp", Bc[:, t], u[:, t])
        ys[:, t] = np.einsum("bn,bhnp->bhp", Cc[:, t], hs)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), hs, rtol=2e-3, atol=2e-3)


def test_moe_capacity_and_aux():
    from repro.models.moe import MoEDims, moe_ffn, moe_param_shapes
    rng = np.random.default_rng(6)
    dims = MoEDims(d_model=32, n_experts=4, top_k=2, d_ff=64)
    params = {k: jnp.asarray(rng.standard_normal(s) * 0.05, jnp.float32)
              for k, s in moe_param_shapes(dims).items()}
    x = jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.float32)
    out, aux = moe_ffn(params, x, dims)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0
