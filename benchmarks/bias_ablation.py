"""Beyond-paper ablation: the printed Algorithm 1 loop vs Theorem-1 semantics.

The paper's pseudocode re-selects a join after a cover rejection; Theorem 1's
proof requires retry-within-join (uniform over the cover piece).  This
benchmark quantifies the resulting bias: chi-square statistic of each variant
against the uniform distribution over the exact union (DESIGN.md §7.9).
"""

from __future__ import annotations

import numpy as np
from scipy import stats as sps

from repro.core.framework import estimate_union, warmup
from repro.core.overlap import exact_union_size
from repro.core.union_sampler import SetUnionSampler
from repro.data.workloads import uq3

from .common import emit, timed


def chi2_p(ss, U):
    mat = ss.matrix()
    uni, counts = np.unique(mat.view([("", mat.dtype)] * mat.shape[1]).ravel(),
                            return_counts=True)
    N = len(ss)
    exp = N / U
    chi2 = float(((counts - exp) ** 2 / exp).sum()) + (U - uni.shape[0]) * exp
    return chi2, 1 - sps.chi2.cdf(chi2, df=U - 1)


def main(small: bool = True) -> None:
    wl = uq3(scale=0.01 if small else 0.05, overlap=0.5, seed=0)
    wr = warmup(wl.cat, wl.joins, method="exact")
    est = estimate_union(wr.oracle)
    U = exact_union_size(wl.cat, wl.joins)
    N = (60 if small else 200) * U
    import time
    for strict in (False, True):
        s = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=1,
                            membership="probe", strict_paper_loop=strict)
        t0 = time.perf_counter()
        ss = s.sample(N)
        dt = time.perf_counter() - t0
        chi2, p = chi2_p(ss, U)
        tag = "printed_loop" if strict else "theorem1_retry"
        emit(f"ablation_alg1_{tag}", dt / N * 1e6,
             f"chi2={chi2:.1f};p={p:.4f};N={N}")


if __name__ == "__main__":
    main(small=False)
