"""Fig 4c/4d: union-size estimation runtime — HISTOGRAM-BASED vs FULLJOIN.

The device-estimation comparison (host refinement loop vs the jitted
walk+probe+HT batch of the estimator subsystem) rides along via
:mod:`benchmarks.estimation_device`, which excludes one-time jit
compilation like the other device benchmarks.

CLI: ``python -m benchmarks.estimation_runtime [--smoke]`` — ``--smoke`` is
the CI job: the quick functional pass over both engines; the default is the
paper-scale run.
"""

from __future__ import annotations

import argparse
import time

from repro.core.framework import estimate_union, warmup
from repro.core.overlap import exact_union_size
from repro.data.workloads import uq1, uq3

from .common import emit


def run_one(tag, wl, rw_walks):
    t0 = time.perf_counter()
    wr = warmup(wl.cat, wl.joins, method="histogram")
    estimate_union(wr.oracle)
    t_hist = time.perf_counter() - t0

    t0 = time.perf_counter()
    wr2 = warmup(wl.cat, wl.joins, method="random_walk", rw_max_walks=rw_walks)
    estimate_union(wr2.oracle)
    t_rw = time.perf_counter() - t0

    t0 = time.perf_counter()
    full = exact_union_size(wl.cat, wl.joins)
    t_full = time.perf_counter() - t0

    emit(f"fig4c_{tag}_hist", t_hist * 1e6, f"speedup_vs_fulljoin={t_full/max(t_hist,1e-9):.1f}x")
    emit(f"fig4c_{tag}_rw", t_rw * 1e6, f"speedup_vs_fulljoin={t_full/max(t_rw,1e-9):.1f}x")
    emit(f"fig4c_{tag}_fulljoin", t_full * 1e6, f"|U|={full}")


def main(small: bool = True) -> None:
    scale = 0.05 if small else 0.5
    run_one("uq1", uq1(scale=scale, overlap=0.3, seed=0, n_joins=3),
            2000 if small else 20000)
    run_one("uq3", uq3(scale=scale, overlap=0.3, seed=0),
            2000 if small else 20000)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="quick functional pass (CI job)")
    args = ap.parse_args()
    from .common import header
    from . import estimation_device
    header()
    main(small=args.smoke)
    estimation_device.main(small=args.smoke)
