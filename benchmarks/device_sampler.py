"""Beyond-paper: host (numpy) vs device (jitted) sampler throughput.

Two comparisons:

* single chain join — the original device-path benchmark
  (:class:`JaxChainSampler`, now backed by the generalised tree engine),
* 2-join union — host ``SetUnionSampler`` vs the fused device engine
  (``backend="jax"``): one jitted program per Algorithm-1 round, no host
  round trips for cover selection / candidate draws / membership probes.

The jitted samplers run the whole pipeline as one XLA program — the
deployment path that co-locates sampling with training/serving.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.framework import estimate_union, warmup
from repro.core.jax_sampler import JaxChainSampler
from repro.core.join_sampler import JoinSampler
from repro.core.union_sampler import SetUnionSampler
from repro.data.workloads import uq1

from .common import emit


def bench_chain(small: bool) -> None:
    wl = uq1(scale=0.1 if small else 0.5, overlap=0.4, seed=0, n_joins=1)
    cat, spec = wl.cat, wl.joins[0]
    n = 20_000 if small else 200_000

    host = JoinSampler(cat, spec, method="ew")
    rng = np.random.default_rng(0)
    host.sample_batch(rng, 1024)             # warm caches
    t0 = time.perf_counter()
    host.sample_uniform(rng, n, batch=8192)
    t_host = time.perf_counter() - t0

    dev = JaxChainSampler(cat, spec, seed=0)
    dev.sample_batch(8192)                   # compile
    t0 = time.perf_counter()
    dev.sample_uniform(n, batch=8192)
    t_dev = time.perf_counter() - t0

    emit("device_sampler_host_numpy", t_host / n * 1e6, f"n={n}")
    emit("device_sampler_jitted", t_dev / n * 1e6,
         f"speedup={t_host/max(t_dev,1e-9):.2f}x")


def bench_union(small: bool) -> None:
    """2-join union: host Algorithm-1 loop vs the fused device engine."""
    wl = uq1(scale=0.1 if small else 0.5, overlap=0.4, seed=0, n_joins=2)
    wr = warmup(wl.cat, wl.joins, method="exact")
    est = estimate_union(wr.oracle)
    n = 50_000 if small else 400_000

    host = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=3)
    host.sample(1024)                        # warm caches
    t0 = time.perf_counter()
    host.sample(n)
    t_host = time.perf_counter() - t0

    dev = SetUnionSampler(wl.cat, wl.joins, est.cover, seed=3,
                          backend="jax", round_batch=16384)
    dev.sample(1024)                         # compile the fused round
    t0 = time.perf_counter()
    dev.sample(n)
    t_dev = time.perf_counter() - t0

    emit("union_engine_host_numpy", t_host / n * 1e6,
         f"n={n} rate={n/max(t_host,1e-9):,.0f}/s")
    emit("union_engine_jitted", t_dev / n * 1e6,
         f"rate={n/max(t_dev,1e-9):,.0f}/s "
         f"speedup={t_host/max(t_dev,1e-9):.2f}x")


def main(small: bool = True) -> None:
    bench_chain(small)
    bench_union(small)


if __name__ == "__main__":
    main(small=False)
