"""Beyond-paper: host (numpy) vs device (jitted) chain-sampler throughput.

The jitted sampler runs the whole hop pipeline as one XLA program (no host
round trips) — the deployment path that co-locates sampling with training.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.jax_sampler import JaxChainSampler
from repro.core.join_sampler import JoinSampler
from repro.data.workloads import uq1

from .common import emit


def main(small: bool = True) -> None:
    wl = uq1(scale=0.1 if small else 0.5, overlap=0.4, seed=0, n_joins=1)
    cat, spec = wl.cat, wl.joins[0]
    n = 20_000 if small else 200_000

    host = JoinSampler(cat, spec, method="ew")
    rng = np.random.default_rng(0)
    host.sample_batch(rng, 1024)             # warm caches
    t0 = time.perf_counter()
    host.sample_uniform(rng, n, batch=8192)
    t_host = time.perf_counter() - t0

    dev = JaxChainSampler(cat, spec, seed=0)
    dev.sample_batch(1024)                   # compile
    t0 = time.perf_counter()
    dev.sample_uniform(n, batch=8192)
    t_dev = time.perf_counter() - t0

    emit("device_sampler_host_numpy", t_host / n * 1e6, f"n={n}")
    emit("device_sampler_jitted", t_dev / n * 1e6,
         f"speedup={t_host/max(t_dev,1e-9):.2f}x")


if __name__ == "__main__":
    main(small=False)
